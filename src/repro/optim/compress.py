"""1-bit sign gradient compression with error feedback — the paper's binary
domain applied to the collective fabric.

signSGD-with-majority-vote / EF-signSGD style: each worker transmits
sign(g + e) as packed bit-planes (32x smaller than f32, 16x than bf16) plus
one f32 scale per tensor; the residual e accumulates the quantization error
so the compressed SGD direction stays unbiased in the long run
(Karimireddy et al., 2019).

Under pjit we model compression *inside* the step function: the gradient
all-reduce operates on the packed uint32 planes (what crosses the pod axis)
and the scales.  ``compress/decompress`` round-trips are bit-exact with
``repro.kernels`` packing, so the same Pallas kernels serve training comms
and serving GEMMs — one bit-engine, two uses, exactly the paper's
"same sense amp, different reference" economy.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bitpack


class CompressState(NamedTuple):
    error: dict   # residual per leaf (f32)


def init(params) -> CompressState:
    return CompressState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def abstract(params) -> CompressState:
    return CompressState(jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params))


def compress_leaf(g: jnp.ndarray, e: jnp.ndarray):
    """g -> (planes uint32, scale f32 scalar, new_error).  sign with L1 scale:
    approx = scale * sign(g + e); e' = (g + e) - approx."""
    corrected = g.astype(jnp.float32) + e
    scale = jnp.mean(jnp.abs(corrected))
    flat = corrected.reshape(-1)
    planes = bitpack.pack_bits(bitpack.pad_to_word(flat))
    approx = scale * jnp.where(flat >= 0, 1.0, -1.0)
    new_e = (flat - approx).reshape(g.shape)
    return planes, scale, new_e


def decompress_leaf(planes: jnp.ndarray, scale: jnp.ndarray, shape, dtype):
    n = 1
    for s in shape:
        n *= s
    signs = bitpack.unpack_bits(planes, n)
    return (scale * signs).reshape(shape).astype(dtype)


def compress_grads(grads, state: CompressState):
    """Pytree version. Returns (compressed pytree of (planes, scale), state)."""
    leaves, tdef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(state.error)
    out, new_errs = [], []
    for g, e in zip(leaves, errs):
        planes, scale, ne = compress_leaf(g, e)
        out.append((planes, scale))
        new_errs.append(ne)
    return (jax.tree.unflatten(tdef, [o for o in out]),
            CompressState(jax.tree.unflatten(tdef, new_errs)))


def decompress_grads(compressed, like):
    leaves, tdef = jax.tree.flatten(like)
    comp = jax.tree.leaves(compressed, is_leaf=lambda x: isinstance(x, tuple))
    out = [decompress_leaf(c[0], c[1], g.shape, g.dtype)
           for c, g in zip(comp, leaves)]
    return jax.tree.unflatten(tdef, out)
