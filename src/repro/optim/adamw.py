"""AdamW with fp32 state over (possibly bf16) params + global-norm clipping.

State layout mirrors the param pytree (m, v in f32, sharded identically to
the params — under FSDP the optimizer state is automatically ZeRO-sharded
because its shardings are inherited from the param shardings).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def abstract(params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, F32), params)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), zeros, zeros)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), gn


def update(params, grads, state: AdamWState, lr, *, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, max_norm=1.0):
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g32 = g.astype(F32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            u = u + weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * u).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gnorm
