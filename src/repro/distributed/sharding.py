"""Logical-axis -> mesh-axis rules and sharding helpers.

Mesh axes (launch/mesh.py):
  pod    — inter-pod (DCN-class links); pure data parallelism, and the axis
           the 1-bit gradient compression targets.
  data   — intra-pod batch + FSDP (ZeRO-3 param/optimizer sharding).
  model  — tensor parallel (heads / d_ff / vocab) and expert parallel.

Rules are per-call overridable — the §Perf hillclimbs swap them without
touching model code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

DEFAULT_RULES = {"fsdp": "data", "tp": "model", "ep": "model"}


def shard_map(f, mesh: Mesh, in_specs, out_specs, manual_axes: set[str]):
    """Version-portable shard_map, manual over ``manual_axes`` only.

    Newer jax spells this ``jax.shard_map(..., axis_names=manual_axes,
    check_vma=False)``; jax 0.4.x spells it ``auto = mesh axes - manual``.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs,
                                 axis_names=set(manual_axes), check_vma=False)
        except TypeError:  # mid-window jax: top-level symbol, old kwargs
            pass
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False,
                      auto=frozenset(mesh.axis_names) - set(manual_axes))


def pxor(x: jax.Array, axis_name: str) -> jax.Array:
    """Bitwise-XOR all-reduce over a named mesh axis (inside shard_map).

    XOR has no built-in collective, so all-gather the per-device values and
    fold them locally, pairwise (log2-depth arithmetic — the *communication*
    is the one all-gather, ``devices`` copies of ``x`` per device).  The
    sharded engine only reduces ``digest_width``-word digests (512 bytes
    each at the default width), so digests are the entire cross-device
    payload of a sharded digest — the buffer itself never moves.
    """
    g = jax.lax.all_gather(x, axis_name, axis=0)      # (devices, ...)
    while g.shape[0] > 1:
        half = g.shape[0] // 2
        folded = g[:half] ^ g[half:2 * half]
        g = (folded if g.shape[0] % 2 == 0
             else jnp.concatenate([folded, g[2 * half:]], axis=0))
    return g[0]


def batch_axes(mesh: Mesh, global_batch: int):
    """Largest prefix of (pod, data) that divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen = []
    div = 1
    for a in axes:
        n = mesh.shape[a]
        if global_batch % (div * n) == 0:
            chosen.append(a)
            div *= n
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def data_specs(mesh: Mesh, global_batch: int, has_ctx: bool = False):
    """PartitionSpecs for a train/prefill batch dict."""
    ba = batch_axes(mesh, global_batch)
    specs = {"tokens": P(ba, None), "labels": P(ba, None)}
    if has_ctx:
        specs["ctx"] = P(ba, None, None)
    return specs
