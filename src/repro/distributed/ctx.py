"""Ambient activation-sharding rules (MaxText-style logical axis names).

GSPMD gets argument shardings from in_shardings, but *intermediate*
placement is cost-model guesswork — and at 256-way meshes it reliably
guesses wrong for FSDP-sharded contractions (it all-reduces TB-scale
activations instead of all-gathering MB-scale weight shards; measured in
EXPERIMENTS.md §Perf iteration 1).  Models therefore pin activations at
block boundaries via :func:`constrain`, using logical names resolved
against an ambient rule set.

Outside a mesh/rules context (CPU smoke tests, examples) ``constrain`` is a
no-op, so model code carries no mesh plumbing.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def _rules() -> dict | None:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def activation_rules(rules: dict | None):
    """rules: logical axis -> mesh axis (or tuple), e.g.
    {"batch": ("pod", "data"), "tp": "model", "ep": "model"}."""
    prev = _rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def constrain(x, *axes):
    """Pin activation sharding: constrain(y, "batch", None, "tp").

    Logical axes map through the ambient rules; unknown names and absent
    rules degrade to unconstrained.  Must be called under a mesh context
    (jit with in_shardings provides one via the dry-run's `with mesh:`).
    """
    rules = _rules()
    if rules is None:
        return x
    spec = P(*(rules.get(a) if isinstance(a, str) else None for a in axes))
    return jax.lax.with_sharding_constraint(x, spec)
