"""Fault-tolerance orchestration: checkpoint-restart, straggler mitigation,
elastic re-meshing policy.

The mechanisms (what this module coordinates):
* restart      — deterministic resume: step index addresses both the
                 checkpoint and the (stateless) data pipeline, so a restart
                 replays nothing and skips nothing.
* verification — every save/restore parity-checks shards (checkpoint/ckpt.py);
                 a corrupt shard is treated as a failed node: fall back to
                 the previous checkpoint.
* stragglers   — per-step wall-time watermarking: steps slower than
                 ``straggler_factor`` x the trailing median are logged and
                 counted; after ``max_strikes`` the runner requests a
                 re-shard (in a real cluster: evict + re-slice the mesh; in
                 this container: recorded decision, exercised by tests).
* elasticity   — checkpoints are tree-path addressed (not device-indexed),
                 so restore onto a different mesh shape re-shards via the
                 in_shardings of the target jit — no format migration.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from statistics import median

from repro.checkpoint import ckpt


@dataclass
class StragglerPolicy:
    straggler_factor: float = 2.0
    max_strikes: int = 3
    window: int = 20
    _times: list = field(default_factory=list)
    strikes: int = 0
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> str:
        """Returns "ok" | "straggler" | "reshard"."""
        self._times = (self._times + [dt])[-self.window:]
        if len(self._times) < 5:
            return "ok"
        med = median(self._times[:-1])
        if dt > self.straggler_factor * med:
            self.strikes += 1
            self.events.append((step, dt, med))
            if self.strikes >= self.max_strikes:
                self.strikes = 0
                return "reshard"
            return "straggler"
        return "ok"


@dataclass
class Runner:
    """Restartable step loop around a (state, batch)->state step function."""
    directory: str
    save_every: int = 50
    keep_last: int = 3
    root_key: str | None = None
    policy: StragglerPolicy = field(default_factory=StragglerPolicy)

    def resume_or_init(self, like, init_fn):
        """Restore latest valid checkpoint or build fresh state."""
        step = ckpt.latest_step(self.directory)
        while step is not None:
            try:
                state, _ = ckpt.restore(self.directory, step, like,
                                        root_key=self.root_key)
                return state, step
            except Exception:
                # corrupt/unreadable shard (parity mismatch, truncated zip,
                # missing manifest) == failed node: fall back one checkpoint
                prev = [s for s in self._steps() if s < step]
                step = max(prev) if prev else None
        return init_fn(), 0

    def maybe_save(self, step: int, state) -> bool:
        if step % self.save_every != 0:
            return False
        ckpt.save(self.directory, step, state, root_key=self.root_key)
        self._gc()
        return True

    def observe_step(self, step: int, dt: float) -> str:
        return self.policy.observe(step, dt)

    def _steps(self):
        import os, re
        if not os.path.isdir(self.directory):
            return []
        return sorted(int(m.group(1)) for f in os.listdir(self.directory)
                      if (m := re.match(r"ckpt_(\d+)\.npz$", f)))

    def _gc(self):
        import os
        steps = self._steps()
        for s in steps[:-self.keep_last]:
            for pat in (f"ckpt_{s:08d}.npz", f"manifest_{s:08d}.msgpack"):
                try:
                    os.remove(os.path.join(self.directory, pat))
                except FileNotFoundError:
                    pass
