"""Incremental verification — the paper's backup-scrub workload without the
redundant traffic.

The paper's Fig. 1(a) story is periodic verification of a massive data pool:
XOR the copy against the source, all-zero means intact.  At framework scale
:func:`repro.core.verify.tree_digest` already reduces the *comparison*
traffic to 512-byte digests — but it still re-digests every leaf on every
scan, even when a training step touched a fraction of the tree.  The in-DRAM
bulk X(N)OR line (Angizi & Fan, 2019) makes the point that the win of
memory-side logic is *not moving data you don't have to*; this module
applies it to the digest pass itself:

* :class:`ChunkedDigest` — a per-leaf ``(n_chunks, digest_width)`` digest
  matrix, one row per fixed-size chunk of the leaf's uint32 word stream,
  computed through the engine's chunk-level export
  (:meth:`repro.core.engine.CimEngine.digest_chunks`).  XOR-folding the
  rows equals the one-shot digest of the leaf (chunks are aligned to whole
  digest rows, same invariant as ``digest_stream``), so the matrix refines
  the existing digest without changing it.
* :class:`DigestCache` — keyed by tree path, retains each leaf's last-seen
  word stream and digest matrix.  Re-digesting a tree then costs engine
  traffic proportional to what *changed*: unchanged leaf objects are
  identity-hits (zero work), changed leaves get a single fused word-compare
  to locate dirty chunks (no digest dispatch — this is the cheap in-memory
  XOR+zero-test the paper makes free), and only dirty chunks are
  re-dispatched through the engine.  ``engine.stats`` therefore shows
  O(dirty-chunks) digest cycles, not O(tree) — pinned by
  ``tests/test_incremental.py``.

Both engine classes drop in: a :class:`repro.core.engine.ShardedCimEngine`
digests each dirty chunk sharded, so the incremental scan scales across the
mesh exactly like the full scan (DESIGN.md §12).

The identity tier only trusts *immutable* leaves (jax arrays): any numpy
leaf passed as the same object falls through to the word-compare — even
read-only flags can't prove a host buffer didn't mutate (a frozen view
still aliases its writable base) — so in-place host-side updates are
always detected (at the cost of the compare pass).  The retained word
streams make the cache the
reference copy of the pool: memory cost is one extra copy of the tree,
which is the backup being verified in the paper's workload.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as _engine
from repro.core import verify as _verify
from repro.core.verify import DIGEST_WIDTH, leaf_key
from repro.kernels import ops


@dataclasses.dataclass
class ChunkedDigest:
    """Per-chunk digest matrix of one leaf's uint32 word stream.

    ``chunks[i]`` is the XOR-parity digest of words
    ``[i*chunk_words, (i+1)*chunk_words)``; :meth:`digest` folds the rows
    into the leaf's ordinary one-shot digest.
    """
    chunks: np.ndarray          # (n_chunks, digest_width) uint32, host-side
    chunk_words: int
    nwords: int                 # unpadded length of the word stream
    digest_width: int = DIGEST_WIDTH
    _folded: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)  # memoized digest() fold

    @property
    def n_chunks(self) -> int:
        return self.chunks.shape[0]

    @classmethod
    def compute(cls, buf, engine: _engine.CimEngine,
                chunk_words: int | None = None,
                digest_width: int = DIGEST_WIDTH) -> "ChunkedDigest":
        """Full compute through the engine's chunk-level digest export."""
        words = _leaf_words(buf)
        chunk = engine._chunk_words(chunk_words, digest_width)
        rows = np.asarray(engine.digest_chunks(words, chunk, digest_width))
        return cls(chunks=rows, chunk_words=chunk,
                   nwords=int(words.shape[0]), digest_width=digest_width)

    def digest(self) -> np.ndarray:
        """Whole-leaf digest: XOR fold of the chunk rows (bit-identical to
        ``ops.digest`` of the full stream).  Memoized — identity-tier cache
        hits must not re-fold a huge matrix on every scrub; updates build a
        new ChunkedDigest, so the memo can never go stale."""
        if self._folded is None:
            self._folded = np.bitwise_xor.reduce(self.chunks, axis=0)
        return self._folded

    def diff(self, other: "ChunkedDigest") -> np.ndarray:
        """Indices of chunk rows that differ from ``other``'s."""
        if (self.chunks.shape != other.chunks.shape
                or self.chunk_words != other.chunk_words):
            raise ValueError(
                f"chunk layouts differ: {self.chunks.shape}x{self.chunk_words}"
                f" vs {other.chunks.shape}x{other.chunk_words}")
        return np.flatnonzero((self.chunks != other.chunks).any(axis=1))


@dataclasses.dataclass
class CacheStats:
    """Work accounting for one :meth:`DigestCache.digests` pass."""
    leaves: int = 0             # leaves examined
    clean_leaves: int = 0       # identity-hits: zero dispatch
    new_leaves: int = 0         # first sight / shape change: full dispatch
    chunks: int = 0             # chunks covered by the examined leaves
    dirty_chunks: int = 0       # chunks re-digested through the engine


@dataclasses.dataclass
class _Entry:
    leaf: object                # last-seen jax leaf (identity tier); None
                                # for host leaves — identity never trusts them
    words: jnp.ndarray          # its word stream (the comparison baseline)
    cd: ChunkedDigest


class DigestCache:
    """Tree-path-keyed digest cache: O(changed-chunks) re-verification.

    ``digests(tree)`` returns the same per-leaf digests as
    :func:`repro.core.verify.tree_digest` (bit-identical), dispatching the
    engine only for chunks whose words changed since the previous call.
    ``last`` holds the :class:`CacheStats` of the most recent pass.
    """

    def __init__(self, engine: _engine.CimEngine | None = None,
                 chunk_words: int | None = None,
                 digest_width: int = DIGEST_WIDTH, impl: str = "auto"):
        self.engine = engine if engine is not None \
            else _engine.CimEngine(impl=impl)
        self.digest_width = digest_width
        self.chunk_words = self.engine._chunk_words(chunk_words, digest_width)
        self._entries: dict[str, _Entry] = {}
        self.last = CacheStats()
        self.last_leaf_dirty: dict[str, int] = {}
        self.last_leaf_new: set[str] = set()
        self.observed_since_save: dict[str, int] = {}

    # -- bookkeeping ---------------------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    def chunk_digests(self, key: str) -> ChunkedDigest | None:
        """The cached digest matrix for one tree path (None if unseen)."""
        entry = self._entries.get(key)
        return entry.cd if entry else None

    def drop(self, key: str) -> None:
        self._entries.pop(key, None)
        self.observed_since_save.pop(key, None)

    def clear(self) -> None:
        self._entries.clear()
        self.observed_since_save.clear()

    # -- the incremental pass ------------------------------------------------

    def digests(self, tree):
        """Pytree -> same-structure pytree of (digest_width,) uint32 digests,
        re-digesting only chunks whose digest row changed.

        ``last_leaf_dirty`` afterwards maps each leaf key to the number of
        chunks the word-compare tier *observed* changing in this pass (0
        for identity hits, compare-clean leaves, and fresh entries); the
        same counts accumulate into ``observed_since_save`` until
        :meth:`mark_saved` clears them.  This is exact change evidence —
        ``save_delta`` consults the accumulated map so a changed leaf is
        stored even when its XOR-parity digest collides with the base's
        (an even number of flips per digest column cancels), *including*
        when the observing scrub pass happened earlier and the cache is
        already synced by the time save_delta re-digests.
        """
        flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
        stats = CacheStats()
        self.last_leaf_dirty = {}
        self.last_leaf_new = set()
        out = [self._leaf_digest(leaf_key(path), leaf, stats)
               for path, leaf in flat]
        self.last = stats
        for k, v in self.last_leaf_dirty.items():
            self.observed_since_save[k] = \
                self.observed_since_save.get(k, 0) + v
        return jax.tree_util.tree_unflatten(tdef, out)

    def mark_saved(self) -> None:
        """Forget the accumulated change evidence (``observed_since_save``)
        — called by ``save_delta`` after it durably consumed it."""
        self.observed_since_save.clear()

    def _leaf_digest(self, key: str, leaf, stats: CacheStats) -> np.ndarray:
        stats.leaves += 1
        entry = self._entries.get(key)
        if entry is not None and leaf is entry.leaf \
                and isinstance(leaf, jax.Array):
            # identity tier: jax arrays ONLY — they are immutable, so same
            # object means same bytes.  Any numpy leaf falls through to the
            # word-compare: writability flags can't be trusted (a read-only
            # view still aliases a writable base that may have mutated).
            stats.clean_leaves += 1
            stats.chunks += entry.cd.n_chunks
            return entry.cd.digest()

        words = _leaf_words(leaf)
        n = int(words.shape[0])
        chunk = self.chunk_words
        n_chunks = max(1, -(-n // chunk))
        stats.chunks += n_chunks

        if entry is None or entry.cd.nwords != n:
            # unseen path or re-layout: nothing to delta against — recorded
            # in last_leaf_new so consumers know no change/no-change claim
            # can be made about this leaf (save_delta stores such leaves)
            cd = ChunkedDigest.compute(words, self.engine, chunk,
                                       self.digest_width)
            stats.new_leaves += 1
            stats.dirty_chunks += cd.n_chunks
            self.last_leaf_new.add(key)
        else:
            dirty = _dirty_chunks(words, entry.words, chunk)
            rows = entry.cd.chunks.copy()
            # dispatch every dirty chunk before materializing any: jax
            # dispatch is async, so the k digests overlap on device instead
            # of k sequential dispatch-then-block round trips.
            pending = [(i, self.engine.digest(
                words[i * chunk:(i + 1) * chunk], self.digest_width))
                for i in dirty]
            for i, d in pending:
                rows[i] = np.asarray(d)
            stats.dirty_chunks += len(dirty)
            self.last_leaf_dirty[key] = len(dirty)
            cd = ChunkedDigest(rows, chunk, n, self.digest_width)

        # retain the leaf object only when identity can ever be trusted
        # (immutable jax arrays): pinning a numpy leaf would double the
        # documented one-copy memory cost for nothing.
        self._entries[key] = _Entry(
            leaf if isinstance(leaf, jax.Array) else None, words, cd)
        return cd.digest()


def _leaf_words(leaf) -> jnp.ndarray:
    """Byte-true uint32 word stream of any leaf.

    Host (numpy/scalar) leaves go through :func:`repro.core.verify.np_words`
    — the checkpoint layer's byte view, exact for 64-bit dtypes even when
    jax x64 is off (``jnp.asarray`` would silently downcast them and the
    cache's digests would disagree with the manifest's) — and are
    unconditionally snapshotted (copied): the stored comparison baseline
    must never alias host bytes that can mutate, and writability flags
    can't prove a buffer won't (a read-only view still aliases its base).
    jax arrays take the device view (:func:`repro.kernels.ops.as_words`);
    64-bit jax arrays only exist with x64 enabled, which ``as_words``
    handles.
    """
    if isinstance(leaf, jax.Array):
        return ops.as_words(leaf)
    words, _ = _verify.np_words(np.asarray(leaf))
    return jnp.asarray(words.copy())


def _dirty_chunks(new_words: jnp.ndarray, old_words: jnp.ndarray,
                  chunk: int) -> np.ndarray:
    """Chunk indices whose words differ — one fused elementwise compare (the
    in-memory XOR+zero-test), no digest dispatch."""
    n = new_words.shape[0]
    eq = new_words == old_words
    pad = (-n) % chunk
    if pad:
        eq = jnp.pad(eq, (0, pad), constant_values=True)
    mask = jnp.logical_not(jnp.all(eq.reshape(-1, chunk), axis=1))
    return np.flatnonzero(np.asarray(mask))
