"""XNOR-Net style binary layers (the paper's §VI application, as framework
first-class quantization).

Semantics follow XNOR-Net (Rastegari et al., ECCV'16, [34] in the paper):

  y = ( sign(x) . sign(W)^T ) * alpha_x * beta_w
      alpha_x = mean(|x|)  per input row (the paper's K map, collapsed to
                per-token for LM linears),
      beta_w  = mean(|W|)  per output channel.

Two execution modes:

* ``packed=False`` (training): float-domain straight-through-estimator —
  differentiable, used inside ``train_step``.  sign() forward, clipped
  identity backward (grads flow through alpha/beta exactly as in XNOR-Net).
* ``packed=True`` (inference): bit-plane domain — packs both operands and
  runs the XNOR-popcount GEMM kernel.  Bit-exact with the sign semantics of
  the float path.

Router/norm/embedding/lm-head layers are never binarized (XNOR-Net keeps
first/last layers full precision); `models/` enforces that policy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.kernels import ops


@jax.tree_util.register_pytree_node_class
class PackedLinear:
    """A binarized linear's resident serve form: sign bit-planes + scale.

    The float weight matrix is gone — only the packed planes (one bit per
    weight, the CiM array storing binary filters) and the per-output-channel
    XNOR-Net scale survive.  Leading axes are free (models stack per-layer
    weights on a leading axis and ``lax.scan`` slices it off).

      pb    (..., N, Kw) uint32 — sign planes of w.T, packed along K
      beta  (..., N)     f32    — mean(|w|) per output channel
      k     int                 — the true (unpacked) K, kept as static
                                  pytree aux data: the packed planes round K
                                  up to whole words, so shape alone cannot
                                  validate the activation width — dispatch
                                  checks ``x.shape[-1] == k`` instead of
                                  silently mis-correcting the popcount.
    """

    __slots__ = ("pb", "beta", "k")

    def __init__(self, pb, beta, k: int):
        self.pb, self.beta, self.k = pb, beta, k

    def tree_flatten(self):
        return (self.pb, self.beta), self.k

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    def __repr__(self):
        return (f"PackedLinear(pb={self.pb!r}, beta={self.beta!r}, "
                f"k={self.k})")


def xnor_linear(x: jnp.ndarray, w: jnp.ndarray, *, packed: bool = False,
                impl: str = "auto") -> jnp.ndarray:
    """Binary linear: x (..., K) @ w (N, K)^T -> (..., N).

    ``w`` is stored transposed relative to jnp.dot convention (rows are
    output channels) so both operands pack along their last axis.
    """
    n, k = w.shape
    beta = jnp.mean(jnp.abs(w), axis=-1)                      # (N,)
    if packed:
        lead = x.shape[:-1]
        x2 = x.reshape(-1, k)
        alpha = jnp.mean(jnp.abs(x2), axis=-1)                # (M,)
        pa, _ = ops.binarize(x2, impl=impl)
        pb, _ = ops.binarize(w, impl=impl)
        dots = ops.xnor_matmul(pa, pb, valid_k=k, impl=impl)  # (M, N) int32
        y = dots.astype(jnp.float32) * alpha[:, None] * beta[None, :]
        return y.reshape(*lead, n).astype(x.dtype)
    alpha = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)      # (..., 1)
    bx = bitpack.binarize_ste(x)
    bw = bitpack.binarize_ste(w)
    y = jnp.einsum("...k,nk->...n", bx, bw,
                   preferred_element_type=jnp.float32)
    return (y * alpha * beta).astype(x.dtype)


def xnor_linear_prepacked(x: jnp.ndarray, pb: jnp.ndarray, beta: jnp.ndarray,
                          valid_k: int, *, impl: str = "auto",
                          mode: str = "auto") -> jnp.ndarray:
    """Inference with weights already packed offline.

    ``pb``: (N, Kw) uint32, ``beta``: (N,) f32.  The weight matrix never
    exists in float form at serve time — a 16x memory-footprint reduction vs
    bf16 (the CiM array storing binary filters in the paper).

    ``mode`` (resolved by :func:`ops.fused_mode`) selects between the fused
    single-dispatch kernel (binarize + popcount GEMM + alpha/beta epilogue
    in one pass, DESIGN.md §18) and the unfused three-dispatch chain below —
    the fused path's bit-exact-twin reference on ref/interpret backends.
    """
    lead, k = x.shape[:-1], x.shape[-1]
    if k != valid_k:
        # a raise, not an assert: python -O would strip the assert and the
        # popcount correction below would silently be wrong whenever the
        # mismatched widths round to the same packed word count
        raise ValueError(
            f"activation width {k} != packed weight's true K {valid_k}")
    x2 = x.reshape(-1, k)
    if ops.fused_mode(mode) == "kernel":
        y = ops.xnor_linear_fused(x2, pb, beta, valid_k, impl=impl)
        return y.reshape(*lead, pb.shape[0]).astype(x.dtype)
    alpha = jnp.mean(jnp.abs(x2), axis=-1)
    pa, _ = ops.binarize(x2, impl=impl)
    dots = ops.xnor_matmul(pa, pb, valid_k=valid_k, impl=impl)
    y = dots.astype(jnp.float32) * alpha[:, None] * beta[None, :]
    return y.reshape(*lead, pb.shape[0]).astype(x.dtype)


def pack_weights(w: jnp.ndarray, impl: str = "auto"):
    """Offline weight packing: (N, K) float -> ((N, Kw) uint32, (N,) beta)."""
    pb, _ = ops.binarize(w, impl=impl)
    return pb, jnp.mean(jnp.abs(w), axis=-1).astype(jnp.float32)


def pack_linear(w: jnp.ndarray, impl: str = "auto") -> PackedLinear:
    """Pack a model-layout linear weight (possibly layer-stacked).

    ``w``: (..., K, N) in the ``jnp.dot`` convention used by
    :func:`repro.models.layers.linear` (columns are output channels); any
    leading axes are mapped over, so a scanned segment's stacked
    (n_layers, K, N) weight packs to ``PackedLinear((n, N, Kw), (n, N))``.
    """
    if w.ndim < 2:
        raise ValueError(f"pack_linear needs a (..., K, N) matrix, got {w.shape}")
    k = w.shape[-2]
    fn = lambda wi: PackedLinear(*pack_weights(wi.T, impl=impl), k=k)
    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    return fn(w)
