"""Application-level models: Table I latency and Fig. 6 XNOR-Net speedup.

* :func:`xnornet_speedup` — the paper's Eq. (1):
      S = c*N_W*N_I / (c*N_W*N_I / N_O + N_I)
  (c channels, N_W filter h*w, N_I input h*w, N_O XNOR ops per cycle).
  The paper evaluates c=256, N_W=14^2, N_I=3^2 "common in ResNet"; the
  physically conventional reading is N_W=3^2 (filter), N_I=14^2 (map) —
  the curve shape is identical (S -> N_O as c*N_W grows), we expose both.

* :func:`design_cycles` — Table I as a cycle model: bulk ops of n_bits on a
  CiM array of row width W cost latency_cycles * ceil(n_bits / W).

* :func:`tpu_n_o` — this framework's N_O on TPU v5e: packed uint32 lanes on
  the VPU (8 sublanes x 128 lanes x 32 bits = 32768 bit-XNORs per VPU op),
  the quantity to plug into Eq. (1) for the adapted design.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

# Table I of the paper: (technology, extra transistors, latency cycles)
TABLE_I = {
    "pinatubo":        ("CMOS", 7, 3),
    "felix":           ("Crossbar", None, 3),
    "cmos_memristive": ("CMOS", 16, 2),
    "xorim":           ("CMOS", 12, 3),
    "sixor":           ("Memristor", None, 1),
    "this_work":       ("CMOS", 13, 1),
}


def xnornet_speedup(n_o, c: int = 256, n_w: int = 14 ** 2, n_i: int = 3 ** 2):
    """Paper Eq. (1). Ideal limit: S -> N_O / (1 + N_O/(c*N_W))."""
    n_o = jnp.asarray(n_o, jnp.float32)
    num = c * n_w * n_i
    return num / (num / n_o + n_i)


def xornet_speedup(n_o, c: int = 256, n_w: int = 14 ** 2, n_i: int = 3 ** 2,
                   fp_reduction: float = 0.3984):
    """XOR-Net variant ([36]): 39.84% fewer full-precision ops per layer."""
    n_o = jnp.asarray(n_o, jnp.float32)
    num = c * n_w * n_i
    return num / (num / n_o + (1.0 - fp_reduction) * n_i)


def design_cycles(design: str, n_bits: int, row_width: int = 512) -> int:
    """Total cycles for a bulk bitwise op of n_bits on a given design."""
    _, _, lat = TABLE_I[design]
    return lat * -(-n_bits // row_width)


def copy_verify_cycles(rows: int, design: str = "this_work") -> int:
    """Paper §II system view: duplicating `rows` unique rows in a 2*rows bank.

    2 activation cycles per copied row + one XOR stream per row for
    verification (XOR stream latency depends on the design).
    """
    _, _, lat = TABLE_I[design]
    return rows * 2 + rows * lat


class TpuBitEngine(NamedTuple):
    sublanes: int = 8
    lanes: int = 128
    word_bits: int = 32
    vpu_issue: int = 4      # VPU ops/cycle (4 ALUs per port group, v5e-class)

    @property
    def n_o(self) -> int:
        """Bit-XNORs per TPU core cycle for packed operands."""
        return self.sublanes * self.lanes * self.word_bits * self.vpu_issue


def tpu_n_o() -> int:
    return TpuBitEngine().n_o
