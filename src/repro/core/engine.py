"""Banked batched CiM engine — the scheduler over the single-cycle primitive.

The paper's array computes ONE row-pair XOR/XNOR per sense cycle, but a
deployment tiles many independent arrays (banks) behind one controller:
every cycle, every bank senses one row-pair across its full row width, so
throughput is ``banks * cols`` bit-ops/cycle (DESIGN.md §10; the same
array-level parallelism X-SRAM and the in-DRAM X(N)OR designs lean on).

:class:`CimEngine` is that controller at framework scale.  It exposes two
coupled views of the same machine:

* **engine path** — bit-packed uint32 buffers (:mod:`repro.core.bitpack`
  layout) dispatched through the three-path kernel layer
  (:func:`repro.kernels.ops.bulk_op` / ``digest`` / ``stream_cipher``),
  with *cycle accounting* under the bank model: production throughput.
* **circuit path** (:meth:`simulate`) — the same schedule mapped onto a
  banked :class:`repro.core.cim.ArrayState` and computed through the analog
  SL-current model, one traced call for banks x pairs x cols bit-ops:
  the faithful cross-check the tests pin the engine against.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import bitpack, cim
from repro.kernels import ops


class BankGeometry(NamedTuple):
    """Geometry of the bank stack: ``banks`` arrays of rows x cols cells."""
    banks: int = 8
    rows: int = 512       # paper §V: 512 rows supported at nominal HRS/LRS
    cols: int = 4096      # bits per row (= 128 uint32 words)

    @property
    def words_per_row(self) -> int:
        return bitpack.packed_width(self.cols)

    @property
    def bits_per_cycle(self) -> int:
        """One row-wide op per bank per cycle."""
        return self.banks * self.cols


@dataclasses.dataclass
class EngineStats:
    """Cycle/op counters accumulated across engine calls."""
    cycles: int = 0
    bit_ops: int = 0
    calls: int = 0

    def account(self, cycles: int, bit_ops: int) -> None:
        self.cycles += cycles
        self.bit_ops += bit_ops
        self.calls += 1

    @property
    def ops_per_cycle(self) -> float:
        return self.bit_ops / self.cycles if self.cycles else 0.0


class CimEngine:
    """Schedules arbitrarily large packed buffers onto the bank stack.

    ``impl`` selects the kernel path (ref/interpret/pallas/auto) for every
    dispatched op, same semantics as :mod:`repro.kernels.ops`.
    """

    def __init__(self, geometry: BankGeometry = BankGeometry(),
                 impl: str = "auto"):
        self.geometry = geometry
        self.impl = impl
        self.stats = EngineStats()

    # -- schedule model ------------------------------------------------------

    def cycles_for(self, nbits: int) -> int:
        """Sense cycles to stream ``nbits`` bit-ops through the bank stack."""
        return -(-nbits // self.geometry.bits_per_cycle)

    def _account(self, *buffers: jnp.ndarray) -> None:
        nbits = max(b.size * b.dtype.itemsize * 8 for b in buffers)
        self.stats.account(self.cycles_for(nbits), nbits)

    # -- engine path: packed uint32 buffers ----------------------------------

    def xor(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Bulk XOR of two same-shape uint32 buffers (one pass)."""
        out = ops.bulk_op(a, b, "xor", impl=self.impl)
        self._account(a)  # after dispatch: failed calls don't skew stats
        return out

    def xnor(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Bulk XNOR — complementary rail, same cycle count."""
        out = ops.bulk_op(a, b, "xnor", impl=self.impl)
        self._account(a)
        return out

    def digest(self, buf: jnp.ndarray, digest_width: int = 128) -> jnp.ndarray:
        """XOR-parity digest routed through the bank stack.

        Folding is XOR of successive row-groups, so the cycle model is the
        same one-op-per-bit stream as :meth:`xor`.
        """
        out = ops.digest(buf, digest_width, impl=self.impl)
        self._account(buf)
        return out

    def verify_copy(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Paper Fig. 1(a): XOR source against copy, all-zero means intact."""
        return jnp.logical_not(jnp.any(self.xor(a, b)))

    def stream_cipher(self, buf: jnp.ndarray, key: jnp.ndarray,
                      counter: int = 0) -> jnp.ndarray:
        """Paper Fig. 1(b): counter-mode XOR pad over the bank stack."""
        out = ops.stream_cipher(buf, key, counter=counter, impl=self.impl)
        self._account(buf)
        return out

    # -- circuit path: the analog model, banked ------------------------------

    def simulate(self, bits_a: jnp.ndarray, bits_b: jnp.ndarray,
                 op: str = "xor") -> jnp.ndarray:
        """Run N row-pairs through the *analog* banked array model.

        ``bits_a``/``bits_b``: (N, C) 0/1 operand rows, C <= geometry.cols.
        Pair ``j`` is programmed into bank ``j // P`` (P = ceil(N/banks))
        as rows (2p, 2p+1); one banked :func:`repro.core.cim.compute` call
        then senses all banks x P pairs — P sense cycles on real hardware,
        one traced call here.  Returns (N, C) bool.
        """
        bits_a, bits_b = jnp.asarray(bits_a), jnp.asarray(bits_b)
        n, c = bits_a.shape
        if bits_b.shape != (n, c):
            raise ValueError(f"operand shapes differ: {bits_a.shape} vs "
                             f"{bits_b.shape}")
        if c > self.geometry.cols:
            raise ValueError(f"{c} cols exceed bank width {self.geometry.cols}")
        banks = self.geometry.banks
        pairs = -(-n // banks)
        if 2 * pairs > self.geometry.rows:
            raise ValueError(f"{n} pairs need {2 * pairs} rows/bank, "
                             f"bank has {self.geometry.rows}")
        pad = banks * pairs - n
        bits_a = jnp.pad(bits_a, ((0, pad), (0, 0)))
        bits_b = jnp.pad(bits_b, ((0, pad), (0, 0)))
        # (banks, pairs, 2, C) -> interleave operand rows -> (banks, 2P, C)
        stacked = jnp.stack([bits_a, bits_b], axis=1)      # (B*P, 2, C)
        cells = stacked.reshape(banks, pairs, 2, c).reshape(banks, 2 * pairs, c)
        state = cim.make_array(cells)
        row_a = 2 * jnp.arange(pairs)
        out = cim.compute(state, row_a, row_a + 1, op)     # (banks, P, C)
        self.stats.account(pairs, n * c)
        return out.reshape(banks * pairs, c)[:n]
