"""Banked batched CiM engine — the scheduler over the single-cycle primitive.

The paper's array computes ONE row-pair XOR/XNOR per sense cycle, but a
deployment tiles many independent arrays (banks) behind one controller:
every cycle, every bank senses one row-pair across its full row width, so
throughput is ``banks * cols`` bit-ops/cycle (DESIGN.md §10; the same
array-level parallelism X-SRAM and the in-DRAM X(N)OR designs lean on).

:class:`CimEngine` is that controller at framework scale.  It exposes two
coupled views of the same machine:

* **engine path** — bit-packed uint32 buffers (:mod:`repro.core.bitpack`
  layout) dispatched through the three-path kernel layer
  (:func:`repro.kernels.ops.bulk_op` / ``digest`` / ``stream_cipher``),
  with *cycle accounting* under the bank model: production throughput.
* **circuit path** (:meth:`simulate`) — the same schedule mapped onto a
  banked :class:`repro.core.cim.ArrayState` and computed through the analog
  SL-current model, one traced call for banks x pairs x cols bit-ops:
  the faithful cross-check the tests pin the engine against.

:class:`ShardedCimEngine` extends the controller across a device mesh
(DESIGN.md §11): the mesh axis is the outermost bank dimension, buffers are
partitioned on their leading word axis, and throughput becomes
``devices * banks * cols`` bit-ops/cycle.  Results are bit-identical to the
single-device engine path; for digests the per-device 512-byte partial
digests are the only cross-device traffic — the buffer never moves.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import bitpack, cim
from repro.kernels import ops


def _under_trace(operands) -> bool:
    """True when the caller is being traced (jit/vmap/...).

    ``trace_state_clean`` is the precise check but lives in private jax
    namespaces that move across releases; try its known homes, then fall
    back to sniffing the operands for tracers.  The fallback misses ops
    traced purely through closed-over constants — those account once at
    trace time, which is also what the constant-folded op costs.
    """
    try:
        return not jax.core.trace_state_clean()
    except AttributeError:
        pass
    try:
        from jax._src import core as _src_core
        return not _src_core.trace_state_clean()
    except Exception:
        pass
    try:
        return any(isinstance(b, jax.core.Tracer) for b in operands)
    except AttributeError:
        return False


class BankGeometry(NamedTuple):
    """Geometry of the bank stack: ``banks`` arrays of rows x cols cells.

    ``devices`` is the outermost tier — the number of mesh devices the stack
    is replicated across (1 for the single-device engine; the sharded engine
    sets it from the mesh axis size, DESIGN.md §11).
    """
    banks: int = 8
    rows: int = 512       # paper §V: 512 rows supported at nominal HRS/LRS
    cols: int = 4096      # bits per row (= 128 uint32 words)
    devices: int = 1      # mesh devices (outer bank tier)

    @property
    def words_per_row(self) -> int:
        return bitpack.packed_width(self.cols)

    @property
    def bits_per_cycle(self) -> int:
        """One row-wide op per bank per device per cycle."""
        return self.devices * self.banks * self.cols

    @property
    def pass_words(self) -> int:
        """uint32 words one full pass over every row of every bank senses."""
        return self.devices * self.banks * self.rows * self.words_per_row


@dataclasses.dataclass
class EngineStats:
    """Cycle/op counters accumulated across engine calls.

    ``by_op`` breaks the same totals down per op kind ("xor", "digest",
    "cipher", ...) so consumers like the incremental verifier can assert
    *which* traffic a code path generated; :meth:`snapshot` captures the
    counters so a later ``stats.cycles - snap.cycles`` measures exactly one
    region (the incremental tests pin O(dirty-chunks) dispatch this way).
    """
    cycles: int = 0
    bit_ops: int = 0
    calls: int = 0
    by_op: dict = dataclasses.field(default_factory=dict)

    def account(self, cycles: int, bit_ops: int, op: str = "bulk") -> None:
        self.cycles += cycles
        self.bit_ops += bit_ops
        self.calls += 1
        per = self.by_op.setdefault(op, [0, 0, 0])
        per[0] += cycles
        per[1] += bit_ops
        per[2] += 1

    def snapshot(self) -> "EngineStats":
        """Frozen copy of the counters (deep-copies ``by_op``)."""
        return dataclasses.replace(
            self, by_op={k: list(v) for k, v in self.by_op.items()})

    @property
    def ops_per_cycle(self) -> float:
        return self.bit_ops / self.cycles if self.cycles else 0.0


class CimEngine:
    """Schedules arbitrarily large packed buffers onto the bank stack.

    ``impl`` selects the kernel path (ref/interpret/pallas/auto) for every
    dispatched op, same semantics as :mod:`repro.kernels.ops`.
    """

    def __init__(self, geometry: BankGeometry = BankGeometry(),
                 impl: str = "auto"):
        self.geometry = geometry
        self.impl = impl
        self.stats = EngineStats()

    # -- schedule model ------------------------------------------------------

    def cycles_for(self, nbits: int) -> int:
        """Sense cycles to stream ``nbits`` bit-ops through the bank stack."""
        return -(-nbits // self.geometry.bits_per_cycle)

    def _account_raw(self, cycles: int, bit_ops: int,
                     *operands: jnp.ndarray, op: str = "bulk") -> None:
        """Record stats exactly once per *execution*, not per trace.

        Cycle/op counts derive from static shapes, so they are known at
        trace time — but mutating ``self.stats`` inside a traced function
        would record once per trace instead of once per call.  Under a
        trace, stage a host callback that fires on every execution of the
        compiled function instead (call :func:`jax.effects_barrier` before
        reading stats that jitted calls produced).
        """
        if _under_trace(operands):
            jax.debug.callback(
                lambda: self.stats.account(cycles, bit_ops, op))
        else:
            self.stats.account(cycles, bit_ops, op)

    def _account(self, *buffers: jnp.ndarray, op: str = "bulk") -> None:
        nbits = max(b.size * b.dtype.itemsize * 8 for b in buffers)
        self._account_raw(self.cycles_for(nbits), nbits, *buffers, op=op)

    # -- engine path: packed uint32 buffers ----------------------------------

    def xor(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Bulk XOR of two same-shape uint32 buffers (one pass)."""
        out = ops.bulk_op(a, b, "xor", impl=self.impl)
        self._account(a, op="xor")  # after dispatch: failures don't skew stats
        return out

    def xnor(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Bulk XNOR — complementary rail, same cycle count."""
        out = ops.bulk_op(a, b, "xnor", impl=self.impl)
        self._account(a, op="xnor")
        return out

    def digest(self, buf: jnp.ndarray, digest_width: int = 128) -> jnp.ndarray:
        """XOR-parity digest routed through the bank stack.

        Folding is XOR of successive row-groups, so the cycle model is the
        same one-op-per-bit stream as :meth:`xor`.
        """
        out = ops.digest(buf, digest_width, impl=self.impl)
        self._account(buf, op="digest")
        return out

    def verify_copy(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Paper Fig. 1(a): XOR source against copy, all-zero means intact.

        Accepts any same-shape/dtype buffer pair — operands are viewed as
        the canonical uint32 word stream (:func:`repro.kernels.ops.as_words`)
        before the bulk XOR, which is uint32-only.  Host numpy operands are
        inspected before any jax conversion, so 64-bit buffers compare
        byte-true even with x64 off (``jnp.asarray`` would downcast them
        and a corruption in the dropped bytes would read as intact).
        """
        if not isinstance(a, jax.Array):
            a = np.asarray(a)
        if not isinstance(b, jax.Array):
            b = np.asarray(b)
        if a.shape != b.shape or a.dtype != b.dtype:
            raise ValueError(
                "verify_copy compares a buffer against its copy: operands "
                f"must share shape/dtype, got {a.shape}/{a.dtype} vs "
                f"{b.shape}/{b.dtype}")
        return jnp.logical_not(jnp.any(self.xor(ops.as_words(a),
                                                ops.as_words(b))))

    def stream_cipher(self, buf: jnp.ndarray, key: jnp.ndarray,
                      counter: int = 0) -> jnp.ndarray:
        """Paper Fig. 1(b): counter-mode XOR pad over the bank stack."""
        out = ops.stream_cipher(buf, key, counter=counter, impl=self.impl)
        self._account(buf, op="cipher")
        return out

    # -- chunked streaming: buffers larger than one bank pass -----------------

    def _chunk_words(self, chunk_words: int | None, align: int) -> int:
        """Resolve the streaming chunk: default one bank pass, ``align``ed up."""
        chunk = chunk_words if chunk_words else self.geometry.pass_words
        return -(-chunk // align) * align

    def xor_stream(self, a: jnp.ndarray, b: jnp.ndarray,
                   chunk_words: int | None = None) -> jnp.ndarray:
        """:meth:`xor`, iterated over fixed-size chunks of the word stream.

        Bit-identical to one-shot :meth:`xor` for any chunk size (XOR is
        elementwise); the default chunk is one bank pass
        (``geometry.pass_words``), bounding peak kernel footprint.
        """
        return self._bulk_stream(a, b, "xor", chunk_words)

    def xnor_stream(self, a: jnp.ndarray, b: jnp.ndarray,
                    chunk_words: int | None = None) -> jnp.ndarray:
        """Chunked :meth:`xnor` — complementary rail of :meth:`xor_stream`."""
        return self._bulk_stream(a, b, "xnor", chunk_words)

    def _bulk_stream(self, a, b, op, chunk_words):
        if a.shape != b.shape:
            raise ValueError(f"operand shapes differ: {a.shape} vs {b.shape}")
        bulk = self.xor if op == "xor" else self.xnor
        chunk = self._chunk_words(chunk_words, 128)
        wa, wb = a.reshape(-1), b.reshape(-1)
        n = wa.shape[0]
        if n <= chunk:
            return bulk(a, b)
        outs = [bulk(wa[i:i + chunk], wb[i:i + chunk])
                for i in range(0, n, chunk)]
        return jnp.concatenate(outs).reshape(a.shape)

    def digest_stream(self, buf: jnp.ndarray, digest_width: int = 128,
                      chunk_words: int | None = None) -> jnp.ndarray:
        """Chunked :meth:`digest`, bit-identical to the one-shot digest.

        The chunk is aligned up to a multiple of ``digest_width`` so every
        chunk covers whole digest rows; XOR-folding the per-chunk digests
        then equals the digest of the whole stream (the tail chunk's zero
        padding is XOR-neutral).
        """
        words = ops.as_words(buf)
        chunk = self._chunk_words(chunk_words, digest_width)
        n = words.shape[0]
        if n <= chunk:
            return self.digest(buf if buf.dtype == jnp.uint32 else words,
                               digest_width)
        dig = self.digest(words[:chunk], digest_width)
        for i in range(chunk, n, chunk):
            dig = dig ^ self.digest(words[i:i + chunk], digest_width)
        return dig

    def digest_chunks(self, buf: jnp.ndarray, chunk_words: int | None = None,
                      digest_width: int = 128) -> jnp.ndarray:
        """Chunk-level digest export: one digest row per ``chunk_words`` slab.

        Returns a ``(n_chunks, digest_width)`` uint32 matrix — row ``i``
        equals :meth:`digest` of words ``[i*chunk, (i+1)*chunk)`` of the
        stream (bit-exactly; XOR is exact in uint32).  Chunks are aligned
        to whole digest rows (same rule as :meth:`digest_stream`), so
        XOR-folding the matrix rows equals the one-shot digest of the
        whole buffer.  The full matrix is ONE fused device fold (priming a
        :class:`repro.core.incremental.DigestCache` over thousands of
        chunks must not pay per-chunk dispatch overhead); the incremental
        verifier's dirty-chunk *re*-digests go through :meth:`digest` per
        chunk, which is what makes its traffic O(dirty).  Cycle accounting
        is the same one-op-per-bit stream either way.
        """
        words = ops.as_words(buf)
        chunk = self._chunk_words(chunk_words, digest_width)
        n = words.shape[0]
        n_chunks = max(1, -(-n // chunk))
        if n_chunks == 1:
            return jnp.stack([self.digest(words, digest_width)])
        w2 = jnp.pad(words, (0, n_chunks * chunk - n))  # zeros: XOR-neutral
        m = w2.reshape(n_chunks, chunk // digest_width, digest_width)
        out = jax.lax.reduce(m, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        self._account(words, op="digest")
        return out

    # -- circuit path: the analog model, banked ------------------------------

    def simulate(self, bits_a: jnp.ndarray, bits_b: jnp.ndarray,
                 op: str = "xor") -> jnp.ndarray:
        """Run N row-pairs through the *analog* banked array model.

        ``bits_a``/``bits_b``: (N, C) 0/1 operand rows, C <= geometry.cols.
        Pair ``j`` is programmed into bank ``j // P`` (P = ceil(N/banks))
        as rows (2p, 2p+1); one banked :func:`repro.core.cim.compute` call
        then senses all banks x P pairs — P sense cycles on real hardware,
        one traced call here.  Returns (N, C) bool.
        """
        bits_a, bits_b = jnp.asarray(bits_a), jnp.asarray(bits_b)
        n, c = bits_a.shape
        if bits_b.shape != (n, c):
            raise ValueError(f"operand shapes differ: {bits_a.shape} vs "
                             f"{bits_b.shape}")
        if c > self.geometry.cols:
            raise ValueError(f"{c} cols exceed bank width {self.geometry.cols}")
        banks = self.geometry.banks
        pairs = -(-n // banks)
        if 2 * pairs > self.geometry.rows:
            raise ValueError(f"{n} pairs need {2 * pairs} rows/bank, "
                             f"bank has {self.geometry.rows}")
        pad = banks * pairs - n
        bits_a = jnp.pad(bits_a, ((0, pad), (0, 0)))
        bits_b = jnp.pad(bits_b, ((0, pad), (0, 0)))
        # (banks, pairs, 2, C) -> interleave operand rows -> (banks, 2P, C)
        stacked = jnp.stack([bits_a, bits_b], axis=1)      # (B*P, 2, C)
        cells = stacked.reshape(banks, pairs, 2, c).reshape(banks, 2 * pairs, c)
        state = cim.make_array(cells)
        row_a = 2 * jnp.arange(pairs)
        out = cim.compute(state, row_a, row_a + 1, op)     # (banks, P, C)
        self._account_raw(pairs, n * c, bits_a, op="simulate")
        return out.reshape(banks * pairs, c)[:n]


class ShardedCimEngine(CimEngine):
    """The bank stack sharded across a device mesh (DESIGN.md §11).

    The mesh axis is the *outermost bank dimension*: a buffer's flat word
    stream is split into ``devices`` contiguous chunks, each chunk scheduled
    onto that device's local bank stack, so throughput scales to
    ``devices * banks * cols`` bit-ops/cycle.

    * :meth:`xor`/:meth:`xnor`/:meth:`stream_cipher` stay fully partitioned
      (the output keeps the input's leading-axis sharding; zero cross-device
      traffic — the cipher regenerates its keystream locally from the
      device's global word offset);
    * :meth:`digest` XOR-reduces the per-device partial digests (all-gather
      + local pairwise fold), so the ``digest_width``-word digests (512
      bytes each at the default width) are the only collective payload —
      the whole point of digesting before comparing;
    * every result is bit-identical to the single-device
      :class:`CimEngine` path (pinned by ``tests/test_sharded_engine.py``
      and the 8-way property sweep in ``tests/test_distributed.py``).

    ``axis`` defaults to the mesh's first axis; pass any axis of a larger
    (pod, data, model) production mesh to dedicate it to engine traffic.
    """

    def __init__(self, mesh: Mesh, axis: str | None = None,
                 geometry: BankGeometry = BankGeometry(), impl: str = "auto"):
        axis = axis if axis is not None else mesh.axis_names[0]
        if axis not in mesh.axis_names:
            raise ValueError(f"axis {axis!r} not in mesh axes {mesh.axis_names}")
        super().__init__(geometry._replace(devices=int(mesh.shape[axis])),
                         impl)
        self.mesh = mesh
        self.axis = axis
        self._fns: dict = {}

    # -- sharded dispatch -----------------------------------------------------

    def _shard_words(self, words: jnp.ndarray, align: int = 128):
        """Pad the flat word stream and fold it to (devices, per_device).

        ``per_device`` is aligned to ``align`` words (the kernel tile width,
        and the digest width for digests) so per-device row blocks line up
        with the unsharded layout; chunks are contiguous, so device ``d``
        holds global words ``[d*per, (d+1)*per)`` — the slice the cipher's
        counter offset and the output un-pad below rely on.
        """
        n = words.shape[0]
        dev = self.geometry.devices
        per = -(-max(n, 1) // (dev * align)) * align
        w2 = jnp.pad(words, (0, dev * per - n)).reshape(dev, per)
        return w2, n

    def _sharded(self, key, build):
        """Cache shard_map-wrapped jitted callables per (op, static args)."""
        if key not in self._fns:
            self._fns[key] = jax.jit(build())
        return self._fns[key]

    def _build_bulk(self, op):
        from repro.distributed import sharding
        ax, impl = self.axis, self.impl

        def f(x, y):
            return ops.bulk_op(x, y, op, impl=impl)

        return sharding.shard_map(f, self.mesh, in_specs=(P(ax), P(ax)),
                                  out_specs=P(ax), manual_axes={ax})

    def _build_digest(self, digest_width):
        from repro.distributed import sharding
        ax, impl = self.axis, self.impl

        def f(x):  # x: (1, per) — this device's contiguous word chunk
            part = ops.digest(x, digest_width, impl=impl)
            return sharding.pxor(part, ax)  # 512B digest = all the traffic

        return sharding.shard_map(f, self.mesh, in_specs=(P(ax),),
                                  out_specs=P(), manual_axes={ax})

    def _build_cipher(self):
        from repro.distributed import sharding
        ax, impl = self.axis, self.impl

        def f(x, k3):  # x: (1, per); keystream index = global word position
            per = jnp.uint32(x.size)
            ctr = k3[2] + jax.lax.axis_index(ax).astype(jnp.uint32) * per
            out = ops.stream_cipher(x.reshape(-1), k3[:2], counter=ctr,
                                    impl=impl)
            return out.reshape(x.shape)

        return sharding.shard_map(f, self.mesh, in_specs=(P(ax), P()),
                                  out_specs=P(ax), manual_axes={ax})

    # -- engine path, sharded -------------------------------------------------

    def _bulk(self, a, b, op):
        if a.dtype != jnp.uint32 or b.dtype != jnp.uint32:
            raise TypeError(f"bulk {op} needs uint32, got {a.dtype}/{b.dtype}")
        if a.shape != b.shape:
            raise ValueError(f"operand shapes differ: {a.shape} vs {b.shape}")
        wa, n = self._shard_words(a.reshape(-1))
        wb, _ = self._shard_words(b.reshape(-1))
        out = self._sharded(op, lambda: self._build_bulk(op))(wa, wb)
        self._account(a, op=op)
        return out.reshape(-1)[:n].reshape(a.shape)

    def xor(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        return self._bulk(a, b, "xor")

    def xnor(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        return self._bulk(a, b, "xnor")

    def digest(self, buf: jnp.ndarray, digest_width: int = 128) -> jnp.ndarray:
        words = ops.as_words(buf)
        # align per-device chunks to whole digest rows AND the kernel tile
        # width, so the global row partition matches the unsharded fold.
        w2, _ = self._shard_words(words, math.lcm(128, digest_width))
        out = self._sharded(("digest", digest_width),
                            lambda: self._build_digest(digest_width))(w2)
        self._account(buf, op="digest")
        return out

    def digest_chunks(self, buf: jnp.ndarray, chunk_words: int | None = None,
                      digest_width: int = 128) -> jnp.ndarray:
        """Per-chunk *sharded* dispatch: each row folds across the mesh, so
        only 512-byte partials cross devices — the single-device fused fold
        would pull the whole buffer onto one device instead."""
        words = ops.as_words(buf)
        chunk = self._chunk_words(chunk_words, digest_width)
        n = words.shape[0]
        rows = [self.digest(words[i:i + chunk], digest_width)
                for i in range(0, max(n, 1), chunk)]
        return jnp.stack(rows)

    def stream_cipher(self, buf: jnp.ndarray, key: jnp.ndarray,
                      counter: int = 0) -> jnp.ndarray:
        if buf.dtype != jnp.uint32:
            raise TypeError(f"stream_cipher needs uint32, got {buf.dtype}")
        w2, n = self._shard_words(buf.reshape(-1))
        k3 = jnp.stack([jnp.asarray(key[0], jnp.uint32),
                        jnp.asarray(key[1], jnp.uint32),
                        jnp.asarray(counter, jnp.uint32)])
        out = self._sharded("cipher", self._build_cipher)(w2, k3)
        self._account(buf, op="cipher")
        return out.reshape(-1)[:n].reshape(buf.shape)
