"""Bulk copy verification — the paper's Fig. 1(a) application, at framework
scale.

The paper XORs a copied row against its source in one cycle; a zero result
verifies the copy.  Our framework-scale equivalents:

* :func:`tree_digest` — per-leaf XOR-parity digests of a parameter pytree
  (jit-able; under pjit the fold runs sharded and the 512-byte digest is the
  only cross-device traffic, which is the whole point of digesting).
* :func:`verify_trees` — compare two pytrees leaf-by-leaf by digest.
* :func:`np_digest` — numpy twin used by the checkpoint layer on the host
  I/O path (bit-identical to the jax fold for uint32 streams).

Device-side digests route through the banked :class:`repro.core.engine
.CimEngine` (cycle-accounted bank schedule, DESIGN.md §10); pass ``engine=``
to share one engine's stats across calls, or ``impl=`` to hit the kernel
layer directly with a throwaway default engine.  A mesh-aware
:class:`repro.core.engine.ShardedCimEngine` drops in unchanged (DESIGN.md
§11): each leaf's fold then runs sharded and only the per-leaf 512-byte
digest crosses devices.  ``chunk_words=`` streams leaves larger than one
bank pass through the engine's chunked mode.

Any single-bit corruption flips exactly one digest bit (XOR linearity), so
digest equality is a true parity check, not a heuristic hash.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as _engine

DIGEST_WIDTH = 128  # uint32 words = 512 bytes


def tree_digest(tree, impl: str = "auto",
                engine: _engine.CimEngine | None = None,
                chunk_words: int | None = None):
    """Pytree -> same-structure pytree of (DIGEST_WIDTH,) uint32 digests.

    ``engine`` may be a single-device :class:`~repro.core.engine.CimEngine`
    or a mesh-aware :class:`~repro.core.engine.ShardedCimEngine` — digests
    are bit-identical either way.  ``chunk_words`` bounds the per-dispatch
    footprint via :meth:`~repro.core.engine.CimEngine.digest_stream`.
    """
    eng = engine if engine is not None else _engine.CimEngine(impl=impl)
    if chunk_words is None:
        fn = lambda x: eng.digest(x, DIGEST_WIDTH)
    else:
        fn = lambda x: eng.digest_stream(x, DIGEST_WIDTH,
                                         chunk_words=chunk_words)
    return jax.tree.map(fn, tree)


def verify_trees(a, b, impl: str = "auto",
                 engine: _engine.CimEngine | None = None,
                 chunk_words: int | None = None):
    """Returns (all_ok: bool array, per-leaf ok pytree) comparing digests."""
    da = tree_digest(a, impl, engine=engine, chunk_words=chunk_words)
    db = tree_digest(b, impl, engine=engine, chunk_words=chunk_words)
    leaf_ok = jax.tree.map(lambda x, y: jnp.all(x == y), da, db)
    return jnp.all(jnp.stack(jax.tree.leaves(leaf_ok))), leaf_ok


def np_words(arr: np.ndarray, align: int = 4):
    """View any numpy array's bytes as the little-endian uint32 stream every
    host-side digest/cipher shares, zero-padding the tail to ``align`` bytes.

    Returns ``(words, nbytes)`` — the uint32 view and the original byte
    length.  This is the single definition of the host byte layout; the
    device twins (:func:`np_digest_via_device`,
    :func:`repro.core.encrypt.encrypt_np_via_device`) route the same words
    through the engine, which is what makes the two paths bit-compatible.
    """
    raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    nbytes = raw.size
    pad = (-nbytes) % align
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
    return raw.view(np.uint32), nbytes


def np_digest(arr: np.ndarray, digest_width: int = DIGEST_WIDTH) -> np.ndarray:
    """Host-side digest of any numpy array (byte view -> uint32 stream)."""
    words, _ = np_words(arr, align=4 * digest_width)
    return np.bitwise_xor.reduce(words.reshape(-1, digest_width), axis=0)


def np_verify(a: np.ndarray, b: np.ndarray) -> bool:
    return bool(np.array_equal(np_digest(a), np_digest(b)))


def np_digest_via_device(arr: np.ndarray, engine: _engine.CimEngine,
                         digest_width: int = DIGEST_WIDTH) -> np.ndarray:
    """Device-routed twin of :func:`np_digest` (bit-identical).

    Views the host array's bytes as the same little-endian uint32 stream
    :func:`np_digest` folds, then folds it on device through ``engine`` —
    so the checkpoint layer can burn digests on the bank stack (sharded or
    not) while staying byte-compatible with manifests written by the host
    path.
    """
    words, _ = np_words(arr)
    return np.asarray(engine.digest(jnp.asarray(words), digest_width))
