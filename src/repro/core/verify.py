"""Bulk copy verification — the paper's Fig. 1(a) application, at framework
scale.

The paper XORs a copied row against its source in one cycle; a zero result
verifies the copy.  Our framework-scale equivalents:

* :func:`tree_digest` — per-leaf XOR-parity digests of a parameter pytree
  (jit-able; under pjit the fold runs sharded and the 512-byte digest is the
  only cross-device traffic, which is the whole point of digesting).
* :func:`verify_trees` — compare two pytrees leaf-by-leaf by digest.
* :func:`np_digest` — numpy twin used by the checkpoint layer on the host
  I/O path (bit-identical to the jax fold for uint32 streams).

Device-side digests route through the banked :class:`repro.core.engine
.CimEngine` (cycle-accounted bank schedule, DESIGN.md §10); pass ``engine=``
to share one engine's stats across calls, or ``impl=`` to hit the kernel
layer directly with a throwaway default engine.  A mesh-aware
:class:`repro.core.engine.ShardedCimEngine` drops in unchanged (DESIGN.md
§11): each leaf's fold then runs sharded and only the per-leaf 512-byte
digest crosses devices.  ``chunk_words=`` streams leaves larger than one
bank pass through the engine's chunked mode.

Any single-bit corruption flips exactly one digest bit (XOR linearity), so
digest equality is a true parity check, not a heuristic hash.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as _engine
from repro.kernels import ops as _ops

DIGEST_WIDTH = 128  # uint32 words = 512 bytes


def leaf_key(path) -> str:
    """Canonical string key ("a/b/0") for a tree_flatten_with_path entry.

    The single definition shared by the checkpoint manifest
    (:mod:`repro.checkpoint.ckpt`) and the incremental
    :class:`repro.core.incremental.DigestCache` — both address leaves by
    this key, and ``save_delta(cache=)`` relies on the two never
    desynchronizing.
    """
    return "/".join(_path_entry_str(p) for p in path)


def _path_entry_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def tree_digest(tree, impl: str = "auto",
                engine: _engine.CimEngine | None = None,
                chunk_words: int | None = None, cache=None):
    """Pytree -> same-structure pytree of (DIGEST_WIDTH,) uint32 digests.

    ``engine`` may be a single-device :class:`~repro.core.engine.CimEngine`
    or a mesh-aware :class:`~repro.core.engine.ShardedCimEngine` — digests
    are bit-identical either way.  ``chunk_words`` bounds the per-dispatch
    footprint via :meth:`~repro.core.engine.CimEngine.digest_stream`.
    ``cache`` (a :class:`repro.core.incremental.DigestCache`) makes repeated
    scans incremental: only chunks that changed since the cache's previous
    pass are re-digested through its engine — same digests, O(dirty-chunks)
    dispatch (DESIGN.md §12).
    """
    if cache is not None:
        # the cache digests through its own engine/chunking/impl; different
        # values here would be silently ignored — refuse.
        if engine is not None and engine is not cache.engine:
            raise ValueError("tree_digest: cache= and engine= conflict — "
                             "the cache digests through cache.engine; pass "
                             "the same engine (or neither)")
        if impl != "auto" and impl != cache.engine.impl:
            raise ValueError(
                f"tree_digest: impl={impl!r} conflicts with the cache "
                f"engine's impl={cache.engine.impl!r} — the cache digests "
                "through its own engine")
        if cache.digest_width != DIGEST_WIDTH:
            raise ValueError(
                f"tree_digest: cache digest_width={cache.digest_width} "
                f"breaks the ({DIGEST_WIDTH},)-digest contract — build the "
                "cache with the default width")
        if chunk_words is not None and cache.engine._chunk_words(
                chunk_words, cache.digest_width) != cache.chunk_words:
            # align the caller's value the same way DigestCache did at
            # construction, so passing the identical argument to both is OK
            raise ValueError(
                f"tree_digest: chunk_words={chunk_words} conflicts with the "
                f"cache's chunk_words={cache.chunk_words}")
        return cache.digests(tree)
    eng = engine if engine is not None else _engine.CimEngine(impl=impl)
    if chunk_words is None:
        fn = lambda x: eng.digest(x, DIGEST_WIDTH)
    else:
        fn = lambda x: eng.digest_stream(x, DIGEST_WIDTH,
                                         chunk_words=chunk_words)
    return jax.tree.map(fn, tree)


def verify_trees(a, b, impl: str = "auto",
                 engine: _engine.CimEngine | None = None,
                 chunk_words: int | None = None,
                 cache_a=None, cache_b=None):
    """Returns (all_ok: bool array, per-leaf ok pytree) comparing digests.

    ``cache_a``/``cache_b`` make the periodic source-vs-backup scrub
    incremental: each tree keeps its own
    :class:`~repro.core.incremental.DigestCache` (the caches retain leaf
    references, so one cache must not track both trees).
    """
    if cache_a is not None and cache_a is cache_b:
        # one cache thrashing between two trees re-digests every differing
        # chunk on every scrub — correct results, but silently O(diff) forever
        raise ValueError("verify_trees: cache_a and cache_b must be distinct "
                         "DigestCaches — a shared cache thrashes between the "
                         "two trees and defeats the incremental scan")
    da = tree_digest(a, impl, engine=engine, chunk_words=chunk_words,
                     cache=cache_a)
    db = tree_digest(b, impl, engine=engine, chunk_words=chunk_words,
                     cache=cache_b)
    leaf_ok = jax.tree.map(lambda x, y: jnp.all(x == y), da, db)
    return jnp.all(jnp.stack(jax.tree.leaves(leaf_ok))), leaf_ok


def np_words(arr: np.ndarray, align: int = 4):
    """View any numpy array's bytes as the little-endian uint32 stream every
    host-side digest/cipher shares, zero-padding the tail to ``align`` bytes.

    Returns ``(words, nbytes)`` — the uint32 view and the original byte
    length.  Delegates to :func:`repro.kernels.ops.host_words`, the single
    definition of the host byte layout; the device twins
    (:func:`np_digest_via_device`,
    :func:`repro.core.encrypt.encrypt_np_via_device`) route the same words
    through the engine, which is what makes the two paths bit-compatible.
    """
    return _ops.host_words(arr, align)


def np_digest(arr: np.ndarray, digest_width: int = DIGEST_WIDTH) -> np.ndarray:
    """Host-side digest of any numpy array (byte view -> uint32 stream)."""
    words, _ = np_words(arr, align=4 * digest_width)
    return np.bitwise_xor.reduce(words.reshape(-1, digest_width), axis=0)


def np_verify(a: np.ndarray, b: np.ndarray) -> bool:
    return bool(np.array_equal(np_digest(a), np_digest(b)))


def np_digest_via_device(arr: np.ndarray, engine: _engine.CimEngine,
                         digest_width: int = DIGEST_WIDTH) -> np.ndarray:
    """Device-routed twin of :func:`np_digest` (bit-identical).

    Views the host array's bytes as the same little-endian uint32 stream
    :func:`np_digest` folds, then folds it on device through ``engine`` —
    so the checkpoint layer can burn digests on the bank stack (sharded or
    not) while staying byte-compatible with manifests written by the host
    path.
    """
    words, _ = np_words(arr)
    return np.asarray(engine.digest(jnp.asarray(words), digest_width))
