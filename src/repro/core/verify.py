"""Bulk copy verification — the paper's Fig. 1(a) application, at framework
scale.

The paper XORs a copied row against its source in one cycle; a zero result
verifies the copy.  Our framework-scale equivalents:

* :func:`tree_digest` — per-leaf XOR-parity digests of a parameter pytree
  (jit-able; under pjit the fold runs sharded and the 512-byte digest is the
  only cross-device traffic, which is the whole point of digesting).
* :func:`verify_trees` — compare two pytrees leaf-by-leaf by digest.
* :func:`np_digest` — numpy twin used by the checkpoint layer on the host
  I/O path (bit-identical to the jax fold for uint32 streams).

Device-side digests route through the banked :class:`repro.core.engine
.CimEngine` (cycle-accounted bank schedule, DESIGN.md §10); pass ``engine=``
to share one engine's stats across calls, or ``impl=`` to hit the kernel
layer directly with a throwaway default engine.

Any single-bit corruption flips exactly one digest bit (XOR linearity), so
digest equality is a true parity check, not a heuristic hash.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as _engine

DIGEST_WIDTH = 128  # uint32 words = 512 bytes


def tree_digest(tree, impl: str = "auto",
                engine: _engine.CimEngine | None = None):
    """Pytree -> same-structure pytree of (DIGEST_WIDTH,) uint32 digests."""
    eng = engine if engine is not None else _engine.CimEngine(impl=impl)
    return jax.tree.map(lambda x: eng.digest(x, DIGEST_WIDTH), tree)


def verify_trees(a, b, impl: str = "auto",
                 engine: _engine.CimEngine | None = None):
    """Returns (all_ok: bool array, per-leaf ok pytree) comparing digests."""
    da = tree_digest(a, impl, engine=engine)
    db = tree_digest(b, impl, engine=engine)
    leaf_ok = jax.tree.map(lambda x, y: jnp.all(x == y), da, db)
    return jnp.all(jnp.stack(jax.tree.leaves(leaf_ok))), leaf_ok


def np_digest(arr: np.ndarray, digest_width: int = DIGEST_WIDTH) -> np.ndarray:
    """Host-side digest of any numpy array (byte view -> uint32 stream)."""
    raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    pad = (-raw.size) % (4 * digest_width)
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
    words = raw.view(np.uint32).reshape(-1, digest_width)
    return np.bitwise_xor.reduce(words, axis=0)


def np_verify(a: np.ndarray, b: np.ndarray) -> bool:
    return bool(np.array_equal(np_digest(a), np_digest(b)))
