"""Bit-plane packing: the data layout of the in-memory XOR engine.

The paper stores operands as rows of single-bit cells and computes a whole
row of XOR/XNOR per sense cycle.  On TPU the analogous layout is *bit-plane
packing*: 32 binary values per ``uint32`` lane, so one VPU int-op performs 32
bit-ops and one 8x128 vreg performs 32,768.  All bit-domain kernels
(:mod:`repro.kernels`) consume this layout.

Conventions
-----------
* A "bit" encodes the sign of a real value: ``bit = 1  <=>  x >= 0`` (i.e.
  ``x -> +1``), ``bit = 0 <=> x < 0`` (``x -> -1``).  This is the XNOR-Net
  binarization.
* Packing runs along the *last* axis, LSB-first within each 32-bit word:
  word ``w`` holds source positions ``32*w .. 32*w+31``; bit ``j`` of word
  ``w`` is source position ``32*w + j``.
* ``K`` (the unpacked length) must be a multiple of 32 for the packed kernels;
  :func:`pad_to_word` pads with an encoding that contributes zero to XNOR
  dot products when both operands share the padding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32
_SHIFTS = jnp.arange(WORD, dtype=jnp.uint32)


def packed_width(k: int) -> int:
    """Number of uint32 words needed for ``k`` bits."""
    return (k + WORD - 1) // WORD


def pad_to_word(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Zero-pad ``axis`` up to a multiple of 32.

    Zero pads binarize to ``+1`` under the ``x >= 0`` rule; XNOR dot products
    of two padded operands pick up ``+1 * +1`` contributions per pad slot,
    which callers must subtract (``xnor_dot`` handles this via ``valid_k``).
    """
    k = x.shape[axis]
    pad = (-k) % WORD
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis % x.ndim] = (0, pad)
    return jnp.pad(x, widths)


def pack_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Pack the sign bits of ``x`` along its last axis into uint32 planes.

    ``x``: (..., K) real or boolean, K % 32 == 0.
    Returns (..., K // 32) uint32.
    """
    k = x.shape[-1]
    if k % WORD != 0:
        raise ValueError(f"last axis {k} not a multiple of {WORD}; pad first")
    if x.dtype == jnp.bool_:
        bits = x
    else:
        bits = x >= 0
    bits = bits.reshape(*x.shape[:-1], k // WORD, WORD).astype(jnp.uint32)
    return jnp.bitwise_or.reduce(bits << _SHIFTS, axis=-1)


def unpack_bits(p: jnp.ndarray, k: int | None = None, signed: bool = True,
                dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`.

    Returns ±1 values (``signed=True``) or {0,1} (``signed=False``) of shape
    (..., k); ``k`` defaults to the full packed width * 32.
    """
    full = p.shape[-1] * WORD
    k = full if k is None else k
    bits = (p[..., :, None] >> _SHIFTS) & jnp.uint32(1)
    bits = bits.reshape(*p.shape[:-1], full)[..., :k]
    if signed:
        return (2 * bits.astype(jnp.int32) - 1).astype(dtype)
    return bits.astype(dtype)


def binarize(x: jnp.ndarray):
    """XNOR-Net binarization of the last axis.

    Returns ``(packed_bits, alpha)`` where ``alpha = mean(|x|)`` along the
    last axis is the XNOR-Net scaling factor, so
    ``x ~= alpha[..., None] * unpack_bits(packed_bits)``.
    """
    xp = pad_to_word(x)
    alpha = jnp.mean(jnp.abs(x), axis=-1)
    return pack_bits(xp), alpha.astype(jnp.float32)


def binarize_ste(x: jnp.ndarray) -> jnp.ndarray:
    """Straight-through-estimator sign(x) in the *unpacked* domain.

    Forward: sign(x) (with sign(0) = +1).  Backward: identity inside
    |x| <= 1, zero outside (the XNOR-Net / BNN clipped STE).
    """
    s = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    clip = (jnp.abs(x) <= 1.0).astype(x.dtype)
    return x * clip + jax.lax.stop_gradient(s - x * clip)
