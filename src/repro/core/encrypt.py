"""XOR stream encryption — the paper's Fig. 1(b) application.

Checkpoint shards are encrypted with a counter-mode XOR pad before hitting
storage and decrypted on restore (XOR is an involution: same code path).
Keys are derived per-leaf from a root key and the leaf's tree path, so no
two leaves reuse a pad position — the counter-mode answer to the paper's
"key must be a true random number" caveat.

Host path (checkpointing) works on numpy byte views; device path
(:func:`encrypt_device`) runs the Pallas/ref cipher under jit.
"""

from __future__ import annotations

import hashlib

import numpy as np
import jax.numpy as jnp


def pad_path(step: int, leaf_key: str) -> str:
    """Canonical pad-derivation path for a checkpoint leaf written at
    ``step``.

    The single definition shared by save/save_delta/check/restore — pads
    are keyed by the step the leaf's bytes were *written* at (a delta
    chain's ``stored_in``), so a leaf re-encrypted at a later delta step
    draws a fresh pad and no (key, counter) position is ever reused across
    the chain.
    """
    return f"{step}/{leaf_key}"


def derive_key(root_key: bytes | str, leaf_path: str):
    """(key0, key1, counter_base) uint32 triple from root key + leaf path."""
    if isinstance(root_key, str):
        root_key = root_key.encode()
    h = hashlib.sha256(root_key + b"\x00" + leaf_path.encode()).digest()
    k0, k1, ctr = (int.from_bytes(h[i:i + 4], "little") for i in (0, 4, 8))
    return np.uint32(k0), np.uint32(k1), np.uint32(ctr)


def _np_keystream(idx: np.ndarray, k0: np.uint32, k1: np.uint32) -> np.ndarray:
    """Numpy twin of ref.keystream_word (bit-identical)."""
    with np.errstate(over="ignore"):
        h = idx.astype(np.uint32) * np.uint32(0x9E3779B9) + k0
        h ^= k1
        h ^= h >> np.uint32(16)
        h *= np.uint32(0x85EBCA6B)
        h ^= h >> np.uint32(13)
        h *= np.uint32(0xC2B2AE35)
        h ^= h >> np.uint32(16)
    return h


def encrypt_np(arr: np.ndarray, root_key: bytes | str, leaf_path: str) -> np.ndarray:
    """Encrypt (or decrypt — involution) a numpy array's bytes in place shape.

    Returns a uint8 buffer of the same byte length; pair with the original
    dtype/shape metadata to reconstruct (checkpoint layer stores both).
    """
    from repro.core.verify import np_words
    k0, k1, ctr = derive_key(root_key, leaf_path)
    words, nbytes = np_words(arr)
    idx = np.arange(words.size, dtype=np.uint32) + ctr
    return (words ^ _np_keystream(idx, k0, k1)).view(np.uint8)[:nbytes]


def decrypt_np(buf: np.ndarray, root_key: bytes | str, leaf_path: str,
               dtype, shape) -> np.ndarray:
    """Inverse of encrypt_np, restoring dtype/shape."""
    plain = encrypt_np(buf, root_key, leaf_path)  # involution
    return plain.view(dtype).reshape(shape).copy()


def encrypt_device(buf: jnp.ndarray, root_key: bytes | str, leaf_path: str,
                   impl: str = "auto", engine=None) -> jnp.ndarray:
    """Device-side cipher over a uint32 buffer (jit-able).

    Routed through the banked :class:`repro.core.engine.CimEngine` — pass
    ``engine=`` to cycle-account the cipher against a shared bank schedule
    (DESIGN.md §10), in which case the engine's own ``impl`` wins and the
    ``impl`` argument is ignored; otherwise a throwaway default-geometry
    engine is built from ``impl``.
    """
    from repro.core.engine import CimEngine
    k0, k1, ctr = derive_key(root_key, leaf_path)
    key = jnp.array([k0, k1], dtype=jnp.uint32)
    eng = engine if engine is not None else CimEngine(impl=impl)
    return eng.stream_cipher(buf, key, counter=int(ctr))


def encrypt_np_via_device_staged(arr: np.ndarray, root_key: bytes | str,
                                 leaf_path: str, engine):
    """Staged twin of :func:`encrypt_np_via_device`: dispatch now,
    materialize later.

    The cipher is dispatched immediately (jax dispatch is async) and a
    zero-argument ``materialize()`` closure is returned; calling it is the
    only sync point.  The checkpoint writer's double buffer uses this to
    overlap one leaf's device cipher with another leaf's host write while
    keeping the host byte contract in exactly one place.
    """
    from repro.core.verify import np_words
    words, nbytes = np_words(arr)
    enc = encrypt_device(jnp.asarray(words), root_key, leaf_path,
                         engine=engine)

    def materialize() -> np.ndarray:
        out = np.asarray(enc).view(np.uint8)
        return out[:nbytes].copy() if nbytes != out.size else out

    return materialize


def encrypt_np_via_device(arr: np.ndarray, root_key: bytes | str,
                          leaf_path: str, engine) -> np.ndarray:
    """Device-routed twin of :func:`encrypt_np` (bit-identical bytes).

    The host array's bytes are viewed as the same little-endian uint32
    stream :func:`encrypt_np` XORs, ciphered on device through ``engine``
    (single-device or sharded — the keystream is position-keyed, so the
    shard split changes nothing), and returned as a uint8 buffer of the
    original byte length.  Checkpoints written this way decrypt with the
    host path and vice versa.
    """
    return encrypt_np_via_device_staged(arr, root_key, leaf_path, engine)()


def decrypt_np_via_device(buf: np.ndarray, root_key: bytes | str,
                          leaf_path: str, dtype, shape, engine) -> np.ndarray:
    """Inverse of :func:`encrypt_np_via_device`, restoring dtype/shape."""
    plain = encrypt_np_via_device(buf, root_key, leaf_path, engine)
    return plain.view(dtype).reshape(shape).copy()
