"""Behavioral circuit simulator for the paper's CiM array (paper §III–IV).

This is the *faithful reproduction* layer: a phenomenological model of the
ReRAM array + modified peripheral sensing of Fig. 2, calibrated to the
paper's reported operating points:

* Cu/HfO2/Pt ReRAM: LRS = 10 kOhm, HRS = 3 GOhm  (paper §III)
* BL precharge V_BL = 100 mV                      (paper §IV)
* accessed-cell currents: I(LRS) = 7.85 uA  => series access-FET resistance
  R_ACC = V/I - LRS = 2.74 kOhm; I(HRS) = 33 pA. Two accessed cells sum on
  the sense line: I_11 = 15.7 uA, I_01 = 7.87 uA, I_00 ~ 0.1 nA including
  one unaccessed-row leak — all matching Fig. 4(d).
* unaccessed-cell leakage (WL low): 774 pA (LRS), 28 pA (HRS) — paper §V.
  Modeled as state-dependent constants (the paper reports them as such; a
  single off-resistance cannot reproduce both, see DESIGN.md §8).

Everything is pure JAX: the Monte-Carlo layer ``vmap``s these functions over
thousands of sampled (LRS, HRS, V_t) worlds.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import logic

# --- calibrated constants (SI units) ---------------------------------------
V_BL = 0.1                    # bit-line precharge (V)
LRS = 10e3                    # low-resistance state (Ohm)
HRS = 3e9                     # high-resistance state (Ohm)
R_ACC = V_BL / 7.85e-6 - LRS  # access-FET on-resistance ~ 2.74 kOhm
LEAK_LRS = 774e-12            # unaccessed LRS leakage (A)
LEAK_HRS = 28e-12             # unaccessed HRS leakage (A)
# CSA small-signal model for node-voltage histograms (Fig. 5(d)):
R_MIRROR = 12e3               # current-mirror load: V_node = I * R_MIRROR
# V_t variation couples into sensing as an equivalent reference shift.
# The CSA the paper builds on (Chang et al. [27]) is *offset-tolerant*
# (current-sampling cancels static offset); the residual coupling is modeled
# with an effective overdrive of 0.5 V => gm/I = 2 /V.  With sigma_Vt = 25 mV
# this leaves > 6 sigma of margin on the tightest (I_11 vs REF2) boundary,
# consistent with the paper's "well-distinguishable under 5000-pt MC".
GM_OVER_I = 2.0


class ArrayState(NamedTuple):
    """One CiM array — or a whole bank stack of them.

    ``r`` is ``(rows, cols)`` for a single array or ``(..., rows, cols)``
    for a stack of independent banks sharing geometry (DESIGN.md §10): every
    function below treats the trailing two axes as (rows, cols) and
    broadcasts over any leading bank axes.
    """
    r: jnp.ndarray           # (..., rows, cols) resistance, Ohm
    leak_lrs: jnp.ndarray    # scalar or broadcastable leakage constants
    leak_hrs: jnp.ndarray


def make_array(bits: jnp.ndarray, lrs: float | jnp.ndarray = LRS,
               hrs: float | jnp.ndarray = HRS,
               leak_lrs=LEAK_LRS, leak_hrs=LEAK_HRS) -> ArrayState:
    """Program an array from a (..., rows, cols) 0/1 matrix ('1' -> LRS).

    Leading axes are independent banks programmed in one shot.
    """
    r = jnp.where(bits.astype(bool), lrs, hrs)
    return ArrayState(r, jnp.asarray(leak_lrs), jnp.asarray(leak_hrs))


def write(state: ArrayState, row: int, col: int, bit) -> ArrayState:
    """Memory-mode write: bias WL/BL so the addressed cell switches state.

    (paper Fig. 3: +0.4 V BL writes '1' (-> LRS), -0.15 V writes '0' (-> HRS);
    half-accessed cells see sub-threshold bias and keep their state — here
    that invariant holds by construction since only (row, col) is updated.)

    On a banked state the same (row, col) cell is written in every bank;
    ``bit`` may be bank-shaped to program different values per bank.
    """
    new_r = jnp.where(jnp.asarray(bit, bool), LRS, HRS)
    return state._replace(r=state.r.at[..., row, col].set(new_r))


def _wl_one_hot(num_rows: int, *row_indices) -> jnp.ndarray:
    """OR of one-hot row selects: (..., P, rows) for (..., P) indices.

    Scalar indices produce the classic (rows,) mask; array indices vectorize
    the word-line decoder over row-pairs (and optionally banks).
    """
    rows = jnp.arange(num_rows)
    wl = jnp.zeros((), bool)
    for idx in row_indices:
        wl = wl | (rows == jnp.asarray(idx)[..., None])
    return wl


def sl_currents(state: ArrayState, wl_mask: jnp.ndarray) -> jnp.ndarray:
    """Sense-line current per column for a given word-line assertion mask.

    Accessed rows contribute V_BL / (R_cell + R_ACC); unaccessed rows leak
    their state-dependent constant.  This is the analog summation the paper
    exploits — on the SL, currents add, so the column-wise result is
    data-parallel across the whole row width (the paper's bulk parallelism).

    ``wl_mask`` is (..., rows) and ``state.r`` is (..., rows, cols); both
    broadcast, so one call senses every bank (and every vectorized row-pair)
    at once — the array-level parallelism of DESIGN.md §10.
    """
    accessed = wl_mask.astype(bool)[..., :, None]
    i_on = V_BL / (state.r + R_ACC)
    is_lrs = state.r < (LRS + HRS) / 2
    i_leak = jnp.where(is_lrs, state.leak_lrs, state.leak_hrs)
    return jnp.sum(jnp.where(accessed, i_on, i_leak), axis=-2)


def compute(state: ArrayState, row_a, row_b, op: str = "xor",
            offset1=0.0, offset2=0.0) -> jnp.ndarray:
    """Single-cycle in-memory Boolean op between two rows (all columns).

    Asserts both word lines, senses each column's SL current through the
    dual-reference datapath of Fig. 2(c).  One sense cycle, row-wide.

    ``row_a``/``row_b`` may be ints (one row-pair, the paper's primitive) or
    integer arrays of shape (P,) / (..., P) naming P row-pairs per bank; the
    result gains a matching (..., P) prefix before the column axis.  On a
    banked (..., rows, cols) state the op runs in every bank, so one traced
    call computes banks x pairs x cols bit-ops (DESIGN.md §10).
    """
    ra, rb = jnp.asarray(row_a), jnp.asarray(row_b)
    wl = _wl_one_hot(state.r.shape[-2], ra, rb)
    if ra.ndim or rb.ndim:
        # insert the pair axis before (rows, cols) so wl (..., P, rows)
        # broadcasts against r (..., 1, rows, cols)
        state = state._replace(r=state.r[..., None, :, :])
    i_sl = sl_currents(state, wl)
    spec = logic.op_table()[op]
    return logic.sense_datapath(i_sl, spec, offset1, offset2)


# Memory-mode read uses the same SA with single-access references
# (paper §IV: "only one cell is accessed and reference current levels are
# different").  One accessed cell: I in {33 pA (HRS), 7.85 uA (LRS)}.
READ_REF = 4e-6


def read(state: ArrayState, row, offset=0.0) -> jnp.ndarray:
    """Memory-mode read of one row — or (P,)/(..., P) rows, vectorized."""
    rv = jnp.asarray(row)
    wl = _wl_one_hot(state.r.shape[-2], rv)
    if rv.ndim:
        state = state._replace(r=state.r[..., None, :, :])
    i_sl = sl_currents(state, wl)
    return i_sl > (READ_REF + offset)


def node_voltages(i_cell: jnp.ndarray, i_ref: jnp.ndarray):
    """CSA internal nodes (Fig. 5(e)): mirror converts current to voltage."""
    return i_cell * R_MIRROR, i_ref * R_MIRROR


def vt_offset_to_iref_shift(delta_vt: jnp.ndarray, i_ref: float) -> jnp.ndarray:
    """Map comparator V_t mismatch to an equivalent reference-current shift.

    Small-signal: dI = gm * dV = (gm/I) * I_ref * dVt.  With gm/I ~ 5 /V a
    25 mV sigma shifts the effective reference by ~12.5% of I_ref — the
    dominant variation term, consistent with the paper's finding that the
    margins (uA-scale) dwarf resistance spread but V_t matters.
    """
    return delta_vt * GM_OVER_I * i_ref
