"""Reference-current -> Boolean-operation selection (paper §III, Fig. 2(b)).

The paper's modified sense amplifier feeds the sense-line current into two
CSAs with references REF1/REF2 and combines their outputs with one inverter
+ one AND gate.  Because the SL current is monotone in the number of '1'
cells among the two accessed ones (s = a + b in {0, 1, 2}), placing the two
references relative to {I_00, I_01, I_11} makes the AND-of-comparators an
*interval* predicate on s — XOR is the interval s == 1, AND is s == 2,
OR is s >= 1.  Complement ops (XNOR/NAND/NOR) use the CSA's complementary
output rail (the latched CSA of Fig. 2(d) produces OUT and OUT_B in the
same cycle, so complementing is free — still single-cycle).

This module is the digital twin of that mechanism and the functional spec
the circuit simulator (:mod:`repro.core.cim`) is tested against.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class OpSpec(NamedTuple):
    """Reference placement (amps) + output-rail selection for one Boolean op."""
    name: str
    ref1: float          # CSA1 reference current (A)
    ref2: float          # CSA2 reference current (A)
    invert_out: bool     # take OUT_B of the final AND (complementary rail)


# Nominal current levels for the calibrated array (paper Fig. 4(d)):
I_00 = 100e-12   # both accessed cells HRS ('0','0') + nominal leakage
I_01 = 7.87e-6   # one LRS ('0','1' / '1','0')
I_11 = 15.7e-6   # both LRS ('1','1')

# References exactly as the paper sets them (XOR: 4 uA / 12 uA).
REF_LO = 4e-6    # in (I_00, I_01)
REF_HI = 12e-6   # in (I_01, I_11)
REF_INF = 1.0    # "above any SL current": disables the second comparator


def op_table() -> dict[str, OpSpec]:
    return {
        # out = (I > ref1) AND NOT (I > ref2)        -> 1 iff ref1 < I <= ref2
        "xor":  OpSpec("xor",  REF_LO, REF_HI, False),   # s == 1
        "and":  OpSpec("and",  REF_HI, REF_INF, False),  # s == 2
        "or":   OpSpec("or",   REF_LO, REF_INF, False),  # s >= 1
        # complementary rail of the same datapath (single cycle):
        "xnor": OpSpec("xnor", REF_LO, REF_HI, True),    # s != 1
        "nand": OpSpec("nand", REF_HI, REF_INF, True),   # s < 2
        "nor":  OpSpec("nor",  REF_LO, REF_INF, True),   # s == 0
    }


def sense_datapath(i_sl: jnp.ndarray, spec: OpSpec,
                   offset1: jnp.ndarray | float = 0.0,
                   offset2: jnp.ndarray | float = 0.0) -> jnp.ndarray:
    """The two-CSA + inverter + AND datapath of Fig. 2(c).

    ``offset1/2`` model comparator input-referred offset (from transistor
    V_t mismatch) as an equivalent reference-current shift — the quantity
    the Monte-Carlo analysis perturbs.
    """
    c1 = i_sl > (spec.ref1 + offset1)
    c2 = i_sl > (spec.ref2 + offset2)
    out = jnp.logical_and(c1, jnp.logical_not(c2))
    return jnp.logical_xor(out, spec.invert_out)


def truth_table(spec: OpSpec) -> list[tuple[int, int, int]]:
    """Evaluate the datapath over the nominal current levels -> (a, b, out)."""
    levels = {(0, 0): I_00, (0, 1): I_01, (1, 0): I_01, (1, 1): I_11}
    rows = []
    for (a, b), i in levels.items():
        out = bool(sense_datapath(jnp.asarray(i), spec))
        rows.append((a, b, int(out)))
    return rows
