"""Monte-Carlo variation analysis (paper §V, Fig. 5) + array scalability.

Reproduces:
* the 5000-point MC over Gaussian LRS/HRS (3 sigma = 10% of mean) and
  transistor V_t (sigma = 25 mV), giving SL-current and CSA node-voltage
  distributions (Fig. 5(c), 5(d)) and per-input-combination error rates;
* the max-array-rows vs HRS/LRS scalability analysis (Fig. 5(b)): leakage
  from unaccessed rows eventually drags I_00 past REF1 — the row budget is
  where the worst-case '00' current crosses the reference (with margin).

Pure JAX, fully vmapped: one jit evaluates all samples x all input combos.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cim, logic

SIGMA_FRAC = 0.10 / 3.0   # 3 sigma = 10% of mean
SIGMA_VT = 25e-3          # V


class MCResult(NamedTuple):
    """Leading axes are (samples,) for ``banks=1`` (the paper's setup) or
    (samples, banks) when the MC is vmapped over a bank stack — every bank
    is an independent array with its own device/Vt world (DESIGN.md §10)."""
    i_sl: jnp.ndarray        # (samples[, banks], 3) currents for s = 0, 1, 2
    v_cell: jnp.ndarray      # (samples[, banks], 3) CSA n_CELL voltages
    v_ref: jnp.ndarray       # (samples[, banks], 2) n_REF voltages (REF1, REF2)
    xor_out: jnp.ndarray     # (samples[, banks], 3) bool datapath outputs (XOR)
    xnor_out: jnp.ndarray    # (samples[, banks], 3)
    error_rate: jnp.ndarray  # (3,) fraction mis-sensed (XOR), over all worlds
    margins: jnp.ndarray     # (samples[, banks], 2) (I01-REF1eff, REF2eff-I01)


def _one_sample(key, rows: int, op_specs) -> tuple:
    """SL currents + sense outputs for one sampled world.

    Array column under test: two accessed cells with states (0,0)/(0,1)/(1,1)
    + (rows-2) unaccessed cells in the worst-ish mixed state (half LRS).
    """
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    lrs = cim.LRS * (1.0 + SIGMA_FRAC * jax.random.normal(k1, (rows,)))
    hrs = cim.HRS * (1.0 + SIGMA_FRAC * jax.random.normal(k2, (rows,)))
    dvt1 = SIGMA_VT * jax.random.normal(k3, ())
    dvt2 = SIGMA_VT * jax.random.normal(k4, ())
    unacc_bits = jax.random.bernoulli(k5, 0.5, (rows - 2,))

    def column_current(bit_a, bit_b):
        bits = jnp.concatenate([jnp.array([bit_a, bit_b], bool), unacc_bits])
        r = jnp.where(bits, lrs, hrs)
        i_on = cim.V_BL / (r + cim.R_ACC)
        i_leak = jnp.where(bits, cim.LEAK_LRS, cim.LEAK_HRS)
        wl = jnp.zeros(rows, bool).at[0].set(True).at[1].set(True)
        return jnp.sum(jnp.where(wl, i_on, i_leak))

    i_s = jnp.stack([column_current(False, False),
                     column_current(False, True),
                     column_current(True, True)])          # (3,)

    off1 = cim.vt_offset_to_iref_shift(dvt1, logic.REF_LO)
    off2 = cim.vt_offset_to_iref_shift(dvt2, logic.REF_HI)
    xor_spec, xnor_spec = op_specs
    xor_o = logic.sense_datapath(i_s, xor_spec, off1, off2)
    xnor_o = logic.sense_datapath(i_s, xnor_spec, off2, off1)
    v_cell, _ = cim.node_voltages(i_s, i_s)
    v_ref = jnp.stack([(logic.REF_LO + off1), (logic.REF_HI + off2)]) * cim.R_MIRROR
    margins = jnp.stack([i_s[1] - (logic.REF_LO + off1),
                         (logic.REF_HI + off2) - i_s[1]])
    return i_s, v_cell, v_ref, xor_o, xnor_o, margins


def run(key: jax.Array, samples: int = 5000, rows: int = 3,
        banks: int = 1) -> MCResult:
    """The paper's 5000-point MC (vmapped, one jit).

    ``banks > 1`` nests a second vmap over independent per-bank worlds —
    the variation picture for the banked engine, where each bank has its
    own device lot and sense amps.  Result axes gain a bank dimension
    (squeezed away for ``banks=1`` so the paper's single-array shapes are
    unchanged); ``error_rate`` aggregates over samples *and* banks.
    """
    specs = (logic.op_table()["xor"], logic.op_table()["xnor"])
    keys = jax.random.split(key, samples * banks)
    keys = keys.reshape(samples, banks, *keys.shape[1:])  # typed keys: (S, B)
    sample_fn = lambda k: _one_sample(k, rows, specs)
    i_s, v_cell, v_ref, xor_o, xnor_o, margins = jax.vmap(
        jax.vmap(sample_fn))(keys)
    want_xor = jnp.array([False, True, False])
    err = jnp.mean(xor_o != want_xor[None, None, :], axis=(0, 1))
    res = (i_s, v_cell, v_ref, xor_o, xnor_o, margins)
    if banks == 1:
        res = tuple(x[:, 0] for x in res)
    i_s, v_cell, v_ref, xor_o, xnor_o, margins = res
    return MCResult(i_s, v_cell, v_ref, xor_o, xnor_o, err, margins)


# ---------------------------------------------------------------------------
# Fig. 5(b): max rows vs on/off ratio
# ---------------------------------------------------------------------------

def max_rows(lrs: float = cim.LRS, hrs: float = cim.HRS,
             margin_frac: float = 0.5) -> jnp.ndarray:
    """Largest row count for which worst-case '00' stays below REF1.

    Worst case: every unaccessed cell is LRS (max leakage).  Scaling the
    paper's leak constants with 1/R (leak ~ V/R through the off transistor):
      I_00(N) = 2 * V/(hrs + R_ACC) + (N-2) * LEAK_LRS * (LRS_nom / lrs)
    Requirement: I_00(N) < margin_frac * REF1 (default: 50% sense margin).
    Larger HRS/LRS ratio (at fixed HRS) -> smaller lrs -> larger accessed
    current AND larger leak, matching the paper's trend that the ratio sets
    scalability.
    """
    lrs = jnp.asarray(lrs, jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32)
    i_acc_00 = 2 * cim.V_BL / (hrs + cim.R_ACC)
    leak_lrs = cim.LEAK_LRS * (cim.LRS / lrs)
    budget = margin_frac * logic.REF_LO - i_acc_00
    return jnp.floor(budget / leak_lrs) + 2


def max_rows_sweep(ratios: jnp.ndarray, vary: str = "lrs") -> jnp.ndarray:
    """Fig. 5(b): sweep HRS/LRS ratio by varying LRS (black line) or HRS."""
    if vary == "lrs":
        return jax.vmap(lambda r: max_rows(lrs=cim.HRS / r, hrs=cim.HRS))(ratios)
    return jax.vmap(lambda r: max_rows(lrs=cim.LRS, hrs=cim.LRS * r))(ratios)
