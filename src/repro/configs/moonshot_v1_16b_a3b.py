"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B; hf]: MoE 64e top-6.

DeepSeek-V3-style fine-grained experts (d_ff=1408 per expert); the
assignment specifies 64 experts, top-6 routing, GQA kv=16 (== n_heads:
effectively MHA).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840,
    pattern=("moe",),
    n_experts=64, top_k=6, d_ff_expert=1408,
    rope_theta=50000.0,
)
