"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture (plus the paper's own XNOR-CNN) registers here.
``get(name)`` also accepts ``<name>+xnor`` to produce the binary-quantized
variant of any LM arch (the paper's technique as a config axis).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_applicable
from repro.configs.qwen2_7b import CONFIG as qwen2_7b
from repro.configs.qwen3_4b import CONFIG as qwen3_4b
from repro.configs.phi4_mini_3_8b import CONFIG as phi4_mini_3_8b
from repro.configs.qwen3_14b import CONFIG as qwen3_14b
from repro.configs.xlstm_350m import CONFIG as xlstm_350m
from repro.configs.llama4_scout_17b_a16e import CONFIG as llama4_scout_17b_a16e
from repro.configs.moonshot_v1_16b_a3b import CONFIG as moonshot_v1_16b_a3b
from repro.configs.recurrentgemma_2b import CONFIG as recurrentgemma_2b
from repro.configs.llama_3_2_vision_11b import CONFIG as llama_3_2_vision_11b
from repro.configs.whisper_tiny import CONFIG as whisper_tiny
from repro.configs.xnor_cnn import CONFIG as xnor_cnn

ALL: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        qwen2_7b, qwen3_4b, phi4_mini_3_8b, qwen3_14b, xlstm_350m,
        llama4_scout_17b_a16e, moonshot_v1_16b_a3b, recurrentgemma_2b,
        llama_3_2_vision_11b, whisper_tiny, xnor_cnn,
    ]
}


def get(name: str) -> ArchConfig:
    quant = "none"
    if name.endswith("+xnor"):
        name, quant = name[: -len("+xnor")], "xnor"
    cfg = ALL[name]
    if quant != "none":
        cfg = dataclasses.replace(cfg, quant=quant,
                                  name=cfg.name + "+xnor")
    return cfg


__all__ = ["ALL", "SHAPES", "ArchConfig", "ShapeConfig", "get",
           "shape_applicable"]
