"""Phi-4-mini 3.8B [arXiv:2412.08905; hf]: dense GQA, RoPE + SwiGLU."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064,
    qkv_bias=False, qk_norm=False, rope_theta=10000.0,
    notes="RoPE SwiGLU GQA kv=8; 200k vocab.",
)
