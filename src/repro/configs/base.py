"""Architecture config schema + shape registry.

Each assigned architecture is one frozen :class:`ArchConfig` in its own
module under ``repro/configs`` (``--arch <id>`` resolves through
:func:`repro.configs.get`).  A config fully determines parameter shapes,
sharding specs and the lowered programs; the *same* dataclass powers the
full-scale dry-run and the reduced smoke tests (:meth:`ArchConfig.smoke`).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 => d_model // n_heads

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    local_window: int = 2048       # window for "local" blocks

    # depth plan: `pattern` tiles across depth; leftover layers take the
    # pattern prefix.  Each maximal run of equal kinds becomes one scanned
    # segment (see models/blocks.py).
    pattern: tuple = ("attn",)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # cross-attention context (vlm) / encoder-decoder (audio)
    n_ctx_tokens: int = 0          # stub modality tokens fed to cross-attn
    encoder_layers: int = 0        # > 0 => enc-dec; encoder runs `pattern`=enc

    # ssm / recurrent
    conv_width: int = 4
    mlstm_chunk: int = 64
    proj_factor: float = 2.0       # xLSTM mLSTM up-projection
    rglru_c: float = 8.0           # Griffin's fixed decay sharpness

    # the paper's technique: binary (XNOR-Net) projections
    quant: str = "none"            # "none" | "xnor"

    # numerics / serving
    dtype: Any = jnp.bfloat16
    kv_cache_dtype: str = "bf16"   # "bf16" | "i8" (fixed-point decode cache)
    kv_i8_scale: float = 32.0      # fixed-point scale for the i8 cache
                                   # (RMS-normed/RoPE'd |k| < ~4; 32 gives
                                   # ~2% rounding)
    block_size: int = 16           # paged KV-cache tokens per block
    prefill_chunk: int = 32        # chunked-prefill piece size (serve)
    fused_decode: str = "auto"     # decode-path kernel fusion (DESIGN.md §18):
                                   # "auto" (fused Pallas kernels on real TPU,
                                   # unfused bit-exact twin elsewhere) |
                                   # "on"/"kernel" | "off"/"ref"; the
                                   # REPRO_FUSED_DECODE env var overrides
    supports_long_context: bool = False
    notes: str = ""

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0, (self.n_heads, self.n_kv_heads)

    # --- depth plan ---------------------------------------------------------
    def layer_kinds(self) -> list[str]:
        reps = -(-self.n_layers // len(self.pattern))
        return list((self.pattern * reps)[: self.n_layers])

    def segments(self) -> list[tuple[str, int]]:
        """Maximal runs of equal block kinds -> scanned segments."""
        segs: list[tuple[str, int]] = []
        for k in self.layer_kinds():
            if segs and segs[-1][0] == k:
                segs[-1] = (k, segs[-1][1] + 1)
            else:
                segs.append((k, 1))
        return segs

    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def encoder_segments(self) -> list[tuple[str, int]]:
        """Encoder depth plan for enc-dec archs ([] otherwise).  Kind names
        are config data here — consumers stay generic over them."""
        return [("enc", self.encoder_layers)] if self.is_encdec() else []

    # --- derived ------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embedding/head shard over 16-way TP
        (standard practice; pad tokens never appear as labels)."""
        return -(-self.vocab // 256) * 256

    def smoke(self, **over) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        scale: dict[str, Any] = dict(
            n_layers=max(2, min(4, len(self.pattern))),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 // self.q_per_kv) if self.q_per_kv <= 4 else 1,
            d_head=16,
            d_ff=128,
            vocab=256,
            local_window=32,
            mlstm_chunk=8,
            block_size=8,
            prefill_chunk=8,
            name=self.name + "-smoke",
        )
        if self.n_experts:
            scale.update(n_experts=4, top_k=min(self.top_k, 2), d_ff_expert=32)
        if self.n_ctx_tokens:
            scale.update(n_ctx_tokens=16)
        if self.encoder_layers:
            scale.update(encoder_layers=2)
        # keep the full pattern so every block kind is exercised
        if len(self.pattern) > 1:
            scale["n_layers"] = len(self.pattern)
        scale.update(over)
        return dataclasses.replace(self, **scale)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Spec rules: long_* only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 524k dense KV decode is the "
                       "quadratic regime sub-quadratic archs exist to avoid "
                       "(DESIGN.md §5)")
    return True, ""
