"""Llama-3.2-11B-Vision [hf:meta-llama; unverified]: cross-attn image layers.

Backbone only (assignment): 40 layers, every 5th a vision cross-attention
layer (8 cross-attn layers over a Llama-3.1-8B-class trunk).  The vision
tower is a STUB: input_specs() feeds precomputed patch embeddings
(n_ctx_tokens x d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    n_ctx_tokens=1600,
    rope_theta=500000.0,
)
