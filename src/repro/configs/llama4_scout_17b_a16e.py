"""Llama-4-Scout-17B-16E [hf:meta-llama; unverified]: MoE 16e top-1.

Early-fusion multimodality is out of the assigned backbone scope (text
backbone only).  Every layer's FFN is a 16-expert top-1 MoE per the
assignment line (d_ff=8192 per expert).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    pattern=("moe",),
    n_experts=16, top_k=1, d_ff_expert=8192,
    rope_theta=500000.0,
)
