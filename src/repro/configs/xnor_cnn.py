"""The paper's own workload as an arch: an XNOR-Net binary-dense image
classifier served through the LM machinery (Fig. 1(c) / §VI, Fig. 6).

Tiny by construction — the full config is already smoke-scale, because the
paper's classifier is a few binary dense layers over 16x16 images.  The
class ids are vocab ids: a request is one QUERY_TOKEN prompt with the
image patches as ctx, ``max_new_tokens=1``, greedy sampling — the emitted
token IS the classification (repro.serve.workloads.ClassifierService).
"""

from repro.models import bcnn  # noqa: F401  (registers the "bindense" kind)
from repro.configs.base import ArchConfig

import jax.numpy as jnp

CONFIG = ArchConfig(
    name="xnor-cnn",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=bcnn.VOCAB,
    pattern=("bindense",),
    n_ctx_tokens=4,                # 16x16 image -> 4 bands of 64 pixels
    quant="xnor",                  # the binary path IS the workload
    dtype=jnp.float32,             # tiny model; exact packed-vs-float logits
    block_size=8,
    prefill_chunk=8,
    notes="XNOR-CNN stripe classifier; bindense kind registered by "
          "repro.models.bcnn",
)
