"""Whisper-tiny [arXiv:2212.04356; unverified]: enc-dec, conv frontend stub.

4-layer encoder over precomputed frame embeddings (the strided-conv audio
frontend is a STUB per the assignment: input_specs() provides
(batch, 1500, d_model) frames), 4-layer decoder with cross-attention.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    pattern=("dec",),
    encoder_layers=4, n_ctx_tokens=1500,
    rope_theta=10000.0,
    notes="enc-dec; decoder cross-attends to 1500 encoder frames.",
)
