"""RecurrentGemma-2B [arXiv:2402.19427; hf]: Griffin RG-LRU + local attn 1:2.

Depth plan (rglru, rglru, local) tiled over 26 layers (tail = 2 rglru).
MQA (kv=1) local attention with a 2048 window; RG-LRU state is
seq-length-independent => long_500k applicable.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, d_head=256,
    pattern=("rglru", "rglru", "local"),
    local_window=2048, conv_width=4, rglru_c=8.0,
    rope_theta=10000.0,
    supports_long_context=True,
)
