"""Qwen3-4B [hf:Qwen/Qwen3-8B family; hf]: dense GQA with qk_norm."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151936, d_head=128,
    qkv_bias=False, qk_norm=True, rope_theta=1e6,
    notes="per-head RMS qk_norm before RoPE (Qwen3).",
)
