"""xLSTM-350M [arXiv:2405.04517; unverified]: sLSTM + mLSTM blocks.

xLSTM[7:1] depth plan: every 8th block is sLSTM, the rest mLSTM
(24 layers = 3 superblocks).  d_ff=0 per assignment: xLSTM blocks carry
their own up/down projections (proj_factor), no separate FFN.
The mLSTM matrix memory is the architectural cousin of the paper's
in-memory analog accumulation (DESIGN.md §8.7).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    pattern=("mlstm",) * 7 + ("slstm",),
    proj_factor=2.0, mlstm_chunk=64, conv_width=4,
    supports_long_context=True,
    notes="O(1)-state per token; long_500k applicable.",
)
