"""Qwen2-7B [arXiv:2407.10671; hf]: dense GQA, QKV bias."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064,
    qkv_bias=True, qk_norm=False, rope_theta=1e6,
    notes="GQA kv=4, QKV bias; d_head=128.",
)
