"""Serving entry point: batched prefill + decode with the resident-state
serve path (container scale uses --smoke reduced configs).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --smoke --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import lm
from repro.train import serve_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    # independent streams for init / prompt / ctx / sampling: reusing one key
    # correlates the generated tokens with the weight init.
    init_key, prompt_key, ctx_key, sample_key = jax.random.split(
        jax.random.PRNGKey(args.seed), 4)
    params = lm.init_params(cfg, init_key)
    prompt = jax.random.randint(prompt_key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    ctx = None
    if cfg.n_ctx_tokens:
        ctx = jax.random.normal(ctx_key, (args.batch, cfg.n_ctx_tokens,
                                          cfg.d_model), jnp.float32) * 0.1

    t0 = time.time()
    out = serve_step.generate(cfg, params, prompt, args.new_tokens, ctx=ctx,
                              temperature=args.temperature,
                              key=sample_key if args.temperature > 0 else None)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print("first row:", out[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
