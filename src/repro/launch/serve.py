"""Serving entry point: the continuous-batching engine over a synthetic
mixed-length request trace (container scale uses --smoke reduced configs).

Requests draw prompt length and token budget independently, so slots free
at staggered times and admission (prefill interleaved with decode) runs
throughout.  Reports aggregate throughput and per-request latency
quantiles; ``--static`` runs the legacy one-batch ``generate`` path
instead, for an A/B on the same machine.

``--prefix-len N`` gives a ``--prefix-frac`` fraction of the trace a
shared N-token leading prefix (the system-prompt regime); the paged
engine's content-addressed prefix cache (DESIGN.md §15) then skips the
shared blocks' prefill and reports hit rate + fresh blocks per request.
``--no-prefix-cache`` A/Bs it off.

``--workload`` picks what to serve (DESIGN.md §16).  ``lm`` (default) is
the synthetic chat trace above; ``transcribe`` streams synthetic audio
through :class:`TranscriptionService` on an enc-dec arch; ``classify``
batches stripe images through :class:`ClassifierService` (defaults to the
paper's xnor-cnn arch, trained in-process).

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b+xnor \
      --smoke --slots 4 --requests 16 --new-tokens 16 --prefix-len 64
  PYTHONPATH=src python -m repro.launch.serve --arch whisper-tiny \
      --smoke --workload transcribe --streams 3 --windows 2
  PYTHONPATH=src python -m repro.launch.serve --arch xnor-cnn \
      --workload classify --requests 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models import lm
from repro.serve import (ClassifierService, ServeEngine,
                         TranscriptionService, synthetic_audio_trace,
                         synthetic_trace)
from repro.train import serve_step


def _run_transcribe(cfg, params, args) -> int:
    """Streaming transcription over synthetic audio (DESIGN.md §16)."""
    svc = TranscriptionService(
        cfg, params, slots=args.slots,
        s_max=args.s_max or 32,
        tokens_per_window=max(2, args.new_tokens),
        temperature=args.temperature, seed=args.seed,
        pack=not args.no_pack)
    streams = synthetic_audio_trace(
        args.streams, args.windows, n_ctx_tokens=cfg.n_ctx_tokens,
        d_model=cfg.d_model, seed=args.seed)
    t0 = time.time()
    transcripts = svc.transcribe(streams)
    dt = time.time() - t0
    total = sum(len(t) for t in transcripts.values())
    print(f"arch={cfg.name} workload=transcribe streams={args.streams} "
          f"windows={args.windows} slots={args.slots}")
    print(f"  {total} transcript tokens in {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s; "
          f"{svc.stats.prefills} window sessions, "
          f"{svc.stats.decode_steps} decode steps)")
    for sid in sorted(transcripts):
        print(f"  stream {sid}: {transcripts[sid][:12]}"
              f"{'...' if len(transcripts[sid]) > 12 else ''}")
    return 0


def _run_classify(cfg, args) -> int:
    """Batched XNOR-CNN classification (DESIGN.md §16, paper Fig. 6)."""
    from repro.models import bcnn

    svc = ClassifierService(cfg=cfg, slots=args.slots,
                            pack=not args.no_pack, seed=args.seed)
    n = max(args.requests, 1)
    imgs, y = bcnn.synthetic_images(jax.random.PRNGKey(args.seed + 1), n)
    t0 = time.time()
    pred = svc.classify(np.asarray(imgs))
    dt = time.time() - t0
    acc = float(np.mean(pred == np.asarray(y)))
    print(f"arch={cfg.name} workload=classify images={n} "
          f"slots={args.slots} packed={not args.no_pack}")
    print(f"  train acc {svc.train_acc:.2f}; serve acc {acc:.2f}; "
          f"{n / max(dt, 1e-9):.1f} images/s "
          f"({svc.stats.prefills} one-shot sessions, "
          f"{svc.stats.decode_steps} decode steps)")
    return 0


def _run_replicated(cfg, params, trace, s_max, args) -> int:
    """The replicated tier (DESIGN.md §17): N engine replicas behind the
    least-loaded router, optional kill-a-replica drill mid-run, encrypted
    migration checkpoints, background integrity scrubbing."""
    import contextlib
    import tempfile

    from repro.serve import Router

    if args.dense:
        raise SystemExit("--replicas > 1 needs the paged layout "
                         "(drop --dense): migration extracts state "
                         "through per-slot block tables")
    with contextlib.ExitStack() as stack:
        ckpt_dir = args.ckpt_dir or stack.enter_context(
            tempfile.TemporaryDirectory(prefix="serve_mig_"))
        router = Router(cfg, params, args.replicas, slots=args.slots,
                        s_max=s_max, ckpt_dir=ckpt_dir,
                        epoch_steps=args.epoch_steps, eos_id=args.eos_id,
                        temperature=args.temperature, seed=args.seed,
                        pack=not args.no_pack, block_size=args.block_size,
                        prefill_chunk=args.prefill_chunk,
                        n_blocks=args.n_blocks,
                        prefix_cache=not args.no_prefix_cache)
        for r in trace:
            router.submit(r)
        rep = router.run(kill_at=args.kill_at or None)
    sr = rep.serve_report()
    lat = sr.latency_quantiles((0.5, 0.95))
    ttft = sr.ttft_quantiles((0.5, 0.95))
    print(f"arch={cfg.name} replicas={args.replicas} "
          f"slots={args.slots}/replica requests={len(trace)} "
          f"kill_at={args.kill_at or '—'}")
    print(f"  generated {rep.generated} tokens in {rep.wall:.2f}s "
          f"-> {rep.tok_per_s:.1f} tok/s across replicas")
    print(f"  latency p50={lat[0.5]*1e3:.0f}ms p95={lat[0.95]*1e3:.0f}ms; "
          f"ttft p50={ttft[0.5]*1e3:.0f}ms p95={ttft[0.95]*1e3:.0f}ms")
    print(f"  migrations: {len(rep.migrations)} "
          f"(killed {rep.killed or 'none'}); "
          f"stragglers observed: {len(rep.straggler_events)}")
    print(f"  scrubber: {rep.scrub_passes} passes, "
          f"{sum(r.scrub_weight_leaves for r in rep.replicas)} weight "
          f"leaves + {sum(r.scrub_idle_blocks for r in rep.replicas)} idle "
          f"blocks verified, {rep.scrub_corruptions} corruptions")
    done = sum(1 for s in rep.sessions.values() if s.done)
    print(f"  completed {done}/{len(trace)}")
    return 0 if done == len(trace) and rep.scrub_corruptions == 0 else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max prompt length in the trace")
    ap.add_argument("--new-tokens", type=int, default=16,
                    help="max per-request token budget in the trace")
    ap.add_argument("--s-max", type=int, default=0,
                    help="resident cache capacity (0: prompt+new)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--no-pack", action="store_true",
                    help="serve quant archs from float weights (A/B)")
    ap.add_argument("--static", action="store_true",
                    help="legacy static-batch generate() instead")
    ap.add_argument("--dense", action="store_true",
                    help="slot-dense KV layout instead of block-paged (A/B)")
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged-KV tokens per block (0: cfg.block_size)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill piece size (0: cfg.prefill_chunk)")
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="shared block-pool size (0: slots x full tables)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable content-addressed prefix caching (A/B)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared leading tokens in the trace (0: none)")
    ap.add_argument("--prefix-frac", type=float, default=0.9,
                    help="fraction of requests opening with the shared "
                         "prefix (with --prefix-len)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve replicas (>1: the replicated tier with "
                         "least-loaded routing and live migration, §17)")
    ap.add_argument("--kill-at", type=int, default=0,
                    help="router step of the kill-a-replica drill "
                         "(0: no drill; needs --replicas > 1)")
    ap.add_argument("--epoch-steps", type=int, default=8,
                    help="integrity-scrubber cadence in router steps "
                         "(0: off; --replicas > 1)")
    ap.add_argument("--ckpt-dir", default="",
                    help="migration checkpoint directory (default: a "
                         "temp dir; --replicas > 1)")
    ap.add_argument("--workload", choices=("lm", "transcribe", "classify"),
                    default="lm",
                    help="what to serve: chat trace, streaming "
                         "transcription, or image classification")
    ap.add_argument("--streams", type=int, default=3,
                    help="audio streams (--workload transcribe)")
    ap.add_argument("--windows", type=int, default=2,
                    help="windows per stream (--workload transcribe)")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    if args.workload == "classify":
        return _run_classify(cfg, args)

    init_key, _ = jax.random.split(jax.random.PRNGKey(args.seed))
    params = lm.init_params(cfg, init_key)

    if args.workload == "transcribe":
        return _run_transcribe(cfg, params, args)
    pl = max(4, args.prompt_len)
    nt = max(2, args.new_tokens)
    trace = synthetic_trace(
        args.requests, cfg.vocab, seed=args.seed,
        prompt_lens=tuple(sorted({max(2, pl // 4), max(3, pl // 2), pl})),
        new_tokens=tuple(sorted({max(2, nt // 2), nt})),
        n_ctx_tokens=cfg.n_ctx_tokens, d_model=cfg.d_model,
        prefix_frac=args.prefix_frac, prefix_len=args.prefix_len)
    s_max = args.s_max or (args.prefix_len + pl + nt)

    if args.static:
        # the TRUE legacy path (generate_static, not the engine wrapper):
        # one fixed batch, uniform shapes, eager per-token dispatch.
        # independent streams for prompt / ctx / sampling, per the PR-2 fix
        # (one shared key correlates generated tokens with the inputs).
        prompt_key, ctx_key, sample_key = jax.random.split(
            jax.random.PRNGKey(args.seed + 1), 3)
        prompt = jax.random.randint(prompt_key, (args.slots, pl), 0,
                                    cfg.vocab)
        ctx = None
        if cfg.n_ctx_tokens:
            ctx = jax.random.normal(
                ctx_key, (args.slots, cfg.n_ctx_tokens, cfg.d_model),
                jnp.float32) * 0.1
        t0 = time.time()
        out = serve_step.generate_static(
            cfg, params, prompt, nt, ctx=ctx, temperature=args.temperature,
            key=sample_key if args.temperature > 0 else None)
        dt = time.time() - t0
        print(f"arch={cfg.name} static generate {out.shape} in {dt:.2f}s "
              f"({args.slots * nt / dt:.1f} tok/s)")
        return 0

    if args.replicas > 1:
        return _run_replicated(cfg, params, trace, s_max, args)

    eng = ServeEngine(cfg, params, slots=args.slots, s_max=s_max,
                      eos_id=args.eos_id, temperature=args.temperature,
                      seed=args.seed, pack=not args.no_pack,
                      paged=not args.dense, block_size=args.block_size,
                      prefill_chunk=args.prefill_chunk,
                      n_blocks=args.n_blocks,
                      prefix_cache=not args.no_prefix_cache)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    lat = report.latency_quantiles((0.5, 0.95))
    ttft = report.ttft_quantiles((0.5, 0.95))
    qwait = report.queue_wait_quantiles((0.5, 0.95))
    packed = (not args.no_pack) and cfg.quant == "xnor"
    print(f"arch={cfg.name} slots={args.slots} requests={len(trace)} "
          f"packed={packed} layout={'dense' if args.dense else 'paged'}")
    print(f"  generated {report.generated} tokens in {report.wall:.2f}s "
          f"-> {report.tok_per_s:.1f} tok/s "
          f"({report.prefills} prefills, {report.decode_steps} decode steps)")
    print(f"  latency p50={lat[0.5]*1e3:.0f}ms p95={lat[0.95]*1e3:.0f}ms")
    # queue-wait is the scheduling share of TTFT (time spent waiting for a
    # slot / for blocks); the remainder is prefill compute — reported
    # separately so backpressure and compute cost are distinguishable
    print(f"  ttft    p50={ttft[0.5]*1e3:.0f}ms p95={ttft[0.95]*1e3:.0f}ms "
          f"(queue-wait p50={qwait[0.5]*1e3:.0f}ms "
          f"p95={qwait[0.95]*1e3:.0f}ms)")
    st = report.stats
    if not args.dense and st.blocks_total:
        print(f"  blocks: peak {st.blocks_peak}/{st.blocks_total} "
              f"mean {st.blocks_mean:.1f} "
              f"(util {st.block_utilization:.0%}); "
              f"prefill traces {st.prefill_traces} "
              f"({st.prefill_chunks} chunks)")
        # hit rate = prompt tokens whose prefill was skipped via cached
        # blocks; blocks/request = fresh allocations per admission (shared
        # blocks are mapped, not allocated)
        print(f"  prefix cache: "
              f"{'on' if eng.prefix_caching else 'off'}; "
              f"hit rate {st.prefix_hit_rate:.0%} "
              f"({st.prefix_hits}/{st.prefills} prompts, "
              f"{st.prefix_tokens}/{st.prompt_tokens} tokens), "
              f"{st.blocks_per_request:.2f} fresh blocks/request, "
              f"{st.cow_copies} cow, {st.prefix_evictions} evictions")
    done = sum(1 for s in report.sessions.values() if s.done)
    first = trace[0]
    print(f"  completed {done}/{len(trace)}; first request tokens: "
          f"{np.asarray(report.tokens(first.rid))[:8].tolist()}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
