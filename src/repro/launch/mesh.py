"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization.

Topology model (TPU v5e-class):
  single pod:  (16, 16)    axes (data, model)   = 256 chips
  multi pod:   (2, 16, 16) axes (pod, data, model) = 512 chips
"model" is the innermost axis (fastest ICI neighborhood); "pod" is the
slow DCN-class axis that the 1-bit gradient compression targets.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.x; Auto is that jax's only behavior
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_engine_mesh(n_devices: int | None = None):
    """1-D mesh over the host's devices, axis ``bank``.

    The sharded CiM engine's mesh-as-outer-bank-dimension model
    (DESIGN.md §11): every device carries one local bank stack, so the
    engine's throughput tier is ``devices x banks x cols`` bits/cycle.
    Takes the first ``n_devices`` devices (all by default) — unlike the
    production meshes this axis has no topology constraint, engine traffic
    is embarrassingly parallel except for the 512-byte digest reduce.
    """
    devs = jax.devices()
    n = n_devices or len(devs)
    if n > len(devs):
        raise ValueError(f"asked for {n} devices, host has {len(devs)}")
    return Mesh(np.asarray(devs[:n]), ("bank",))


def make_replica_meshes(n_replicas: int):
    """Partition the host's devices into one 1-D ``bank`` sub-mesh per
    serve replica (DESIGN.md §17).

    With at least one device per replica each sub-mesh gets a disjoint
    contiguous slice of ``len(devices) // n_replicas`` devices (the
    remainder stays unused — equal-width replicas keep the straggler
    policy's per-step timing comparable); with fewer devices than replicas
    the sub-meshes wrap round-robin and replicas share.  The router pins
    each replica's programs to its sub-mesh's first device, so under the
    8-virtual-device CI mode replicas genuinely run side by side.
    """
    if n_replicas < 1:
        raise ValueError(f"need at least one replica, got {n_replicas}")
    devs = jax.devices()
    if len(devs) >= n_replicas:
        per = len(devs) // n_replicas
        slices = [devs[i * per:(i + 1) * per] for i in range(n_replicas)]
    else:
        slices = [[devs[i % len(devs)]] for i in range(n_replicas)]
    return [Mesh(np.asarray(s), ("bank",)) for s in slices]


def make_smoke_mesh(n_devices: int | None = None):
    """Tiny mesh for CPU-scale distributed tests (e.g. 8 = 2x2x2)."""
    n = n_devices or len(jax.devices())
    if n >= 8:
        shape, axes = (2, 2, n // 4), ("pod", "data", "model")
    elif n >= 4:
        shape, axes = (2, n // 2), ("data", "model")
    else:
        shape, axes = (1, n), ("data", "model")
    return _make_mesh(shape, axes)
