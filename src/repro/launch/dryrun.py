"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
and extract memory / cost / collective analyses — the proof that the
distribution config is coherent without real hardware.

MUST be the first two lines, before any other import (jax locks the device
count at first init):
"""
import os  # noqa: E402
# Drop any inherited device-count flag first: XLA takes the LAST occurrence,
# so appending the ambient XLA_FLAGS (e.g. the 8-device CI job's) verbatim
# would silently override the 512-device grid this driver needs.
os.environ["XLA_FLAGS"] = " ".join(
    ["--xla_force_host_platform_device_count=512"]
    + [f for f in os.environ.get("XLA_FLAGS", "").split()
       if not f.startswith("--xla_force_host_platform_device_count")])

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

import repro.configs as configs                    # noqa: E402
from repro.configs.base import SHAPES, shape_applicable  # noqa: E402
from repro.distributed import sharding             # noqa: E402
from repro.distributed.ctx import activation_rules  # noqa: E402
from repro.launch import mesh as mesh_mod          # noqa: E402
from repro.models import lm                        # noqa: E402
from repro.roofline import analysis                # noqa: E402
from repro.train import train_step as train_mod    # noqa: E402


# ---------------------------------------------------------------------------
# input specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg, shape) -> dict:
    """Abstract inputs for one (arch, shape) cell — no device allocation."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
               "labels": jax.ShapeDtypeStruct((b, s), i32)}
    elif shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode: one new token against a seq_len-deep cache
        out = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.n_ctx_tokens and shape.kind != "decode":
        out["ctx"] = jax.ShapeDtypeStruct(
            (b, cfg.n_ctx_tokens, cfg.d_model), jnp.float32)
    return out


def default_q_chunk(cfg, shape, unroll: bool = False) -> int:
    if shape.kind in ("train", "prefill") and shape.seq_len > 8192:
        # unrolled roofline runs use few big chunks (exact costs, bounded
        # HLO size); scan runs use small chunks (bounded VMEM claim).
        return shape.seq_len // 4 if unroll else 2048
    return 0


# ---------------------------------------------------------------------------
# cell builders: (fn, args, in_shardings, out_shardings, donate)
# ---------------------------------------------------------------------------

def build_cell(cfg, shape, mesh, *, rules=None, kv_shard="auto",
               q_chunk=None, microbatch=1, grad_compress="none",
               unroll=False, acc_bf16=False, fsdp_pods=False):
    rules = rules or dict(sharding.DEFAULT_RULES)
    if fsdp_pods and "pod" in mesh.axis_names:
        # ZeRO-3 across BOTH pod and data axes: halves the per-chip
        # param/grad/optimizer floor at the cost of inter-pod (DCN-class)
        # weight all-gathers per layer.
        rules["fsdp"] = ("pod", "data")
    if rules.get("fsdp") == "off":
        # serving configuration: no FSDP — params replicated over the data
        # axis (TP-only sharding).  Kills per-layer weight all-gathers; at
        # inference there is no optimizer state so the memory cost is just
        # params/TP per chip.
        rules["fsdp"] = None
    ba = sharding.batch_axes(mesh, shape.global_batch)
    tp_size = mesh.shape[rules["tp"]]
    if kv_shard == "auto":
        # TP over KV heads when they divide the model axis, else
        # sequence-parallel cache (seq_len always divides).
        kv_shard = "heads" if cfg.n_kv_heads % tp_size == 0 else "seq"
    qc = default_q_chunk(cfg, shape, unroll) if q_chunk is None else q_chunk
    ins = input_specs(cfg, shape)
    has_ctx = "ctx" in ins

    if shape.kind == "train":
        state = train_mod.abstract_state(cfg)
        sspec = train_mod.state_pspecs(cfg, rules)
        bspec = sharding.data_specs(mesh, shape.global_batch, has_ctx)
        step = jax.ShapeDtypeStruct((), jnp.int32)

        def fn(state, batch, step):
            return train_mod.train_step(
                cfg, state, batch, step, q_chunk=qc, microbatch=microbatch,
                grad_compress=grad_compress, mesh=mesh, rules=rules,
                unroll=unroll,
                acc_dtype=jnp.bfloat16 if acc_bf16 else jnp.float32)

        metrics_spec = {k: P() for k in
                        ("ce", "aux", "tokens", "loss", "gnorm", "lr")}
        in_sh = (sharding.tree_named(mesh, sspec),
                 sharding.tree_named(mesh, bspec),
                 NamedSharding(mesh, P()))
        out_sh = (sharding.tree_named(mesh, sspec),
                  sharding.tree_named(mesh, metrics_spec))
        args = (state, ins | {}, step)
        tokens = shape.global_batch * shape.seq_len
        mf = lm.model_flops(cfg, "train", tokens)
        return fn, args, in_sh, out_sh, (0,), mf

    params = lm.abstract_params(cfg)
    pspec = lm.param_pspecs(cfg, rules)

    if shape.kind == "prefill":
        def fn(params, batch):
            logits, state = lm.prefill(cfg, params, batch["tokens"],
                                       batch.get("ctx"), s_max=shape.seq_len,
                                       q_chunk=qc, unroll=unroll)
            return logits, state

        bspec = {"tokens": P(ba, None)}
        if has_ctx:
            bspec["ctx"] = P(ba, None, None)
        st_spec = lm.decode_state_pspecs(cfg, ba, kv_shard, tp_size)
        in_sh = (sharding.tree_named(mesh, pspec),
                 sharding.tree_named(mesh, bspec))
        out_sh = (NamedSharding(mesh, P(ba, None, rules["tp"])),
                  sharding.tree_named(mesh, st_spec))
        args = (params, ins)
        tokens = shape.global_batch * shape.seq_len
        mf = lm.model_flops(cfg, "prefill", tokens)
        return fn, args, in_sh, out_sh, (), mf

    # decode: one token against a resident state of depth seq_len
    state = lm.decode_state_spec(cfg, shape.global_batch, shape.seq_len,
                                 abstract=True)
    st_spec = lm.decode_state_pspecs(cfg, ba, kv_shard, tp_size)

    def fn(params, token, state):
        return lm.decode_step(cfg, params, token, state, unroll=unroll)

    in_sh = (sharding.tree_named(mesh, pspec),
             NamedSharding(mesh, P(ba, None)),
             sharding.tree_named(mesh, st_spec))
    out_sh = (NamedSharding(mesh, P(ba, None, rules["tp"])),
              sharding.tree_named(mesh, st_spec))
    args = (params, ins["tokens"], state)
    mf = lm.model_flops(cfg, "decode", shape.global_batch)
    return fn, args, in_sh, out_sh, (2,), mf


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, **overrides) -> dict:
    import dataclasses
    cfg = configs.get(arch)
    kv_dtype = overrides.pop("kv_dtype", None)
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    cap = overrides.pop("capacity_factor", None)
    if cap:
        cfg = dataclasses.replace(cfg, capacity_factor=cap)
    mlstm_chunk = overrides.pop("mlstm_chunk", None)
    if mlstm_chunk:
        cfg = dataclasses.replace(cfg, mlstm_chunk=mlstm_chunk)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh, out_sh, donate, model_flops = build_cell(
        cfg, shape, mesh, **overrides)

    rules = overrides.get("rules") or dict(sharding.DEFAULT_RULES)
    act_rules = {"batch": sharding.batch_axes(mesh, shape.global_batch),
                 "tp": rules["tp"], "ep": rules["ep"]}
    t0 = time.time()
    with mesh, activation_rules(act_rules):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # MODEL_FLOPS is global; roofline terms are per chip
    roof = analysis.roofline(compiled, model_flops=model_flops / mesh.size)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": mesh.size,
        "status": "ok",
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "params": lm.param_count(cfg),
        "active_params": lm.active_param_count(cfg),
        "overrides": {k: str(v) for k, v in overrides.items()},
        **roof,
    }


ALL_SHAPES = list(SHAPES)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=ALL_SHAPES)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--kv-shard", default="auto",
                    choices=["auto", "heads", "seq"])
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "onebit_pod"])
    ap.add_argument("--kv-dtype", default=None, choices=[None, "bf16", "i8"])
    ap.add_argument("--acc-bf16", action="store_true",
                    help="bf16 microbatch gradient accumulator")
    ap.add_argument("--fsdp-pods", action="store_true",
                    help="shard params/optimizer over pod axis too (ZeRO-3 "
                         "across pods)")
    ap.add_argument("--fsdp-off", action="store_true",
                    help="serving config: replicate params over data axis "
                         "(TP-only)")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--mlstm-chunk", type=int, default=None)
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer stacks for exact cost/collective "
                         "analysis (roofline runs); scan is the compile-"
                         "time-friendly default for the multi-pod proof")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(configs.ALL)
    shapes = [args.shape] if args.shape else ALL_SHAPES
    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes[args.mesh]:
                tagsuf = f"_{args.tag}" if args.tag else ""
                out_path = os.path.join(
                    args.out, f"{arch}_{shape_name}_"
                    f"{'multi' if mp else 'single'}{tagsuf}.json")
                try:
                    res = run_cell(arch, shape_name, mp,
                                   kv_shard=args.kv_shard,
                                   q_chunk=args.q_chunk,
                                   microbatch=args.microbatch,
                                   grad_compress=args.grad_compress,
                                   unroll=args.unroll,
                                   kv_dtype=args.kv_dtype,
                                   capacity_factor=args.capacity_factor,
                                   mlstm_chunk=args.mlstm_chunk,
                                   acc_bf16=args.acc_bf16,
                                   fsdp_pods=args.fsdp_pods,
                                   rules=(dict(sharding.DEFAULT_RULES,
                                               fsdp="off")
                                          if args.fsdp_off else None))
                except Exception as e:  # a failing cell is a bug: report it
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                with open(out_path, "w") as f:
                    json.dump(res, f, indent=2)
                line = (f"{res['status']:8s} {arch} {shape_name} "
                        f"{res['mesh']}")
                if res["status"] == "ok":
                    line += (f"  bottleneck={res['bottleneck']}"
                             f" t=({res['t_compute_s']*1e3:.1f},"
                             f"{res['t_memory_s']*1e3:.1f},"
                             f"{res['t_collective_s']*1e3:.1f})ms"
                             f" compile={res['t_compile_s']:.0f}s")
                elif res["status"] == "error":
                    line += "  " + res["error"][:120]
                print(line, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
