"""Training entry point.

Two modes:
  * default — actually run the training loop at whatever scale the current
    backend supports (CPU container: use --smoke for a reduced config).
  * --dry   — lower+compile only, on the production mesh (see dryrun.py for
    the batch version over all cells).

Fault tolerance is on by default: checkpoints every --save-every steps with
XOR-parity verification (+ optional --encrypt-key), resume-from-latest on
start, straggler watermarking via distributed.fault.Runner.

Example (container scale):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 60 --batch 8 --seq 64 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data.synthetic import Pipeline
from repro.distributed import fault
from repro.models import lm
from repro.train import train_step as train_mod


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--quant", default=None, choices=[None, "xnor"],
                    help="binary (XNOR-Net) projections — the paper's mode")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--encrypt-key", default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.quant:
        import dataclasses
        cfg = dataclasses.replace(cfg, quant=args.quant)
    print(f"arch={cfg.name} params={lm.param_count(cfg)/1e6:.2f}M "
          f"active={lm.active_param_count(cfg)/1e6:.2f}M quant={cfg.quant}")

    pipe = Pipeline(cfg, args.batch, args.seq, seed=args.seed)

    runner = None
    start_step = 0
    state = None
    if args.ckpt_dir:
        runner = fault.Runner(args.ckpt_dir, save_every=args.save_every,
                              root_key=args.encrypt_key)
        like = train_mod.abstract_state(cfg)
        state, start_step = runner.resume_or_init(
            like, lambda: train_mod.init_state(cfg, jax.random.PRNGKey(args.seed)))
        if start_step:
            print(f"resumed from checkpoint @ step {start_step}")
    if state is None or start_step == 0:
        state = train_mod.init_state(cfg, jax.random.PRNGKey(args.seed))

    @jax.jit
    def step_fn(state, batch, step):
        return train_mod.train_step(cfg, state, batch, step,
                                    peak_lr=args.lr, warmup=args.warmup,
                                    total=args.steps,
                                    microbatch=args.microbatch)

    losses = []
    for step in range(start_step, args.steps):
        t0 = time.time()
        host_batch = pipe.get(step)
        batch = jax.tree.map(jnp.asarray, host_batch)
        state, metrics = step_fn(state, batch, jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        if runner:
            verdict = runner.observe_step(step, dt)
            if verdict != "ok":
                print(f"[fault] step {step}: {verdict}")
            runner.maybe_save(step + 1, state)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['gnorm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")

    first = np.mean(losses[:5]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-5:])
    print(f"loss: first~{first:.4f} last~{last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
