"""Three-term roofline from a compiled (unexecuted) XLA artifact.

  compute term    = HLO_FLOPs_per_chip / PEAK_FLOPS
  memory term     = HLO_bytes_per_chip / HBM_BW
  collective term = sum over collectives of per-chip link bytes / ICI_BW

FLOPs/bytes come from ``compiled.cost_analysis()`` (the SPMD module is the
per-device program, so its numbers are already per chip).  Collective bytes
are not in cost_analysis: we parse the optimized HLO text and apply ring
factors per op kind (DESIGN.md §9).

Hardware constants (TPU v5e-class, per chip) are module-level so §Perf can
sweep them.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12   # bf16
HBM_BW = 819e9        # bytes/s
ICI_BW = 50e9         # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

# result-size multipliers per op for per-chip ring traffic, as a function of
# group size n:  bytes_moved = factor(n) * result_bytes
_FACTORS = {
    "all-reduce":         lambda n: 2.0 * (n - 1) / n,
    "all-gather":         lambda n: (n - 1) / n,       # result is gathered
    "reduce-scatter":     lambda n: float(n - 1),      # result is the shard
    "all-to-all":         lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}

_OP_RE = re.compile(
    r"=\s+([a-z0-9_]+\[[^\]]*\](?:\{[^}]*\})?"            # result shape
    r"|\([^=]*?\))\s+"                                     # or tuple shape
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))     # [num_groups, group_size]
    return 1


@dataclass
class CollectiveStats:
    per_op: dict = field(default_factory=dict)   # kind -> (count, bytes)
    total_bytes: float = 0.0

    def add(self, kind: str, nbytes: float):
        c, b = self.per_op.get(kind, (0, 0.0))
        self.per_op[kind] = (c + 1, b + nbytes)
        self.total_bytes += nbytes


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-chip link bytes summed over every collective in the module."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue
        n = _group_size(line)
        if n <= 1:
            continue
        nbytes = _shape_bytes(shape_str) * _FACTORS[kind](n)
        stats.add(kind, nbytes)
    return stats


def roofline(compiled, model_flops: float | None = None) -> dict:
    """Derive the three terms + bottleneck from a compiled artifact."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    ma = compiled.memory_analysis()
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    stats = collective_bytes(compiled.as_text())

    t_compute = hlo_flops / PEAK_FLOPS
    t_memory = hlo_bytes / HBM_BW
    t_collective = stats.total_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    bottleneck = max(terms, key=terms.get)
    out = {
        "hlo_flops_per_chip": hlo_flops,
        "hlo_bytes_per_chip": hlo_bytes,
        "collective_bytes_per_chip": stats.total_bytes,
        "collectives": {k: {"count": c, "bytes": b}
                        for k, (c, b) in sorted(stats.per_op.items())},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": bottleneck,
        "bound_time_s": max(terms.values()),
        "memory_analysis": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes": (ma.argument_size_in_bytes
                           + ma.temp_size_in_bytes),
        },
    }
    if model_flops:
        out["model_flops_per_chip"] = model_flops
        out["useful_flops_frac"] = (model_flops / hlo_flops
                                    if hlo_flops else 0.0)
        # roofline fraction: useful work per chip over the machine-bound time
        out["roofline_frac"] = (model_flops / PEAK_FLOPS
                                / max(max(terms.values()), 1e-30))
    return out


def dispatch_count(jaxpr) -> int:
    """Primitive dispatches in a traced program (jaxpr or ClosedJaxpr).

    Call-like primitives (pjit, scan bodies, cond branches, ...) are
    descended into — they are program structure, not dispatches — while a
    ``pallas_call`` counts as exactly one: the whole fused kernel is a
    single device dispatch regardless of how much work its body folds in.
    This is the metric behind the "fused decode is one dispatch where the
    chain was N" CI gate (the unfused gather/mask/softmax/PV chain counts
    its gather, einsums, reductions and elementwise stages individually).
    """
    if hasattr(jaxpr, "jaxpr"):          # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
            continue
        subs = []
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            subs += [s for s in vs
                     if hasattr(s, "eqns") or hasattr(s, "jaxpr")]
        n += sum(dispatch_count(s) for s in subs) if subs else 1
    return n


def decode_roofline_bytes(*, param_bytes: int, widths: dict,
                          layers_per_class: dict, slots: int,
                          block_size: int, n_kv_heads: int, d_head: int,
                          kv_itemsize: int, io_bytes: int = 0) -> int:
    """Analytic minimum HBM bytes for one paged decode step.

    A decode step cannot move less than: every live parameter byte once
    (batch=slots shares one weight read), plus one streaming pass over the
    table-addressed K/V working set — per paged layer, ``slots`` tables of
    ``W`` blocks of ``block_size x n_kv_heads x d_head`` elements, K and V
    (the x2).  ``io_bytes`` covers tokens/logits/state I/O (small).  The
    achieved/roofline ratio reported by the serve benchmarks compares the
    compiled program's ``bytes accessed`` against this floor — gather
    materialization, score round-trips and scatter copies all show up as
    achieved bytes above it.
    """
    kv = 0
    for cls, w in widths.items():
        kv += (layers_per_class.get(cls, 0) * slots * w * block_size
               * n_kv_heads * d_head * kv_itemsize * 2)
    return int(param_bytes + kv + io_bytes)


def format_row(name: str, r: dict) -> str:
    mf = r.get("roofline_frac")
    return (f"| {name} | {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f}"
            f" | {r['t_collective_s']*1e3:.2f} | {r['bottleneck']}"
            f" | {r.get('useful_flops_frac', 0) * 100:.0f}%"
            f" | {(mf or 0) * 100:.1f}% |")
