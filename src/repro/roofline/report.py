"""Render EXPERIMENTS.md tables from experiments/dryrun JSON artifacts."""

from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir="experiments/dryrun", tag=None):
    cells = {}
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        name = os.path.basename(p)[:-5]
        parts = name.split("_")
        r = json.load(open(p))
        t = None
        if tag is not None:
            if not name.endswith("_" + tag):
                continue
        elif len(parts) > 3 and parts[-1] not in ("single", "multi"):
            continue  # tagged variant; baseline table only
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def dryrun_table(cells, mesh="multi") -> str:
    lines = ["| arch | shape | status | devices | params | per-chip peak mem"
             " | compile |",
             "|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh:
            continue
        if r["status"] == "ok":
            ma = r["memory_analysis"]
            lines.append(
                f"| {arch} | {shape} | ok | {r['n_devices']} "
                f"| {r['params']/1e9:.2f}B "
                f"| {ma['peak_bytes']/2**30:.2f} GiB "
                f"| {r['t_compile_s']:.0f}s |")
        elif r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | skip (long-ctx n/a) | — | — |"
                         " — | — |")
        else:
            lines.append(f"| {arch} | {shape} | **ERROR** | — | — | — | — |")
    return "\n".join(lines)


def roofline_table(cells, mesh="single") -> str:
    lines = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) "
             "| bottleneck | useful HLO-FLOP frac | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh or r["status"] != "ok":
            continue
        lines.append(
            f"| {arch} | {shape} | {r['t_compute_s']*1e3:.1f} "
            f"| {r['t_memory_s']*1e3:.1f} | {r['t_collective_s']*1e3:.1f} "
            f"| {r['bottleneck']} | {r.get('useful_flops_frac', 0):.2f} "
            f"| {r.get('roofline_frac', 0)*100:.1f}% |")
    return "\n".join(lines)


def serve_decode_header() -> str:
    """Header for :func:`serve_decode_row` tables."""
    return ("| decode path | achieved bytes | roofline bytes | % of peak "
            "| dispatches |\n|---|---|---|---|---|")


def serve_decode_row(name: str, r: dict) -> str:
    """One serve-decode roofline line: achieved vs. analytic-minimum bytes.

    ``r`` is an ``analysis.roofline`` dict augmented with ``roofline_bytes``
    (from ``analysis.decode_roofline_bytes``) and optionally ``dispatches``.
    "% of peak" is roofline/achieved — 100% means the program moves exactly
    the analytic floor.  Both serve benchmarks render through here so the
    achieved-vs-roofline columns in BENCH_serve.json and the human tables
    can never drift apart.
    """
    achieved = float(r.get("hlo_bytes_per_chip", 0.0))
    floor = float(r.get("roofline_bytes", 0.0))
    pct = 100.0 * floor / achieved if achieved else 0.0
    disp = r.get("dispatches")
    return (f"| {name} | {achieved:.3e} | {floor:.3e} | {pct:.1f}% "
            f"| {disp if disp is not None else '—'} |")


def summarize(cells):
    by = defaultdict(int)
    for r in cells.values():
        by[r["status"]] += 1
    return dict(by)


if __name__ == "__main__":
    import sys
    tag = sys.argv[1] if len(sys.argv) > 1 else None
    cells = load(tag=tag)
    print(summarize(cells))
    print(dryrun_table(cells))
    print()
    print(roofline_table(cells))
