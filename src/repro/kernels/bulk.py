"""Pallas TPU kernel: bulk bitwise XOR/XNOR over packed uint32 tiles.

The digital-equivalent form of the paper's banked single-cycle engine
(DESIGN.md §10): each grid step is one "bank cycle" — a (br, D) tile of
packed operand words is XORed lane-parallel, br*D*32 bit-ops per step.
HBM traffic is two reads + one write of the payload; there is no reduction
and no cross-tile dependency, so the kernel streams at memory bandwidth —
the TPU analogue of every bank sensing one row-pair per cycle.

XNOR is the complementary output rail of the same datapath (paper
Fig. 2(d)): the kernel inverts in-register, still one pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref, *, invert: bool):
    x = a_ref[...] ^ b_ref[...]                        # (br, D) uint32
    o_ref[...] = ~x if invert else x


@functools.partial(jax.jit, static_argnames=("invert", "br", "interpret"))
def bulk_xor(a: jnp.ndarray, b: jnp.ndarray, *, invert: bool = False,
             br: int = 512, interpret: bool = False) -> jnp.ndarray:
    """Elementwise XOR (or XNOR with ``invert=True``) of (R, D) uint32 tiles.

    R % br == 0 (ops.bulk_op pads flat buffers; XOR pad words are sliced off
    by the caller, so the pad value never matters).
    """
    r, d = a.shape
    assert a.shape == b.shape and r % br == 0, (a.shape, b.shape, br)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_kernel, invert=invert),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.uint32),
        interpret=interpret,
    )(a, b)
