"""Pure-jnp oracles for every Pallas kernel in :mod:`repro.kernels`.

Each function is the semantic ground truth the kernels are tested against
(``tests/test_kernels.py`` sweeps shapes/dtypes and asserts allclose /
bit-exact equality).  They are also the *production fallback* on non-TPU
backends: ``ops.py`` dispatches here whenever the Pallas path is unavailable,
so the whole framework (including the 512-device dry-run on CPU) runs the
same semantics everywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack

GOLDEN = np.uint32(0x9E3779B9)  # numpy scalar: folds into Pallas kernels


# ---------------------------------------------------------------------------
# XNOR-popcount GEMM
# ---------------------------------------------------------------------------

def xnor_gemm(pa: jnp.ndarray, pb: jnp.ndarray, valid_k: int) -> jnp.ndarray:
    """Binary (±1) matmul in the packed domain.

    ``pa``: (M, Kw) uint32 bit-planes, ``pb``: (N, Kw) uint32 bit-planes.
    Returns (M, N) int32 with ``out[m, n] = sum_k a[m, k] * b[n, k]`` over the
    first ``valid_k`` (unpacked, ±1) positions.  Padding bits must be equal in
    both operands (``bitpack.pad_to_word`` pads with +1): each padded slot
    XORs to 0, so ``dot_padded = K_pad - 2*popcount`` and the wrapper removes
    the pad contribution by using ``valid_k`` instead of ``K_pad``.
    """
    x = jnp.bitwise_xor(pa[:, None, :], pb[None, :, :])
    popc = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    return jnp.int32(valid_k) - 2 * popc


def xnor_dot_float(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Float-domain equivalence oracle: sign(a) @ sign(b).T."""
    sa = jnp.where(a >= 0, 1.0, -1.0)
    sb = jnp.where(b >= 0, 1.0, -1.0)
    return (sa @ sb.T).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fused sign-extract + pack + alpha
# ---------------------------------------------------------------------------

def pack(x: jnp.ndarray):
    """(M, K) -> ((M, K/32) uint32, (M,) f32 alpha = mean|x|)."""
    return bitpack.pack_bits(x), jnp.mean(jnp.abs(x), axis=-1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Fused paged-decode attention (kernels/paged_attn.py, DESIGN.md §18)
# ---------------------------------------------------------------------------

def paged_decode(q: jnp.ndarray, ck: jnp.ndarray, cv: jnp.ndarray,
                 table: jnp.ndarray, pos: jnp.ndarray, *, window: int = 0,
                 scale: float = 1.0, out_scale: float = 1.0) -> jnp.ndarray:
    """One-shot masked-softmax oracle for the fused paged decode kernel.

    Same semantics as the C == 1 path of ``models/attention.py::
    paged_attention`` after the scatter: gather the pool through the block
    table, score, mask (monotone or window-ring), softmax, PV.  ``q`` is
    (B, KV, G, dh); ``ck``/``cv`` are the (n_blocks, KV, bs, dh) pool
    (any dtype incl. int8 — decoded to f32 here, the fixed-point factors
    arrive folded into ``scale``/``out_scale``); returns (B, KV, G, dh)
    in q.dtype.
    """
    b, kv, g, dh = q.shape
    bs = ck.shape[2]
    cap = table.shape[1] * bs
    gk = jnp.moveaxis(ck[table], 1, 2).reshape(b, kv, cap, dh)
    gv = jnp.moveaxis(cv[table], 1, 2).reshape(b, kv, cap, dh)
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   gk.astype(jnp.float32)) * scale
    kslot = jnp.arange(cap, dtype=jnp.int32)
    p = pos[:, None]
    if window:
        age = (p % cap - kslot[None]) % cap
        valid = age < jnp.minimum(window, p + 1)
    else:
        valid = kslot[None] <= p
    s = jnp.where(valid[:, None, None, :], s, jnp.float32(-1e30))
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, gv.astype(jnp.float32))
    return (out * out_scale).astype(q.dtype)


# ---------------------------------------------------------------------------
# Fused prepacked XNOR linear (binarize + popcount GEMM + alpha/beta epilogue)
# ---------------------------------------------------------------------------

def xnor_linear_fused(x: jnp.ndarray, pb: jnp.ndarray, beta: jnp.ndarray,
                      valid_k: int) -> jnp.ndarray:
    """Oracle for the fused packed linear: the exact unfused chain.

    ``x``: (M, K) activations, ``pb``: (N, Kw) prepacked weight bit-planes,
    ``beta``: (N,) weight scales.  Returns (M, N) f32 =
    (valid_k - 2*popcount) * alpha * beta with alpha = mean|x| per row —
    bit-for-bit what binarize -> xnor_gemm -> scale produces unfused
    (alpha stays in x.dtype exactly as the layer computes it).
    """
    alpha = jnp.mean(jnp.abs(x), axis=-1)
    pa = bitpack.pack_bits(bitpack.pad_to_word(x))
    dots = xnor_gemm(pa, pb, valid_k).astype(jnp.float32)
    return dots * alpha[:, None] * beta[None, :]


# ---------------------------------------------------------------------------
# Bulk XOR/XNOR (the banked engine's row-pair cycle, DESIGN.md §10)
# ---------------------------------------------------------------------------

def bulk_xor(a: jnp.ndarray, b: jnp.ndarray, invert: bool = False) -> jnp.ndarray:
    """Elementwise XOR (XNOR with ``invert``) of two uint32 buffers."""
    x = jnp.bitwise_xor(a, b)
    return jnp.bitwise_not(x) if invert else x


# ---------------------------------------------------------------------------
# XOR parity digest (bulk copy-verification)
# ---------------------------------------------------------------------------

def parity_digest(words: jnp.ndarray, digest_width: int = 128) -> jnp.ndarray:
    """XOR-fold a flat uint32 buffer into a ``digest_width``-word digest.

    The digest of a buffer is invariant to where the buffer lives — comparing
    digests of source and copy is the paper's row-parity copy-verification.
    Buffer length must be a multiple of ``digest_width`` (ops.py pads with 0,
    which is XOR-neutral).
    """
    r = words.reshape(-1, digest_width)
    return jnp.bitwise_xor.reduce(r, axis=0)


# ---------------------------------------------------------------------------
# Counter-mode XOR stream cipher
# ---------------------------------------------------------------------------

def keystream_word(idx: jnp.ndarray, key0: jnp.ndarray, key1: jnp.ndarray) -> jnp.ndarray:
    """Deterministic 32-bit keystream: murmur3-finalizer counter hash.

    Not cryptographic — stands in for the paper's "true random key" XOR pad;
    the framework interface accepts externally supplied pads for real use.
    Shared verbatim by the Pallas kernel so ref and kernel are bit-identical.
    """
    h = idx.astype(jnp.uint32) * GOLDEN + key0.astype(jnp.uint32)
    h = h ^ key1.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def xor_cipher(words: jnp.ndarray, key: jnp.ndarray, counter: int | jnp.ndarray = 0) -> jnp.ndarray:
    """Encrypt/decrypt (involution) a flat uint32 buffer in counter mode."""
    idx = (jnp.arange(words.shape[0], dtype=jnp.uint32)
           + jnp.asarray(counter, jnp.uint32))
    return words ^ keystream_word(idx, key[0], key[1])
