"""Shared online-softmax (m, l, acc) accumulator for fused attention kernels.

One streaming pass over key tiles maintains, per query row,

  m    running max of the masked scores seen so far,
  l    running sum of exp(score - m),
  acc  running sum of exp(score - m) @ V,

with the Dao et al. FA-2 correction ``exp(m_prev - m_new)`` rescaling the
stale l/acc whenever a new tile raises the max.  ``finish`` normalizes:
``acc / l`` equals plain masked softmax(scores) @ V exactly in real
arithmetic (floating-point results differ only in rounding/association —
which is why the model-level dispatch keeps a bit-exact jnp twin, DESIGN.md
§18).

Both fused kernels import these helpers instead of hand-copying the
recurrence: :mod:`repro.kernels.flash_attn` (grid-tiled prefill attention)
and :mod:`repro.kernels.paged_attn` (block-table paged decode).  The
helpers operate on Pallas refs — ``m_ref``/``l_ref`` are ``(rows, 1)`` f32
VMEM scratch, ``acc_ref`` is ``(rows, dh)`` f32 VMEM scratch — and are
ordinary jnp code, so they also run under ``interpret=True`` and inside
the pure-jnp reference twins.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30   # masking constant shared with models/attention.py


def init(m_ref, l_ref, acc_ref) -> None:
    """Reset the accumulator at the first key tile of a query row."""
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def update(s: jnp.ndarray, v: jnp.ndarray, m_ref, l_ref, acc_ref) -> None:
    """Fold one masked score tile ``s`` (rows, bk) f32 and its value tile
    ``v`` (bk, dh) into the running (m, l, acc).

    Masked-out scores must already be ``NEG_INF``; a tile whose rows are
    *entirely* masked must be skipped by the caller (``exp(NEG_INF -
    NEG_INF) == 1`` would poison l/acc while m is still at its initial
    value — the classic online-softmax edge case).
    """
    m_prev = m_ref[...]                                   # (rows, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (rows, bk)
    corr = jnp.exp(m_prev - m_new)                        # (rows, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_ref[...] = m_new


def finish(m_ref, l_ref, acc_ref) -> jnp.ndarray:
    """Normalize after the last tile: (rows, dh) f32 attention output."""
    return acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
