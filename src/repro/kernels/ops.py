"""Public ops: padding, backend dispatch (Pallas on TPU / jnp ref elsewhere).

Every op has three execution paths with identical semantics:
  * ``impl="pallas"``     — the TPU kernel (real hardware),
  * ``impl="interpret"``  — the same kernel body interpreted on CPU (tests),
  * ``impl="ref"``        — the pure-jnp oracle (CPU production + dry-run).
``impl="auto"`` picks pallas on TPU backends and ref otherwise, so the same
model code lowers everywhere (the 512-device CPU dry-run included).
"""

from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.kernels import bulk as _bulk
from repro.kernels import cipher as _cipher
from repro.kernels import pack as _pack
from repro.kernels import parity as _parity
from repro.kernels import ref
from repro.kernels import xnor_gemm as _xnor_gemm

_FORCE = os.environ.get("REPRO_KERNEL_IMPL", "")  # "", "ref", "pallas", "interpret"


def _resolve(impl: str) -> str:
    impl = _FORCE or impl
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in ("ref", "pallas", "interpret"):
        raise ValueError(
            f"unknown kernel impl {impl!r} (from REPRO_KERNEL_IMPL or impl=);"
            " expected auto|ref|interpret|pallas")
    return impl


def fused_mode(mode: str = "auto") -> str:
    """Resolve the fused-decode dispatch: ``"kernel"`` or ``"ref"``.

    ``"kernel"`` routes the decode hot path through the single-dispatch
    Pallas kernels (paged_attn / the fused packed linear); ``"ref"`` keeps
    the unfused jnp chain, which is the kernels' bit-exact reference twin
    (DESIGN.md §18).  ``"auto"`` picks the kernel exactly when the base
    dispatch resolves to real-TPU pallas — on ref/interpret backends the
    chain stays unfused so every cross-layout token pin (paged == dense,
    prefix on == off, migration identity) remains bitwise across both
    ``REPRO_KERNEL_IMPL`` CI modes.  The ``REPRO_FUSED_DECODE`` env var
    (read per call, so tests can monkeypatch) overrides ``mode``:
    on/kernel/fused force the kernel, off/ref/unfused force the chain.
    """
    mode = os.environ.get("REPRO_FUSED_DECODE", "") or mode
    if mode in ("on", "kernel", "fused"):
        return "kernel"
    if mode in ("off", "ref", "unfused"):
        return "ref"
    if mode != "auto":
        raise ValueError(
            f"unknown fused-decode mode {mode!r} (from REPRO_FUSED_DECODE or"
            " cfg.fused_decode); expected auto|on|kernel|fused|off|ref|unfused")
    return "kernel" if _resolve("auto") == "pallas" else "ref"


def _pad_rows(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)) if pad else x


def _pad_cols(x: jnp.ndarray, mult: int, value=0) -> jnp.ndarray:
    pad = (-x.shape[-1]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------


def xnor_matmul(pa: jnp.ndarray, pb: jnp.ndarray, valid_k: int,
                impl: str = "auto", **blocks) -> jnp.ndarray:
    """±1 dot in the packed domain for arbitrary (M, Kw) x (N, Kw).

    Padding rule: row pads produce garbage rows that are sliced off; column
    (word) pads are zero words in BOTH operands, XOR to zero, and are removed
    by ``valid_k`` accounting (popcount of zero is zero -> each pad word
    contributes +32 to the padded dot; using valid_k subtracts exactly that).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.xnor_gemm(pa, pb, valid_k)
    bm = blocks.get("bm", 128)
    bn = blocks.get("bn", 128)
    bk = blocks.get("bk", 64)
    m, n = pa.shape[0], pb.shape[0]
    pa2, pb2 = _pad_rows(pa, bm), _pad_rows(pb, bn)
    kw = pa2.shape[1]
    # pad kw up to a multiple of bk rather than collapsing the tile to bk=1
    # on non-divisible packed widths (e.g. kw=96 with bk=64): pad words are
    # zero in both operands and the kpad-valid_k correction below removes
    # their bias exactly, so the grid stays ceil(kw/bk) steps.
    bk = min(bk, kw)
    pa2, pb2 = _pad_cols(pa2, bk), _pad_cols(pb2, bk)
    # pad words are 0 in both operands => popcount contribution 0; the
    # (kw_pad*32 - valid_k) correction below removes their +1 dot bias.
    kpad = pa2.shape[1] * bitpack.WORD
    out = _xnor_gemm.xnor_gemm(pa2, pb2, valid_k=kpad, bm=bm, bn=bn, bk=bk,
                               interpret=(impl == "interpret"))
    return out[:m, :n] - jnp.int32(kpad - valid_k)


def binarize(x: jnp.ndarray, impl: str = "auto", bm: int = 256):
    """(..., K) float -> ((..., Kw) uint32, (...,) f32 alpha). Fused on TPU."""
    impl = _resolve(impl)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = _pad_cols(x.reshape(-1, k), bitpack.WORD)
    if impl == "ref":
        planes = bitpack.pack_bits(x2)
        alpha = jnp.mean(jnp.abs(x2[:, :k]), axis=-1).astype(jnp.float32)
    else:
        m = x2.shape[0]
        # pad rows up to a multiple of bm rather than collapsing the tile to
        # bm=1 on non-divisible row counts (the digest/stream_cipher fix):
        # pad rows are garbage in planes/alpha and are sliced off below.
        bm = min(bm, m)
        x3 = _pad_rows(x2, bm)
        planes, alpha = _pack.pack(x3, bm=bm, interpret=(impl == "interpret"))
        planes, alpha = planes[:m], alpha[:m]
        # kernel alpha averaged over padded K; rescale to true K.
        alpha = alpha * (x2.shape[1] / k)
    return planes.reshape(*lead, -1), alpha.reshape(lead)


def xnor_linear_fused(x: jnp.ndarray, pb: jnp.ndarray, beta: jnp.ndarray,
                      valid_k: int, impl: str = "auto", bm: int = 128,
                      bn: int = 128) -> jnp.ndarray:
    """Single-dispatch packed linear: binarize + XNOR GEMM + alpha/beta.

    ``x``: (M, K) activations, ``pb``: (N, Kw) prepacked weight planes,
    ``beta``: (N,) weight scales; returns (M, N) f32.  The unfused chain
    (``binarize`` -> ``xnor_matmul`` -> scale) materializes the packed
    activation planes and the int32 dots in HBM between dispatches; here
    they live and die inside one kernel.  ``impl="ref"`` runs the pure-jnp
    oracle (bit-identical to the unfused ref chain).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.xnor_linear_fused(x, pb, beta, valid_k)
    m, n = x.shape[0], pb.shape[0]
    # column pads are 0.0: they pack to 1-bits, matching pb's word-tail pad
    # bits, so the kernel's valid_k accounting stays exact (see _fused_kernel)
    xp = _pad_cols(x, bitpack.WORD)
    bm, bn = min(bm, m), min(bn, n)
    xp, pb2 = _pad_rows(xp, bm), _pad_rows(pb, bn)
    beta2 = jnp.pad(beta, (0, pb2.shape[0] - n))
    out = _xnor_gemm.xnor_linear_fused(xp, pb2, beta2, valid_k=valid_k,
                                       bm=bm, bn=bn,
                                       interpret=(impl == "interpret"))
    return out[:m, :n]


def digest(buf: jnp.ndarray, digest_width: int = 128, impl: str = "auto",
           br: int = 512) -> jnp.ndarray:
    """XOR-parity digest of any array (viewed as a uint32 stream)."""
    impl = _resolve(impl)
    words = as_words(buf)
    pad = (-words.shape[0]) % digest_width
    words = jnp.pad(words, (0, pad))  # zeros are XOR-neutral
    words = words.reshape(-1, digest_width)
    if impl == "ref":
        return ref.parity_digest(words, digest_width)
    # pad rows rather than shrink the tile (zero rows are XOR-neutral for the
    # fold): shrinking to br=1 on non-divisible row counts explodes the grid
    # to one row per step.
    br = min(br, words.shape[0])
    words = _pad_rows(words, br)
    return _parity.parity_digest(words, digest_width=digest_width, br=br,
                                 interpret=(impl == "interpret"))


def bulk_op(a: jnp.ndarray, b: jnp.ndarray, op: str = "xor",
            impl: str = "auto", br: int = 512) -> jnp.ndarray:
    """Bulk bitwise XOR/XNOR of two same-shape uint32 buffers.

    The digital form of the banked engine's compute cycle (DESIGN.md §10):
    every uint32 lane carries 32 row-columns, so one call is the bulk
    row-wide Boolean op the paper computes per sense cycle, tiled over the
    whole buffer.  Restricted to uint32 like :func:`stream_cipher` so results
    are bit-exact across all three impl paths.
    """
    if op not in ("xor", "xnor"):
        raise ValueError(f"bulk_op supports xor/xnor, got {op!r}")
    if a.dtype != jnp.uint32 or b.dtype != jnp.uint32:
        raise TypeError(f"bulk_op needs uint32, got {a.dtype}/{b.dtype}")
    if a.shape != b.shape:
        raise ValueError(f"operand shapes differ: {a.shape} vs {b.shape}")
    invert = op == "xnor"
    impl = _resolve(impl)
    if impl == "ref":
        return ref.bulk_xor(a, b, invert=invert)
    words_a, words_b = a.reshape(-1), b.reshape(-1)
    n = words_a.shape[0]
    d = 128
    pad = (-n) % d
    a2 = jnp.pad(words_a, (0, pad)).reshape(-1, d)
    b2 = jnp.pad(words_b, (0, pad)).reshape(-1, d)
    # pad rows rather than shrink the tile: pad output is sliced off below
    # (no cross-tile dependency, unlike digest's fold).
    br = min(br, a2.shape[0])
    a2, b2 = _pad_rows(a2, br), _pad_rows(b2, br)
    out = _bulk.bulk_xor(a2, b2, invert=invert, br=br,
                         interpret=(impl == "interpret"))
    return out.reshape(-1)[:n].reshape(a.shape)


def stream_cipher(buf: jnp.ndarray, key: jnp.ndarray, counter: int = 0,
                  impl: str = "auto", br: int = 512) -> jnp.ndarray:
    """XOR counter-mode cipher over a uint32 buffer. Involution.

    Restricted to uint32 so decryption round-trips bit-exactly; the
    checkpoint layer views other dtypes as uint32 host-side (numpy .view).
    ``counter`` may be a python int or a traced uint32 scalar — the sharded
    engine offsets it per device by the shard's word position.
    """
    if buf.dtype != jnp.uint32:
        raise TypeError(f"stream_cipher needs uint32, got {buf.dtype}")
    impl = _resolve(impl)
    words = buf.reshape(-1)
    n = words.shape[0]
    if impl == "ref":
        return ref.xor_cipher(words, key, counter).reshape(buf.shape)
    d = 128
    pad = (-n) % d
    w2 = jnp.pad(words, (0, pad)).reshape(-1, d)
    # pad rows rather than shrink the tile: pad output is sliced off below,
    # so the keystream words the pad rows consume never reach the caller.
    br = min(br, w2.shape[0])
    w2 = _pad_rows(w2, br)
    k3 = jnp.stack([jnp.asarray(key[0], jnp.uint32),
                    jnp.asarray(key[1], jnp.uint32),
                    jnp.asarray(counter, jnp.uint32)])
    out = _cipher.xor_cipher(w2, k3, br=br, interpret=(impl == "interpret"))
    return out.reshape(-1)[:n].reshape(buf.shape)


def host_words(arr: np.ndarray, align: int = 4):
    """View a host numpy array's bytes as the canonical little-endian uint32
    stream, zero-padding the tail to ``align`` bytes.

    Returns ``(words, nbytes)``.  This is THE single definition of the host
    byte layout: :func:`repro.core.verify.np_words` delegates here and
    :func:`as_words` routes host inputs through it, so the digest/cipher
    host and device paths can never desynchronize.
    """
    raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    nbytes = raw.size
    pad = (-nbytes) % align
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
    return raw.view(np.uint32), nbytes


def as_words(buf: jnp.ndarray) -> jnp.ndarray:
    """Losslessly view any array as a flat uint32 stream (pads odd tails).

    Host (numpy) inputs take the :func:`host_words` byte view BEFORE any
    jax conversion: with x64 disabled ``jnp.asarray`` silently downcasts
    float64/int64 and the stream would drop half of every element's bytes.
    jax arrays bitcast on device (64-bit ones only exist with x64 on).
    """
    if not isinstance(buf, jax.Array):
        return jnp.asarray(host_words(np.asarray(buf))[0])
    flat = buf.reshape(-1)
    size = jnp.dtype(flat.dtype).itemsize
    if flat.dtype == jnp.uint32:
        return flat
    if size == 4:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32)
    if size == 8:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32).reshape(-1)
    if size == 2:
        u16 = jax.lax.bitcast_convert_type(flat, jnp.uint16)
        if u16.shape[0] % 2:
            u16 = jnp.pad(u16, (0, 1))
        u16 = u16.reshape(-1, 2).astype(jnp.uint32)
        return u16[:, 0] | (u16[:, 1] << 16)
    if size == 1:
        u8 = jax.lax.bitcast_convert_type(flat, jnp.uint8)
        pad = (-u8.shape[0]) % 4
        if pad:
            u8 = jnp.pad(u8, (0, pad))
        u8 = u8.reshape(-1, 4).astype(jnp.uint32)
        return u8[:, 0] | (u8[:, 1] << 8) | (u8[:, 2] << 16) | (u8[:, 3] << 24)
    raise ValueError(f"unsupported dtype {buf.dtype}")
