"""Pallas TPU kernel: counter-mode XOR stream cipher.

Encryption in the paper is a bulk XOR against a key row.  Counter mode makes
the pad position-dependent (no key-row reuse across rows) while staying a
pure XOR — decryption is the same kernel (involution).  The keystream is
generated *inside* the kernel from (key, word index), so the only HBM traffic
is one read + one write of the payload: the keystream never touches HBM.

Keystream = murmur3 finalizer over the global word index (shared bit-exactly
with ref.keystream_word).  Stand-in for the paper's true-random pad; external
pads are supported one level up (core/encrypt.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import keystream_word


def _kernel(k_ref, w_ref, o_ref, *, cols: int):
    i = pl.program_id(0)
    chunk = w_ref[...]                                 # (br, D) uint32
    br, d = chunk.shape
    base = (i * br * d + k_ref[0, 2]).astype(jnp.uint32)
    idx = (base
           + jax.lax.broadcasted_iota(jnp.uint32, chunk.shape, 0) * np.uint32(d)
           + jax.lax.broadcasted_iota(jnp.uint32, chunk.shape, 1))
    o_ref[...] = chunk ^ keystream_word(idx, k_ref[0, 0], k_ref[0, 1])


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def xor_cipher(words: jnp.ndarray, key: jnp.ndarray, *, br: int = 512,
               interpret: bool = False) -> jnp.ndarray:
    """Encrypt/decrypt a (R, D) uint32 buffer.

    ``key`` is (3,) uint32: (key0, key1, counter_base).  R % br == 0.
    """
    r, d = words.shape
    assert r % br == 0, (words.shape, br)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_kernel, cols=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.uint32),
        interpret=interpret,
    )(key.reshape(1, 3), words)
