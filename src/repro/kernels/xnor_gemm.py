"""Pallas TPU kernel: bit-packed XNOR-popcount GEMM.

This is the TPU realization of the paper's single-cycle in-memory XNOR: each
operand word is read from HBM into VMEM exactly once, and the XOR + popcount
+ accumulate all happen in that same pass (VPU int32 lanes; the MXU is
deliberately idle — binary dot products are bitwise ops, not MACs).

Tiling
------
Grid is (M/bm, N/bn, Kw/bk) with the k-axis innermost ("arbitrary"
dimension semantics: the output block is revisited across k steps and
accumulated in place, the standard Pallas matmul pattern).  Per grid step the
VMEM working set is

    a_blk (bm, bk) u32  +  b_blk (bn, bk) u32  +  o_blk (bm, bn) i32

e.g. (128, 128, 128) -> 64 KiB + 64 KiB + 64 KiB, far under the ~16 MiB VMEM
budget; bk can grow to amortize grid overhead.  The inner loop walks the bk
packed words one vreg-row at a time so the (bm, bn) partial product is the
only live intermediate (no (bm, bn, bk) tensor is ever materialized).

Lane alignment: bm, bn multiples of 8 (sublanes) and ideally 128 (lanes);
bk is a VMEM-bandwidth knob.  `ops.xnor_matmul` pads arbitrary shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitpack import WORD
from repro.kernels import compat


def _kernel(a_ref, b_ref, o_ref, *, bk: int):
    """One (bm, bn) output block, accumulating over the k-grid axis."""
    kstep = pl.program_id(2)

    a = a_ref[...]  # (bm, bk) uint32
    b = b_ref[...]  # (bn, bk) uint32

    def body(w, acc):
        # One packed word per iteration: 32 bit-ops per int32 lane op.
        aw = jax.lax.dynamic_slice_in_dim(a, w, 1, axis=1)      # (bm, 1)
        bw = jax.lax.dynamic_slice_in_dim(b, w, 1, axis=1)      # (bn, 1)
        x = jnp.bitwise_xor(aw, bw.reshape(1, -1))              # (bm, bn)
        return acc + jax.lax.population_count(x).astype(jnp.int32)

    acc = jax.lax.fori_loop(
        0, bk, body, jnp.zeros(o_ref.shape, jnp.int32))

    @pl.when(kstep == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(kstep != 0)
    def _accum():
        o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("valid_k", "bm", "bn", "bk", "interpret"))
def xnor_gemm(pa: jnp.ndarray, pb: jnp.ndarray, *, valid_k: int,
              bm: int = 128, bn: int = 128, bk: int = 64,
              interpret: bool = False) -> jnp.ndarray:
    """Packed binary matmul: (M, Kw) x (N, Kw) -> (M, N) int32 ±1-dot.

    Requires M % bm == N % bn == Kw % bk == 0 (use ops.xnor_matmul for
    arbitrary shapes).  ``valid_k`` is the unpacked dot length; padding bits
    must agree between operands (see ref.xnor_gemm).
    """
    m, kw = pa.shape
    n, kw2 = pb.shape
    assert kw == kw2, (kw, kw2)
    assert m % bm == 0 and n % bn == 0 and kw % bk == 0, (pa.shape, pb.shape, bm, bn, bk)

    grid = (m // bm, n // bn, kw // bk)
    popc = pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pa, pb)
    return jnp.int32(valid_k) - 2 * popc


# ---------------------------------------------------------------------------
# Fused prepacked linear: binarize + popcount GEMM + alpha/beta epilogue
# ---------------------------------------------------------------------------

def _fused_kernel(x_ref, b_ref, beta_ref, o_ref, *, valid_k: int):
    """One (bm, bn) f32 output tile of the fused packed linear.

    The real-valued activation block is read from HBM exactly once: its sign
    bits are packed in-register (the pack.py idiom), the packed words stream
    through the XOR+popcount loop, and the XNOR-Net epilogue
    ``(valid_k - 2*popc) * alpha * beta`` lands in the same pass — no packed
    activation plane or int32 dot tensor ever round-trips HBM.
    """
    x = x_ref[...].astype(jnp.float32)                      # (bm, Kp)
    bm, kp = x.shape
    bits = (x >= 0).astype(jnp.uint32).reshape(bm, kp // WORD, WORD)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, WORD), 2)
    pa = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)  # (bm, Kw)
    b = b_ref[...]                                           # (bn, Kw)

    def body(w, acc):
        aw = jax.lax.dynamic_slice_in_dim(pa, w, 1, axis=1)  # (bm, 1)
        bw = jax.lax.dynamic_slice_in_dim(b, w, 1, axis=1)   # (bn, 1)
        xw = jnp.bitwise_xor(aw, bw.reshape(1, -1))          # (bm, bn)
        return acc + jax.lax.population_count(xw).astype(jnp.int32)

    popc = jax.lax.fori_loop(
        0, b.shape[1], body, jnp.zeros(o_ref.shape, jnp.int32))
    # column pads of x are 0.0 -> sign bit 1, matching pb's word-tail pad
    # bits (prepacking zero-pads, 0 >= 0 -> 1): pads XOR to 0, so the
    # valid_k accounting removes their +1 dot bias exactly (ref.xnor_gemm).
    dots = (jnp.int32(valid_k) - 2 * popc).astype(jnp.float32)
    # 0.0 pads are |.|-neutral, so sum/valid_k is the true-row-length mean.
    alpha = jnp.sum(jnp.abs(x), axis=-1, keepdims=True) / valid_k
    o_ref[...] = dots * alpha * beta_ref[...]                # beta: (1, bn)


@functools.partial(jax.jit, static_argnames=("valid_k", "bm", "bn",
                                             "interpret"))
def xnor_linear_fused(x: jnp.ndarray, pb: jnp.ndarray, beta: jnp.ndarray, *,
                      valid_k: int, bm: int = 128, bn: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """Fused packed linear: (M, Kp) float x (N, Kw) packed -> (M, N) f32.

    Requires M % bm == N % bn == 0 and Kp == Kw * 32 (ops.xnor_linear_fused
    pads arbitrary shapes).  Grid is (M/bm, N/bn) with K unblocked — a full
    activation row must be visible in one step to compute alpha alongside
    the dot (same constraint as pack.py); per-step VMEM is the (bm, Kp) f32
    activation block + (bn, Kw) u32 weight planes + the (bm, bn) tile.
    """
    m, kp = x.shape
    n, kw = pb.shape
    assert kp == kw * WORD and m % bm == 0 and n % bn == 0, (x.shape, pb.shape)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_fused_kernel, valid_k=valid_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kw), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, pb, beta.astype(jnp.float32).reshape(1, -1))
