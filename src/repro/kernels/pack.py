"""Pallas TPU kernel: fused sign-extract + bit-pack + alpha scale.

One HBM pass over the real-valued operand produces (a) the packed sign
bit-planes and (b) the XNOR-Net scaling factor alpha = mean|x| per row —
mirroring the paper's sense amplifier producing the digital bit in the same
cycle that reads the cell.  Without fusion this costs three passes
(sign, pack, abs-mean); fused it is exactly one read of x.

The 32->1 pack is expressed as a (bm, Kw, 32) reshape + weighted sum over the
last axis.  Bits are disjoint powers of two, so an integer sum equals the
bitwise OR; Mosaic lowers the small trailing reduction to lane shuffles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitpack import WORD


def _kernel(x_ref, p_ref, a_ref, *, block_k: int):
    x = x_ref[...].astype(jnp.float32)           # (bm, K)
    bm, k = x.shape
    bits = (x >= 0).astype(jnp.uint32).reshape(bm, k // WORD, WORD)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, WORD), 2)
    p_ref[...] = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)
    a_ref[...] = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def pack(x: jnp.ndarray, *, bm: int = 256, interpret: bool = False):
    """(M, K) float -> ((M, K/32) uint32 planes, (M,) f32 alpha).

    M % bm == 0 and K % 32 == 0 (ops.binarize pads arbitrary shapes).
    K is kept unblocked: a full row must be visible to compute alpha in the
    same pass; rows are streamed bm at a time.
    """
    m, k = x.shape
    assert m % bm == 0 and k % WORD == 0, (x.shape, bm)
    grid = (m // bm,)
    planes, alpha = pl.pallas_call(
        functools.partial(_kernel, block_k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k // WORD), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k // WORD), jnp.uint32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return planes, alpha[:, 0]
