"""jax-version compatibility shims for the Pallas TPU API.

The TPU compiler-params container was renamed across jax releases
(``TPUCompilerParams`` -> ``CompilerParams``); resolve whichever this jax
ships so the kernels import on both sides of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
