"""Pallas TPU kernel: fused block-paged decode attention (DESIGN.md §18).

The paged decode hot path (`models/attention.py::paged_attention`, C == 1)
was a chain of separate XLA ops — block-table gather -> QK matmul -> mask ->
softmax -> PV matmul — so every decode step round-tripped the gathered
(B, KV, cap, dh) K/V and the (B, KV, G, 1, cap) score tensor through HBM.
This kernel collapses the chain into a single dispatch: per (slot, kv-head)
it walks the slot's host-built int32 block table, streams each referenced
K/V pool block through VMEM exactly once, and folds it into the shared
online-softmax (m, l, acc) accumulator (:mod:`repro.kernels.online_softmax`,
the same recurrence :mod:`repro.kernels.flash_attn` uses for prefill tiles).

Block-table walk
----------------
Grid is (B, KV, W) with the table axis innermost ("arbitrary": the output
block is revisited and accumulated across w steps).  The table and the
per-slot positions ride in as *scalar-prefetched* operands
(``pltpu.PrefetchScalarGridSpec``): they are available before the kernel
body runs, so the K/V BlockSpec index_maps compute the DMA source directly
as ``table[b, w]`` — the gather never materializes, the pool block streams
HBM -> VMEM once and dies in registers.

Masking cases (bit-for-bit the unfused chain's semantics):
  * full-monotone tables — key slot ``w*bs + t`` valid iff ``<= pos[b]``;
  * window rings — ``age = (pos % cap - kslot) % cap`` valid iff
    ``age < min(window, pos + 1)`` (ring capacity ``cap = W*bs``);
  * dead slots / trash block — dead and mid-prefill slots keep table rows
    that may point at stale or trash blocks; their keys are killed by the
    position mask exactly as in the unfused path (the reserved trash block
    0 is only ever *written* through the ``valid`` scatter routing, never
    legitimately read);
  * i8 KV — the fixed-point correction folds into ``scale`` (QK side) and
    ``out_scale`` (PV side), so the int8 pool decodes in one pass too.

A block whose keys are all masked is skipped entirely (``@pl.when``):
that is both the dead-block fast path and the guard for the online-softmax
all-NEG_INF edge case (see online_softmax.update).

Numerics: the online recurrence equals one-shot masked softmax exactly in
real arithmetic but not bit-for-bit in floats (association/rounding).  The
model-level dispatch therefore routes this kernel on real TPU backends and
keeps the jnp chain — which doubles as this kernel's reference twin
(:func:`repro.kernels.ref.paged_decode` is the semantic oracle) — on
ref/interpret backends, so every cross-layout token pin (paged == dense,
prefix on == off, migration identity) stays bit-exact in both CI modes.
``REPRO_FUSED_DECODE=on`` forces the kernel everywhere (parity tests and
the microbenchmark do this explicitly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels import online_softmax as osm

NEG_INF = osm.NEG_INF


def _kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bs: int, w_total: int, window: int,
            scale: float, out_scale: float):
    b = pl.program_id(0)
    w = pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(w == 0)
    def _init():
        osm.init(m_ref, l_ref, acc_ref)

    # key slot index within the gathered cap-axis of the unfused chain:
    # table column w holds tokens w*bs .. w*bs + bs - 1 of the (ring) window
    kslot = w * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    if window:
        cap = w_total * bs
        age = (pos % cap - kslot) % cap
        valid = age < jnp.minimum(window, pos + 1)
    else:
        valid = kslot <= pos

    # skip fully dead blocks: ragged table tails past pos, ring blocks that
    # fell out of the window, and dead slots' stale rows all land here
    @pl.when(jnp.any(valid))
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (bs, dh)
        v = v_ref[0, 0].astype(jnp.float32)            # (bs, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, NEG_INF)               # (G, bs)
        osm.update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(w == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (osm.finish(m_ref, l_ref, acc_ref)
                       * out_scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale", "out_scale",
                                             "interpret"))
def paged_decode_attention(q, ck, cv, table, pos, *, window: int = 0,
                           scale: float = 1.0, out_scale: float = 1.0,
                           interpret: bool = False) -> jnp.ndarray:
    """One fused decode-attention dispatch over the block-paged pool.

    q       (B, KV, G, dh)  — this step's queries, compact GQA form
    ck, cv  (n_blocks, KV, bs, dh) — the shared pool (f32/bf16 or int8),
            with this step's K/V already scattered in (the scatter is a
            (B,) token write, not part of the HBM-bound gather chain)
    table   (B, W) int32    — per-slot physical block ids
    pos     (B,)  int32     — per-slot current absolute position
    window  0 for monotone tables; the local window size for block rings
    scale   QK scale (``dh**-0.5``, with the i8 fixed-point factor folded
            in for int8 pools); ``out_scale`` is the PV-side i8 correction.

    Returns (B, KV, G, dh) in q.dtype.  VMEM per step: one (bs, dh) K and V
    block + (G, dh) q/out tiles + the (G, 1)/(G, dh) accumulator — e.g.
    bs=16, dh=128, G=8: ~21 KB, so the pool never round-trips HBM.
    """
    b, kv, g, dh = q.shape
    bs = ck.shape[2]
    w_total = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, w_total),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b, h, w, tbl, pos: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh),
                         lambda b, h, w, tbl, pos: (tbl[b, w], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh),
                         lambda b, h, w, tbl, pos: (tbl[b, w], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda b, h, w, tbl, pos: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, w_total=w_total, window=window,
                          scale=scale, out_scale=out_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table, pos, q, ck, cv)
