"""Pallas TPU kernel: single-pass XOR-fold parity digest.

The paper's copy-verification XORs source row against copied row and checks
for all-zeros.  At framework scale we fold an arbitrarily large uint32 buffer
into a fixed-width digest in ONE streaming pass (digest(a) == digest(b) <=>
parity check passes for the whole buffer; any single-bit corruption flips
exactly one digest bit).  The digest block stays resident in VMEM across the
whole grid; HBM traffic is exactly one read of the buffer — the roofline for
verification is the HBM stream, the TPU analogue of "single cycle".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _kernel(w_ref, d_ref):
    i = pl.program_id(0)
    chunk = w_ref[...]                                   # (br, D) uint32
    fold = jnp.bitwise_xor.reduce(chunk, axis=0)[None, :]  # (1, D)

    @pl.when(i == 0)
    def _init():
        d_ref[...] = fold

    @pl.when(i != 0)
    def _accum():
        d_ref[...] ^= fold


@functools.partial(jax.jit, static_argnames=("digest_width", "br", "interpret"))
def parity_digest(words: jnp.ndarray, *, digest_width: int = 128,
                  br: int = 512, interpret: bool = False) -> jnp.ndarray:
    """Fold a (R, digest_width) uint32 buffer to a (digest_width,) digest.

    R % br == 0 (ops.digest pads flat buffers with XOR-neutral zeros).
    """
    r, d = words.shape
    assert d == digest_width and r % br == 0, (words.shape, digest_width, br)
    grid = (r // br,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.uint32),
        compiler_params=compat.CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(words)
    return out[0]
