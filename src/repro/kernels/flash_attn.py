"""Pallas TPU kernel: fused (flash) causal attention — §Perf It8b follow-up.

The roofline analysis (EXPERIMENTS.md §Perf iteration 8b) shows the 32k
prefill cells are memory-bound almost entirely by the f32 score stream of
chunked attention (~2.7 TB/chip/step for qwen2-7b): scores round-trip HBM
once per chunk.  This kernel keeps the (bq, bk) score tile in VMEM and
streams K/V exactly once per query block — the same "one memory pass"
discipline as the paper's XOR engine, applied to the framework's own
hotspot.  Projected effect: prefill memory term 6.85 s → ~0.15 s
(q/k/v/out streams only), leaving the cell collective-bound at ~2.5 s.

Online-softmax (Dao et al. FA-2 schedule): per q-tile running (m, l, acc),
one pass over k-tiles, causal masking at tile granularity.

Grid: (B*H, Sq/bq, Sk/bk) with the k axis innermost ("arbitrary"); the
q-tile accumulators live in the output ref + two SMEM-side carries folded
into VMEM scratch via input_output_aliasing-free re-reads (interpret-mode
validated; ops.flash_attention is the jit wrapper, ref is _sdpa).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels import online_softmax as osm

NEG_INF = osm.NEG_INF


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, scale: float, causal: bool):
    kstep = pl.program_id(2)
    qstep = pl.program_id(1)

    @pl.when(kstep == 0)
    def _init():
        osm.init(m_ref, l_ref, acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)                  # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        qpos = qstep * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kstep * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        # tiles strictly above the causal diagonal are fully masked: their
        # update is an exact no-op (p == 0, corr == 1), so skip the work.
        # The k axis walks left-to-right, so tile (q, 0) is never all-masked
        # and the online_softmax all-NEG_INF edge case cannot arise here.
        @pl.when(kstep * bk <= qstep * bq + (bq - 1))
        def _update():
            osm.update(s, v, m_ref, l_ref, acc_ref)
    else:
        osm.update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(kstep == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = osm.finish(m_ref, l_ref, acc_ref).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bk: int = 256, interpret: bool = False):
    """q/k/v: (BH, S, dh) -> (BH, S, dh).  S % bq == S % bk == 0.

    VMEM per step: q,k,v,o tiles + (bq, dh) acc + 2*(bq,1) carries — e.g.
    bq=bk=256, dh=128: ~0.6 MB, far under budget; bk can grow to amortize.
    """
    bh, s, dh = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    grid = (bh, s // bq, s // bk)
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=dh ** -0.5,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q, k, v, causal: bool = True):
    """Oracle: plain masked softmax attention (f32)."""
    bh, s, dh = q.shape
    sc = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * dh ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
