"""Deterministic synthetic data pipeline.

Stateless and index-addressed: batch ``i`` is a pure function of
(seed, step, shape), so a restarted job resumes mid-epoch with zero
coordination — the data-side half of fault tolerance.  The generator is a
Zipf-ish unigram mixture with short-range structure (token t depends on
t-1 via a hash) so cross-entropy has learnable signal for the examples.

Host-side numpy for feeding; :func:`batch_on_device` is the jit-able twin
used in tests.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _mix(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 16)) * np.uint64(0x85EBCA6B)
    x = (x ^ (x >> 13)) * np.uint64(0xC2B2AE35)
    return x ^ (x >> 16)


def batch(seed: int, step: int, batch_size: int, seq_len: int, vocab: int,
          ctx_shape: tuple | None = None) -> dict:
    """-> {tokens (B,S) int32, labels (B,S) int32, [ctx (B,*ctx_shape) f32]}."""
    base = _mix(np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15)
                + np.uint64(step))
    idx = (np.arange(batch_size * (seq_len + 1), dtype=np.uint64)
           .reshape(batch_size, seq_len + 1))
    h = _mix(idx + base)
    # zipf-ish skew: square a uniform in [0,1) then scale
    u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    toks = (u * u * vocab).astype(np.int64)
    # short-range structure: every 3rd token echoes a hash of its predecessor
    echo = (_mix(toks[:, :-1].astype(np.uint64) + base) % np.uint64(vocab))
    mask = (idx[:, 1:] % np.uint64(3)) == 0
    stream = toks[:, 1:].copy()
    stream[mask] = echo.astype(np.int64)[mask]
    tokens = stream.astype(np.int32)
    labels = np.concatenate([tokens[:, 1:], np.full((batch_size, 1), -1,
                                                    np.int32)], axis=1)
    out = {"tokens": tokens, "labels": labels}
    if ctx_shape is not None:
        ch = _mix(np.arange(batch_size * int(np.prod(ctx_shape)),
                            dtype=np.uint64) + base + np.uint64(7))
        ctx = ((ch >> np.uint64(11)).astype(np.float64) / float(1 << 53))
        out["ctx"] = (ctx.reshape(batch_size, *ctx_shape) * 0.2 - 0.1).astype(
            np.float32)
    return out


class Pipeline:
    """Step-indexed host loader with one-batch lookahead (prefetch)."""

    def __init__(self, cfg, batch_size: int, seq_len: int, seed: int = 0):
        self.cfg, self.b, self.s, self.seed = cfg, batch_size, seq_len, seed
        self._next = None
        self._next_step = None

    def _make(self, step: int) -> dict:
        ctx_shape = None
        if self.cfg.n_ctx_tokens:
            ctx_shape = (self.cfg.n_ctx_tokens, self.cfg.d_model)
        return batch(self.seed, step, self.b, self.s, self.cfg.vocab,
                     ctx_shape)

    def get(self, step: int) -> dict:
        if self._next_step == step and self._next is not None:
            out = self._next
        else:
            out = self._make(step)
        # prefetch the following batch synchronously-cheap (numpy)
        self._next_step = step + 1
        self._next = self._make(step + 1)
        return out


def batch_on_device(seed: int, step: int, b: int, s: int, vocab: int) -> dict:
    """jit-able variant used in integration tests."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    toks = jax.random.categorical(
        key, jnp.zeros((vocab,)), shape=(b, s + 1)).astype(jnp.int32)
    return {"tokens": toks[:, :-1],
            "labels": jnp.concatenate(
                [toks[:, 1:-1], jnp.full((b, 1), -1, jnp.int32)], axis=1)}
