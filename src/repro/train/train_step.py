"""The lowered training program: grad + AdamW update (+ optional microbatch
accumulation and 1-bit inter-pod gradient compression).

This is the function the multi-pod dry-run lowers for every train-shape
cell; all sharding is carried by in_shardings/out_shardings built from the
model's ParamDefs (launch/dryrun.py).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.models import lm
from repro.optim import adamw, schedule


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def init_state(cfg, key) -> TrainState:
    params = lm.init_params(cfg, key)
    return TrainState(params, adamw.init(params))


def abstract_state(cfg) -> TrainState:
    params = lm.abstract_params(cfg)
    return TrainState(params, adamw.abstract(params))


def state_pspecs(cfg, rules):
    pspec = lm.param_pspecs(cfg, rules)
    from jax.sharding import PartitionSpec as P
    return TrainState(pspec, adamw.AdamWState(P(), pspec, pspec))


def _grads(cfg, params, batch, q_chunk, microbatch: int,
           unroll: bool = False, acc_dtype=jnp.float32):
    """value_and_grad with optional sequential microbatch accumulation.

    ``acc_dtype=bf16`` halves the resident accumulator (measured §Perf: the
    f32 accumulator + its scan double-buffer is a multi-GiB slab at 100B
    scale); each microbatch grad is produced in f32 and rounded once on
    accumulate, so the rounding error is O(microbatch) ULPs, not O(steps).
    """
    if microbatch <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.loss_fn(cfg, p, batch, q_chunk=q_chunk,
                                 unroll=unroll),
            has_aux=True)(params)
        return loss, metrics, grads

    b = batch["tokens"].shape[0]
    assert b % microbatch == 0, (b, microbatch)
    mb = b // microbatch
    parts = jax.tree.map(
        lambda x: x.reshape(microbatch, mb, *x.shape[1:]), batch)

    def body(carry, mb_batch):
        acc, loss_acc = carry
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.loss_fn(cfg, p, mb_batch, q_chunk=q_chunk),
            has_aux=True)(params)
        acc = jax.tree.map(
            lambda a, g: a + g.astype(acc_dtype), acc, grads)
        return (acc, loss_acc + loss), metrics

    zero = jax.tree.map(
        lambda p: jnp.zeros(p.shape, acc_dtype), params)
    (gsum, loss_sum), metrics = jax.lax.scan(body, (zero, 0.0), parts)
    grads = jax.tree.map(lambda g: (g.astype(jnp.float32) / microbatch),
                         gsum)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum / microbatch, metrics, grads


def _onebit_pod_allreduce(grads, pod_axis: str = "pod"):
    """Majority-vote 1-bit gradient exchange across the pod axis.

    Runs inside shard_map(auto={data, model}): each pod packs sign bits
    (32x smaller than f32), all-gathers the planes over the slow inter-pod
    axis, and reconstructs by majority vote scaled by the mean of per-pod
    L1 scales.  The only inter-pod traffic is uint32 planes + one scalar
    per tensor — the paper's bulk-XOR-domain economy applied to DCN.
    """
    def one(g):
        g32 = g.astype(jnp.float32)
        scale = jnp.mean(jnp.abs(g32))
        flat = g32.reshape(-1)
        planes = bitpack.pack_bits(bitpack.pad_to_word(flat))
        all_planes = jax.lax.all_gather(planes, pod_axis)      # (P, W)
        all_scales = jax.lax.all_gather(scale, pod_axis)       # (P,)
        votes = bitpack.unpack_bits(all_planes, flat.shape[0])  # (P, N) ±1
        maj = jnp.sign(jnp.sum(votes, axis=0) + 0.5)
        out = (jnp.mean(all_scales) * maj).reshape(g.shape)
        return out.astype(g.dtype)

    return jax.tree.map(one, grads)


def train_step(cfg, state: TrainState, batch: dict, step: jnp.ndarray, *,
               peak_lr: float = 3e-4, warmup: int = 100, total: int = 10000,
               q_chunk: int = 0, microbatch: int = 1,
               grad_compress: str = "none", mesh=None, rules=None,
               unroll: bool = False, acc_dtype=jnp.float32):
    """One optimizer step. Returns (state, metrics).

    grad_compress="onebit_pod" wraps the grad computation in shard_map over
    the pod axis and exchanges 1-bit gradients inter-pod (multi-pod meshes
    only; DESIGN.md §4).
    """
    if grad_compress == "onebit_pod":
        assert mesh is not None and "pod" in mesh.axis_names
        from jax.sharding import PartitionSpec as P

        def podwise(params, pod_batch):
            loss, metrics, grads = _grads(cfg, params, pod_batch, q_chunk,
                                          microbatch, unroll, acc_dtype)
            grads = _onebit_pod_allreduce(grads)
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree.map(
                lambda m: jax.lax.pmean(m.astype(jnp.float32), "pod"), metrics)
            return loss, metrics, grads

        # manual over "pod" only; data/model stay auto-partitioned inside.
        in_specs = (jax.tree.map(lambda _: P(), state.params),
                    jax.tree.map(lambda _: P("pod"), batch))
        out_specs = (P(),
                     {"ce": P(), "aux": P(), "tokens": P()},
                     jax.tree.map(lambda _: P(), state.params))
        from repro.distributed import sharding as _sharding
        loss, metrics, grads = _sharding.shard_map(
            podwise, mesh, in_specs, out_specs, manual_axes={"pod"},
        )(state.params, batch)
    else:
        loss, metrics, grads = _grads(cfg, state.params, batch, q_chunk,
                                      microbatch, unroll, acc_dtype)

    lr = schedule.warmup_cosine(step, peak_lr=peak_lr, warmup=warmup,
                                total=total)
    new_params, opt, gnorm = adamw.update(state.params, grads, state.opt, lr)
    metrics = dict(metrics, loss=loss, gnorm=gnorm, lr=lr)
    return TrainState(new_params, opt), metrics
