"""Serving programs lowered by the dry-run and used by examples/serve.py:

  prefill_step — consume a full prompt, build the resident decode state.
  decode_step  — one token for the whole batch against resident state.
  sample       — greedy / temperature sampling from the last-token logits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm


def prefill_step(cfg, params, batch: dict, s_max: int, q_chunk: int = 0):
    """batch: {tokens (B, S), [ctx]} -> (first sampled token, DecodeState)."""
    logits, state = lm.prefill(cfg, params, batch["tokens"],
                               batch.get("ctx"), s_max=s_max,
                               q_chunk=q_chunk)
    return logits, state


def decode_step(cfg, params, token: jnp.ndarray, state: lm.DecodeState):
    """token (B, 1) -> (logits (B, 1, V), state)."""
    return lm.decode_step(cfg, params, token, state)


def sample(logits: jnp.ndarray, key=None, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    g = jax.random.gumbel(key, logits[:, -1].shape, jnp.float32)
    return jnp.argmax(logits[:, -1] / temperature + g, axis=-1).astype(
        jnp.int32)[:, None]


def generate(cfg, params, prompt: jnp.ndarray, n_new: int,
             ctx: jnp.ndarray | None = None, temperature: float = 0.0,
             key=None):
    """Greedy/temperature generation loop (example-scale, jit per step).

    Logits are sliced to the true vocab (the table is padded to 256-multiples
    for TP; pad ids must never be sampled)."""
    s_max = prompt.shape[1] + n_new
    batch = {"tokens": prompt}
    if ctx is not None:
        batch["ctx"] = ctx
    logits, state = prefill_step(cfg, params, batch, s_max=s_max)
    logits = logits[..., :cfg.vocab]
    tok = sample(logits, key, temperature)
    out = [tok]
    for i in range(n_new - 1):
        if key is not None:
            key = jax.random.fold_in(key, i)
        logits, state = decode_step(cfg, params, tok, state)
        tok = sample(logits[..., :cfg.vocab], key, temperature)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
