"""Serving programs lowered by the dry-run and used by examples/serve.py:

  prefill_step — consume a full prompt, build the resident decode state.
  decode_step  — one token for the whole batch against resident state.
  sample       — greedy / temperature sampling from the last-token logits.
  generate     — compatibility wrapper over the continuous-batching engine
                 (:mod:`repro.serve`): the historical static-batch API,
                 now served by the same jitted slot-pool decode program.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm


def prefill_step(cfg, params, batch: dict, s_max: int, q_chunk: int = 0):
    """batch: {tokens (B, S), [ctx]} -> (first sampled token, DecodeState)."""
    logits, state = lm.prefill(cfg, params, batch["tokens"],
                               batch.get("ctx"), s_max=s_max,
                               q_chunk=q_chunk)
    return logits, state


def decode_step(cfg, params, token: jnp.ndarray, state: lm.DecodeState):
    """token (B, 1) -> (logits (B, 1, V), state)."""
    return lm.decode_step(cfg, params, token, state)


def sample(logits: jnp.ndarray, key=None, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    g = jax.random.gumbel(key, logits[:, -1].shape, jnp.float32)
    return jnp.argmax(logits[:, -1] / temperature + g, axis=-1).astype(
        jnp.int32)[:, None]


def generate_static(cfg, params, prompt: jnp.ndarray, n_new: int,
                    ctx: jnp.ndarray | None = None, temperature: float = 0.0,
                    key=None):
    """The pre-engine static-batch loop, preserved verbatim: batch prefill +
    eager per-token decode, uniform shapes, jit dispatch per step.  This is
    the baseline ``benchmarks/serve_throughput.py`` and ``launch/serve.py
    --static`` measure the engine against — :func:`generate` itself now
    routes through the engine, so an A/B against it would be engine vs
    engine.

    Logits are sliced to the true vocab (the table is padded to
    256-multiples for TP; pad ids must never be sampled)."""
    s_max = prompt.shape[1] + n_new
    batch = {"tokens": prompt}
    if ctx is not None:
        batch["ctx"] = ctx
    logits, state = prefill_step(cfg, params, batch, s_max=s_max)
    logits = logits[..., :cfg.vocab]
    tok = sample(logits, key, temperature)
    out = [tok]
    for i in range(n_new - 1):
        if key is not None:
            key = jax.random.fold_in(key, i)
        logits, state = decode_step(cfg, params, tok, state)
        tok = sample(logits[..., :cfg.vocab], key, temperature)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def generate(cfg, params, prompt: jnp.ndarray, n_new: int,
             ctx: jnp.ndarray | None = None, temperature: float = 0.0,
             key=None):
    """Greedy/temperature generation — compatibility wrapper.

    Each prompt row becomes one engine request (a single-trace B-request
    run); greedy outputs are token-identical to the historical static loop
    (per-row math is batch-composition independent).  ``pack=False`` keeps
    the float sign path for quant archs, matching the old numerics exactly;
    use :class:`repro.serve.ServeEngine` directly for packed residency and
    heterogeneous traces.

    Logits are sliced to the true vocab inside the engine (the table is
    padded to 256-multiples for TP; pad ids must never be sampled).
    """
    from repro.serve import Request, ServeEngine

    b, p = prompt.shape
    seed = 0
    if key is not None:
        seed = int(np.asarray(jax.random.randint(key, (), 0, 2**31 - 1)))
    # paged=False: the compat contract is bit-level fidelity to the old
    # static loop, so the wrapper stays on the slot-dense layout (chunked
    # prefill re-chunks recurrences, which is allclose- but not bit-exact).
    eng = ServeEngine(cfg, params, slots=b, s_max=p + n_new,
                      temperature=temperature, seed=seed, pack=False,
                      paged=False)
    prompt_h = np.asarray(prompt, np.int32)
    ctx_h = None if ctx is None else np.asarray(ctx)
    for i in range(b):
        eng.submit(Request(rid=i, prompt=prompt_h[i], max_new_tokens=n_new,
                           ctx=None if ctx_h is None else ctx_h[i]))
    report = eng.run()
    return jnp.asarray(np.stack([report.tokens(i) for i in range(b)]),
                       jnp.int32)
