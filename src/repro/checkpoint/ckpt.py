"""Sharded checkpointing with the paper's two memory-side applications wired
into the I/O path:

* every leaf is saved with an **XOR-parity digest** (copy verification,
  paper Fig. 1(a)): digests are computed before the write, stored in the
  manifest, and re-checked after the write (write-verify) and on restore —
  any single-bit corruption anywhere in a shard is detected;
* optional **XOR stream encryption** (paper Fig. 1(b)): leaves are
  encrypted with a counter-mode pad keyed by (root key, write step, leaf
  path) — :func:`repro.core.encrypt.pad_path` — so no pad reuse across
  leaves or steps, full or delta.

Format: one ``.npz`` per step + a msgpack manifest (shapes/dtypes/digests/
step).  Restore is mesh-shape-agnostic: leaves are addressed by tree path,
so an elastic re-mesh (different device count) re-shards on load —
index-free addressing is the elasticity story.

**Delta checkpoints** (:func:`save_delta`, DESIGN.md §12): a delta step's
npz stores only leaves whose digest moved against the base manifest; every
leaf's manifest entry records ``stored_in`` — the step whose npz actually
holds its bytes — so ``check``/``restore`` resolve a base+delta chain in
one hop per leaf, and write-verify after a delta re-checks only the leaves
it wrote.  Restoring a chain is byte-identical to restoring an equivalent
full checkpoint.  GC that prunes old steps must keep every step a live
manifest's ``stored_in`` entries point at (the :class:`repro.distributed
.fault.Runner` only writes full checkpoints, so its GC is unaffected).

Writes are **double-buffered** (:func:`_write_payload`): the device-side
digest/cipher of leaf *k+1* is dispatched before leaf *k*'s bytes are
written to the zip, so with ``engine=`` the host I/O of one leaf overlaps
the device compute of the next (jax dispatch is async; the ``np.asarray``
at write time is the only sync point).

Both applications run host-side (numpy) by default; pass ``engine=`` (a
:class:`repro.core.engine.CimEngine` or mesh-aware ``ShardedCimEngine``)
to ``save``/``save_delta``/``check``/``restore`` to burn digests and the
cipher on the device bank stack instead (DESIGN.md §11).  The two paths
are bit-identical byte-for-byte, so device-written checkpoints restore
through the host path and vice versa.
"""

from __future__ import annotations

import io
import os
import re
import zipfile
from typing import Any, Callable

import ml_dtypes  # noqa: F401  (registers bfloat16 et al. with numpy)
import numpy as np
import jax
import jax.numpy as jnp
import msgpack

from repro.core import encrypt, verify


def _coerce(raw: np.ndarray, dtype_str: str) -> np.ndarray:
    """npz stores exotic dtypes (bfloat16) as void records; view them back."""
    want = np.dtype(dtype_str)
    if raw.dtype == want:
        return raw
    if raw.dtype.kind == "V" and raw.dtype.itemsize == want.itemsize:
        return raw.view(want)
    return raw


def _digest(arr: np.ndarray, engine) -> np.ndarray:
    if engine is None:
        return verify.np_digest(arr)
    return verify.np_digest_via_device(arr, engine)


def _decrypt(raw, root_key, leaf_path, dtype, shape, engine) -> np.ndarray:
    if root_key is None:
        raise ValueError("checkpoint is encrypted; pass root_key= to "
                         "decrypt it")
    if engine is None:
        return encrypt.decrypt_np(raw, root_key, leaf_path, dtype, shape)
    return encrypt.decrypt_np_via_device(raw, root_key, leaf_path, dtype,
                                         shape, engine)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {verify.leaf_key(path): np.asarray(leaf) for path, leaf in flat}


def _leaf_meta(leaf) -> tuple[list, str]:
    """(shape, dtype-string) without forcing a device-to-host transfer."""
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return list(leaf.shape), str(leaf.dtype)
    arr = np.asarray(leaf)
    return list(arr.shape), str(arr.dtype)


def _ckpt_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.npz")


# -- the double-buffered write path ------------------------------------------


def _stage_leaf(arr: np.ndarray, pad_path: str, root_key, engine,
                dig=None):
    """Dispatch one leaf's digest/cipher; syncing happens at write time.

    Returns ``(digest, payload_fn)``: ``digest`` is a numpy array or an
    in-flight device array, ``payload_fn()`` materializes the bytes to
    write.  With ``engine=`` nothing here blocks — jax dispatch is async —
    which is what lets :func:`_write_payload` overlap this leaf's device
    compute with the previous leaf's host write.  ``dig`` skips the digest
    dispatch when the caller already holds it (save_delta's dirty scan).
    """
    if engine is None:
        if dig is None:
            dig = verify.np_digest(arr)
        buf = (arr if root_key is None
               else encrypt.encrypt_np(arr, root_key, pad_path))
        return dig, (lambda: buf)
    if dig is None:
        words, _ = verify.np_words(arr)
        dig = engine.digest(jnp.asarray(words), verify.DIGEST_WIDTH)
    if root_key is None:
        return dig, (lambda: arr)
    # the staged cipher keeps the host byte contract in encrypt.py — one
    # definition shared with the synchronous encrypt_np_via_device path
    return dig, encrypt.encrypt_np_via_device_staged(arr, root_key, pad_path,
                                                     engine)


def _write_payload(path: str, flat: dict[str, np.ndarray],
                   stage: Callable) -> dict[str, np.ndarray]:
    """np.savez-compatible writer with a one-leaf double buffer.

    ``stage(key, arr)`` dispatches leaf work (see :func:`_stage_leaf`); the
    loop stages leaf k+1 *before* flushing leaf k to the zip, so device
    digest/cipher of the next leaf overlaps host I/O of the current one.
    Returns the per-leaf digests (synced numpy arrays).
    """
    digs: dict[str, np.ndarray] = {}

    def flush(zf, key, staged):
        dig, payload_fn = staged
        buf = io.BytesIO()
        np.lib.format.write_array(buf, np.asarray(payload_fn()),
                                  allow_pickle=False)
        zf.writestr(key.replace("/", "__") + ".npy", buf.getvalue())
        digs[key] = np.asarray(dig)

    with open(path, "wb") as f, \
            zipfile.ZipFile(f, "w", zipfile.ZIP_STORED,
                            allowZip64=True) as zf:
        pending = None
        for key, arr in flat.items():
            nxt = (key, stage(key, arr))        # dispatch leaf k+1
            if pending is not None:
                flush(zf, *pending)             # ...while writing leaf k
            pending = nxt
        if pending is not None:
            flush(zf, *pending)
    return digs


# -- save: full and delta -----------------------------------------------------


def save(directory: str, step: int, tree, *, root_key: str | None = None,
         verify_write: bool = True, engine=None) -> dict:
    """Write a full checkpoint; returns the manifest (also written to disk).

    ``engine=`` routes digests and the cipher through the device bank stack
    (bit-identical to the host path, but cycle-accounted, sharded when the
    engine is a ``ShardedCimEngine``, and overlapped with the host write by
    the double buffer).
    """
    os.makedirs(directory, exist_ok=True)
    _refuse_clobbering_chained_base(directory, step)
    flat = _flatten(tree)
    path = _ckpt_path(directory, step)
    tmp = path + ".tmp"                 # write+rename: atomic publish
    digs = _write_payload(
        tmp, flat,
        lambda key, arr: _stage_leaf(arr, encrypt.pad_path(step, key),
                                     root_key, engine))
    os.replace(tmp, path)
    manifest: dict[str, Any] = {
        "step": step, "base_step": None, "encrypted": root_key is not None,
        "leaves": {key: {"shape": list(arr.shape), "dtype": str(arr.dtype),
                         "digest": digs[key].tobytes().hex(),
                         "stored_in": step}
                   for key, arr in flat.items()}}
    if verify_write:  # read back and parity-check the copy (paper Fig. 1(a))
        _verify_or_unpublish(directory, step, manifest, root_key, engine,
                             None, path)
    _write_manifest(directory, step, manifest)
    return manifest


def save_delta(directory: str, step: int, tree, *,
               base_step: int | None = None, root_key: str | None = None,
               verify_write: bool = True, engine=None, cache=None) -> dict:
    """Write a delta checkpoint: only leaves whose digest moved vs the base.

    ``base_step`` defaults to the latest step on disk (which may itself be
    a delta — chains compose, each leaf resolves in one hop through its
    ``stored_in`` entry).  Write-verify re-checks only the leaves this step
    actually wrote.  ``cache`` (a :class:`repro.core.incremental
    .DigestCache`) makes the dirty scan itself incremental — O(dirty-chunk)
    engine dispatch instead of a full re-digest; without it every leaf is
    re-digested (but still only dirty leaves are written).  ``cache`` also
    makes dirtiness *exact*: leaves the cache's word-compare observed
    changing are stored even when their XOR-parity digest collides with
    the base's (an even number of flips per digest column cancels — e.g.
    swapping two aligned blocks); the cacheless scan can only compare
    digests and would skip such a leaf.  Exactness requires the cache to
    have seen the base-state bytes (prime it at or before the base save):
    leaves the cache first meets at save time have no comparison history
    and are conservatively stored — an unprimed cache degrades to a full
    save, never to trusting a collidable digest.

    Restoring ``step`` is byte-identical to restoring a full checkpoint of
    the same tree; encrypted leaves re-written here draw fresh pads keyed
    by this step (:func:`repro.core.encrypt.pad_path`).
    """
    os.makedirs(directory, exist_ok=True)
    if base_step is None:
        base_step = latest_step(directory)
    if base_step is None:
        raise FileNotFoundError(
            f"no base checkpoint under {directory} to delta against; "
            "write a full save() first")
    if step <= base_step:
        # step == base_step would os.replace the base npz the new manifest's
        # clean leaves still point at — silent data loss; chains move forward.
        raise ValueError(
            f"delta step {step} must be greater than its base {base_step}")
    _refuse_clobbering_chained_base(directory, step)
    base = _load_manifest(directory, base_step)
    if base["encrypted"] != (root_key is not None):
        raise ValueError(
            f"delta step {step} and base step {base_step} disagree on "
            "encryption; a chain must be uniformly "
            + ("encrypted" if base["encrypted"] else "plain"))

    # flatten leaves WITHOUT np.asarray: a clean device leaf must never be
    # transferred to host — with cache= the whole write path moves O(dirty)
    # bytes, the subsystem's point (without a cache the digest scan still
    # pulls every leaf host-side, so pass cache= for large device trees).
    flat_paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    leaves = {verify.leaf_key(p): leaf for p, leaf in flat_paths}
    metas = {k: _leaf_meta(leaf) for k, leaf in leaves.items()}
    if cache is not None:
        if engine is not None and engine is not cache.engine:
            # same conflict tree_digest refuses: the dirty scan would
            # dispatch (and cycle-account) on cache.engine, not engine=
            raise ValueError("save_delta: cache= and engine= conflict — the "
                             "dirty scan digests through cache.engine; pass "
                             "the same engine (or build the cache from it)")
        if cache.digest_width != verify.DIGEST_WIDTH:
            # manifest digests are DIGEST_WIDTH words; a different cache
            # width would mark every leaf dirty AND poison the manifest
            # with digests check()/restore() can never reproduce
            raise ValueError(
                f"save_delta: cache digest_width={cache.digest_width} must "
                f"be the manifest width {verify.DIGEST_WIDTH}")
        digs = {k: np.asarray(d)
                for k, d in _flatten(cache.digests(tree)).items()}
        # exact change evidence from the cache's word-compare: a leaf it
        # observed changing since the last save is stored even if its
        # XOR-parity digest collides with the base's (even flips per digest
        # column cancel — e.g. swapping two aligned blocks leaves the
        # parity unchanged).  Accumulated across passes: the observing
        # scrub may have run earlier, leaving the cache already synced.
        observed = cache.observed_since_save
        # a leaf the cache first saw in the digests() call above has no
        # comparison history — the cache cannot attest it is clean, so it
        # is stored (an unprimed cache degrades to a full save, never to
        # silently trusting a collidable digest).
        unproven = cache.last_leaf_new
    else:
        digs = {k: _digest(np.asarray(leaf), engine)
                for k, leaf in leaves.items()}
        observed, unproven = {}, set()
    base_leaves = base["leaves"]
    # digests cover bytes only: a dtype/shape re-interpretation with identical
    # bytes must still be re-stored or the plain restore path would coerce
    # the base bytes through the wrong dtype.
    dirty = [key for key in leaves
             if key not in base_leaves
             or observed.get(key, 0) > 0
             or key in unproven
             or digs[key].tobytes().hex() != base_leaves[key]["digest"]
             or metas[key][0] != list(base_leaves[key]["shape"])
             or metas[key][1] != base_leaves[key]["dtype"]]

    path = _ckpt_path(directory, step)
    tmp = path + ".tmp"
    _write_payload(
        tmp, {k: np.asarray(leaves[k]) for k in dirty},   # dirty only
        lambda key, arr: _stage_leaf(arr, encrypt.pad_path(step, key),
                                     root_key, engine, dig=digs[key]))
    os.replace(tmp, path)

    dirty_set = set(dirty)
    manifest: dict[str, Any] = {
        "step": step, "base_step": base_step,
        "encrypted": root_key is not None,
        "leaves": {key: {
            "shape": metas[key][0], "dtype": metas[key][1],
            "digest": digs[key].tobytes().hex(),
            "stored_in": (step if key in dirty_set else
                          int(base_leaves[key].get("stored_in", base_step))),
        } for key in leaves}}
    if verify_write:  # delta write-verify: only the leaves written here
        _verify_or_unpublish(directory, step, manifest, root_key, engine,
                             dirty, path)
    _write_manifest(directory, step, manifest)
    if cache is not None:
        cache.mark_saved()   # evidence durably consumed (kept on failure)
    return manifest


def _verify_or_unpublish(directory: str, step: int, manifest: dict,
                         root_key, engine, leaves, npz_path: str) -> None:
    """Write-verify against the *in-memory* manifest, before it is published.

    A verify failure must not leave the step on disk: a published-but-bad
    step would become latest_step() — the next delta's default base — and
    its manifest records the intended digests, so the corruption would read
    as clean forever after.  Remove the npz and raise instead.
    """
    ok, bad = _check_manifest(directory, step, manifest, root_key=root_key,
                              engine=engine, leaves=leaves)
    if not ok:
        os.remove(npz_path)
        raise IOError(f"checkpoint write verification failed at step {step}, "
                      f"step unpublished: {bad}")


def _write_manifest(directory: str, step: int, manifest: dict) -> None:
    """Atomic publish: the manifest is the step's publish record
    (latest_step keys off it), so a torn half-written manifest must be
    impossible — write-then-rename, same as the npz."""
    path = os.path.join(directory, f"manifest_{step:08d}.msgpack")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(manifest))
    os.replace(tmp, path)


def _refuse_clobbering_chained_base(directory: str, step: int) -> None:
    """Refuse to overwrite a step a newer manifest's chain still points at.

    Before delta chains every step was self-contained and re-saving an old
    step was merely odd; now a newer delta's ``stored_in`` entries may name
    this step as the only copy of their clean leaves, and os.replace-ing
    its npz would make that newer step permanently unrestorable.
    """
    if not os.path.isdir(directory):
        return
    for f in os.listdir(directory):
        m = re.match(r"manifest_(\d+)\.msgpack$", f)
        if not m or (other_step := int(m.group(1))) <= step:
            continue
        try:
            other = _load_manifest(directory, other_step)
        except (msgpack.exceptions.UnpackException, ValueError, KeyError,
                FileNotFoundError):
            continue        # torn (crashed write) or vanished: not a chain
        # any other error (EACCES, I/O) propagates — silently skipping
        # would disable the data-loss guard exactly when disks misbehave
        if any(int(meta.get("stored_in", other_step)) == step
               for meta in other["leaves"].values()):
            raise ValueError(
                f"step {step} holds the only copy of leaves that step "
                f"{other_step}'s delta chain references; overwriting it "
                "would orphan that chain — save to a new step instead")


# -- read side: chain-resolving check/restore ---------------------------------


def _load_payloads(directory: str, metas: dict, default_step: int) -> dict:
    """Open every npz a set of manifest entries stores bytes in."""
    steps = {int(m.get("stored_in", default_step)) for m in metas.values()}
    out = {}
    for s in steps:
        p = _ckpt_path(directory, s)
        if not os.path.exists(p):
            raise FileNotFoundError(
                f"step {default_step} references leaves stored in step {s}, "
                f"but {p} is missing (delta base pruned?)")
        out[s] = np.load(p)
    return out


def _read_leaf(payloads: dict, key: str, meta: dict, encrypted: bool,
               root_key, engine, default_step: int) -> np.ndarray:
    stored_in = int(meta.get("stored_in", default_step))
    raw = payloads[stored_in][key.replace("/", "__")]
    if encrypted:
        return _decrypt(raw, root_key, encrypt.pad_path(stored_in, key),
                        np.dtype(meta["dtype"]), tuple(meta["shape"]), engine)
    return _coerce(raw, meta["dtype"])


def check(directory: str, step: int, *, root_key: str | None = None,
          engine=None, leaves: list[str] | None = None):
    """Parity-verify a checkpoint on disk against its manifest.

    Follows delta chains (each leaf is read from its ``stored_in`` step);
    ``leaves=`` restricts the check to a subset (the delta write-verify
    path re-checks only what it wrote).
    """
    return _check_manifest(directory, step, _load_manifest(directory, step),
                           root_key=root_key, engine=engine, leaves=leaves)


def _check_manifest(directory: str, step: int, manifest: dict, *,
                    root_key=None, engine=None, leaves=None):
    metas = manifest["leaves"]
    if leaves is not None:
        metas = {k: metas[k] for k in leaves}
    payloads = _load_payloads(directory, metas, step)
    bad = []
    for key, meta in metas.items():
        raw = _read_leaf(payloads, key, meta, manifest["encrypted"],
                         root_key, engine, step)
        if _digest(raw, engine).tobytes().hex() != meta["digest"]:
            bad.append(key)
    return (not bad), bad


def restore(directory: str, step: int | None, like, *,
            root_key: str | None = None, verify_read: bool = True,
            engine=None, transform: Callable | None = None):
    """Load into the structure of ``like`` (abstract or concrete pytree).

    Delta chains resolve transparently: the result is byte-identical to
    restoring a full checkpoint of the same tree.

    ``transform(key, arr)``, when given, maps each leaf (after the parity
    check and dtype cast) to its in-memory form *as it streams off disk* —
    the hook :func:`restore_packed` uses to pack binarizable linears one
    leaf at a time, so the float weights are transient per-leaf and the
    full float tree is never resident.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    manifest = _load_manifest(directory, step)
    payloads = _load_payloads(directory, manifest["leaves"], step)
    flat, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    bad = []
    for path, leaf in flat:
        key = verify.leaf_key(path)
        meta = manifest["leaves"][key]
        raw = _read_leaf(payloads, key, meta, manifest["encrypted"],
                         root_key, engine, step)
        if verify_read:
            if _digest(raw, engine).tobytes().hex() != meta["digest"]:
                bad.append(key)
        arr = raw.reshape(meta["shape"])
        arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
        leaves.append(transform(key, arr) if transform is not None else arr)
    if bad:
        raise IOError(f"checkpoint corruption detected in leaves: {bad}")
    return jax.tree_util.tree_unflatten(tdef, leaves), step


def restore_packed(directory: str, step: int | None, cfg, *,
                   root_key: str | None = None, verify_read: bool = True,
                   engine=None):
    """Restore a float param checkpoint straight into serve-resident form.

    Binarizable linears (``ParamDef.binarize`` under a ``quant="xnor"``
    arch) are packed to :class:`repro.core.xnor_layers.PackedLinear` as
    each leaf streams off disk — pack once at load, per-leaf-transient
    floats, never a resident float copy of the binary filters.  The result
    equals ``lm.pack_params(cfg, restore(...)[0])`` leaf-for-leaf.
    Quant-"none" archs restore unchanged.
    """
    from repro.core import xnor_layers
    from repro.models import lm

    like = lm.abstract_params(cfg)
    if cfg.quant != "xnor":
        return restore(directory, step, like, root_key=root_key,
                       verify_read=verify_read, engine=engine)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        lm.param_defs(cfg), is_leaf=lambda x: hasattr(x, "binarize"))
    binarizable = {verify.leaf_key(p) for p, d in flat if d.binarize}

    def transform(key: str, arr):
        if key in binarizable:
            return xnor_layers.pack_linear(jnp.asarray(arr))
        return arr
    return restore(directory, step, like, root_key=root_key,
                   verify_read=verify_read, engine=engine,
                   transform=transform)


def latest_step(directory: str) -> int | None:
    """Latest *published* step: the manifest is the publish record.

    A step counts only when both its manifest and npz exist — a crash in
    the window between the npz replace and the post-verify manifest write
    leaves an orphan npz that must stay invisible here, or restore(None)
    and the next save_delta's default base would wedge on the missing
    manifest instead of using the last intact step.
    """
    if not os.path.isdir(directory):
        return None
    steps = [s for f in os.listdir(directory)
             if (m := re.match(r"manifest_(\d+)\.msgpack$", f))
             and os.path.exists(_ckpt_path(directory, s := int(m.group(1))))]
    return max(steps) if steps else None


def _load_manifest(directory: str, step: int) -> dict:
    with open(os.path.join(directory, f"manifest_{step:08d}.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())
