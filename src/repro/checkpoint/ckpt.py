"""Sharded checkpointing with the paper's two memory-side applications wired
into the I/O path:

* every leaf is saved with an **XOR-parity digest** (copy verification,
  paper Fig. 1(a)): digests are computed before the write, stored in the
  manifest, and re-checked after the write (write-verify) and on restore —
  any single-bit corruption anywhere in a shard is detected;
* optional **XOR stream encryption** (paper Fig. 1(b)): leaves are
  encrypted with a counter-mode pad keyed by (root key, leaf path), so no
  pad reuse across leaves or steps.

Format: one ``.npz`` per host shard + a msgpack manifest
(shapes/dtypes/digests/step).  Restore is mesh-shape-agnostic: leaves are
addressed by tree path, so an elastic re-mesh (different device count)
re-shards on load — index-free addressing is the elasticity story.

Both applications run host-side (numpy) by default; pass ``engine=`` (a
:class:`repro.core.engine.CimEngine` or mesh-aware ``ShardedCimEngine``)
to ``save``/``check``/``restore`` to burn digests and the cipher on the
device bank stack instead (DESIGN.md §11).  The two paths are bit-identical
byte-for-byte, so device-written checkpoints restore through the host path
and vice versa.
"""

from __future__ import annotations

import io
import json
import os
import re
from typing import Any

import ml_dtypes  # noqa: F401  (registers bfloat16 et al. with numpy)
import numpy as np
import jax
import msgpack

from repro.core import encrypt, verify


def _coerce(raw: np.ndarray, dtype_str: str) -> np.ndarray:
    """npz stores exotic dtypes (bfloat16) as void records; view them back."""
    want = np.dtype(dtype_str)
    if raw.dtype == want:
        return raw
    if raw.dtype.kind == "V" and raw.dtype.itemsize == want.itemsize:
        return raw.view(want)
    return raw


def _digest(arr: np.ndarray, engine) -> np.ndarray:
    if engine is None:
        return verify.np_digest(arr)
    return verify.np_digest_via_device(arr, engine)


def _decrypt(raw, root_key, leaf_path, dtype, shape, engine) -> np.ndarray:
    if root_key is None:
        raise ValueError("checkpoint is encrypted; pass root_key= to "
                         "decrypt it")
    if engine is None:
        return encrypt.decrypt_np(raw, root_key, leaf_path, dtype, shape)
    return encrypt.decrypt_np_via_device(raw, root_key, leaf_path, dtype,
                                         shape, engine)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree, *, root_key: str | None = None,
         verify_write: bool = True, engine=None) -> dict:
    """Write a checkpoint; returns the manifest (also written to disk).

    ``engine=`` routes digests and the cipher through the device bank stack
    (bit-identical to the host path, but cycle-accounted and sharded when
    the engine is a ``ShardedCimEngine``).
    """
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    manifest: dict[str, Any] = {"step": step, "leaves": {}, "encrypted":
                                root_key is not None}
    payload = {}
    for key, arr in flat.items():
        digest = _digest(arr, engine)
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "digest": digest.tobytes().hex(),
        }
        buf = arr
        if root_key is not None:
            buf = (encrypt.encrypt_np(arr, root_key, f"{step}/{key}")
                   if engine is None else
                   encrypt.encrypt_np_via_device(arr, root_key,
                                                 f"{step}/{key}", engine))
        payload[key.replace("/", "__")] = buf
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:      # file handle: atomic rename, no suffix
        np.savez(f, **payload)      # munging from np.savez
    os.replace(tmp, path)
    with open(os.path.join(directory, f"manifest_{step:08d}.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))

    if verify_write:  # read back and parity-check the copy (paper Fig. 1(a))
        ok, bad = check(directory, step, root_key=root_key, engine=engine)
        if not ok:
            raise IOError(f"checkpoint write verification failed: {bad}")
    return manifest


def check(directory: str, step: int, *, root_key: str | None = None,
          engine=None):
    """Parity-verify a checkpoint on disk against its manifest."""
    manifest = _load_manifest(directory, step)
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    bad = []
    for key, meta in manifest["leaves"].items():
        raw = data[key.replace("/", "__")]
        if manifest["encrypted"]:
            raw = _decrypt(raw, root_key, f"{step}/{key}",
                           np.dtype(meta["dtype"]), tuple(meta["shape"]),
                           engine)
        else:
            raw = _coerce(raw, meta["dtype"])
        digest = _digest(raw, engine)
        if digest.tobytes().hex() != meta["digest"]:
            bad.append(key)
    return (not bad), bad


def restore(directory: str, step: int | None, like, *,
            root_key: str | None = None, verify_read: bool = True,
            engine=None):
    """Load into the structure of ``like`` (abstract or concrete pytree)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    manifest = _load_manifest(directory, step)
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    flat, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    bad = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        meta = manifest["leaves"][key]
        raw = data[key.replace("/", "__")]
        if manifest["encrypted"]:
            raw = _decrypt(raw, root_key, f"{step}/{key}",
                           np.dtype(meta["dtype"]), tuple(meta["shape"]),
                           engine)
        else:
            raw = _coerce(raw, meta["dtype"])
        if verify_read:
            if _digest(raw, engine).tobytes().hex() != meta["digest"]:
                bad.append(key)
        arr = raw.reshape(meta["shape"])
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    if bad:
        raise IOError(f"checkpoint corruption detected in leaves: {bad}")
    return jax.tree_util.tree_unflatten(tdef, leaves), step


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def _load_manifest(directory: str, step: int) -> dict:
    with open(os.path.join(directory, f"manifest_{step:08d}.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())
