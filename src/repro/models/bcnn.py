"""Binary-dense classifier blocks: the paper's XNOR-CNN as a registered
block kind (Fig. 1(c) / §VI, Fig. 6 workload).

``bindense`` is the first block kind registered *outside* ``blocks.py`` —
the registry's proof of composability (DESIGN.md §16).  It is an XNOR-Net
residual MLP block conditioned on an image context:

  g  = W_ctx · mean(ctx)          full precision (XNOR-Net first-layer rule)
  u  = XNOR(W_up  · (norm(x)+g))  binary weights+activations — the popcount
  y  = XNOR(W_down· relu(u))      GEMM the paper's CiM array executes
  x' = x + y

Its decode state is the third layout the contracts name: *ctx-derived* —
a pure function of the request's context, held dense per slot (like
cross-attn ctx_kv) so decode never needs the raw image resident.  No
sequential state at all, so fwd/decode/chunk agree token-for-token and
the kind is trivially chunk-exact.

The module also provides the classifier-as-generation plumbing used by
:class:`repro.serve.workloads.ClassifierService`: synthetic stripe images
(the task from ``examples/xnor_cnn_classifier.py``), image -> ctx-patch
embedding, and end-to-end training of the LM-shaped model so a class id
is literally the argmax token (class ids are vocab ids; one query token
prompts the prediction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.ctx import constrain
from repro.models import layers
from repro.models.blocks import PagedLayout
from repro.models.params import ParamDef
from repro.models.registry import BlockContract, register

# vocab layout of the classifier head: class ids are token ids, and the
# one-token prompt is a reserved query token (never a valid class)
N_CLASSES = 2
QUERY_TOKEN = N_CLASSES
VOCAB = N_CLASSES + 2  # classes + query + one spare


def _norm_def(cfg, n):
    return ParamDef((n, cfg.d_model), (None, None), jnp.float32, init="ones")


@register
class BinDenseBlock(PagedLayout):
    """Stateless-in-sequence binary MLP block gated by pooled image ctx."""

    contract = BlockContract("bindense", per_slot_state=True,
                             prefix_shareable=True)

    @classmethod
    def defs(cls, cfg, n):
        d, ff = cfg.d_model, cfg.d_ff
        return {
            "ln1": _norm_def(cfg, n),
            # ctx projection stays full precision: the image enters the
            # network here (XNOR-Net keeps first/last layers fp)
            "w_ctx": ParamDef((n, d, d), (None, "fsdp", "tp"), cfg.dtype),
            "w_up": ParamDef((n, d, ff), (None, "fsdp", "tp"), cfg.dtype,
                             binarize=True),
            "w_down": ParamDef((n, ff, d), (None, "tp", "fsdp"), cfg.dtype,
                               binarize=True),
        }

    @classmethod
    def _gate(cls, cfg, p, ctx, batch):
        """(B, 1, d) ctx-derived gate — the block's whole decode state."""
        if ctx is None:
            return jnp.zeros((batch, 1, cfg.d_model), cfg.dtype)
        pooled = jnp.mean(ctx.astype(jnp.float32), axis=1, keepdims=True)
        return layers.linear(pooled.astype(cfg.dtype),
                             p["w_ctx"]).astype(cfg.dtype)

    @classmethod
    def _mlp(cls, cfg, p, x, g):
        h = layers.rms_norm(x, p["ln1"])
        u = layers.linear(h + g, p["w_up"], cfg.quant)
        u = constrain(u, "batch", None, "tp")
        y = layers.linear(jax.nn.relu(u), p["w_down"], cfg.quant)
        return x + constrain(y, "batch", None, None)

    @classmethod
    def fwd(cls, cfg, p, x, ctx, opts):
        g = cls._gate(cfg, p, ctx, x.shape[0])
        x = cls._mlp(cfg, p, x, g)
        return x, jnp.float32(0.0), (g if opts.want_state else None)

    @classmethod
    def decode(cls, cfg, p, x, state, pos, ctx, table=None, valid=None):
        return cls._mlp(cfg, p, x, state), state

    @classmethod
    def chunk(cls, cfg, p, x, state, pos0, valid, n_valid, ctx, table):
        # no cross-token flow: padded positions only produce unread rows,
        # and the ctx-derived state is position-independent — chunk-exact
        g = cls._gate(cfg, p, ctx, x.shape[0])
        return cls._mlp(cfg, p, x, g), g

    @classmethod
    def state_spec(cls, cfg, batch, s_max, abstract):
        shp = (batch, 1, cfg.d_model)
        if abstract:
            return jax.ShapeDtypeStruct(shp, cfg.dtype)
        return jnp.zeros(shp, cfg.dtype)

    @classmethod
    def state_pspec(cls, cfg, ba, kv_shard: str = "heads", tp_size: int = 16):
        from jax.sharding import PartitionSpec as P
        return P(ba, None, "model")


# ---------------------------------------------------------------------------
# classifier-as-generation plumbing
# ---------------------------------------------------------------------------

def synthetic_images(key, n: int, side: int = 16):
    """Two-class stripe task from examples/xnor_cnn_classifier.py:
    vertical vs horizontal stripes + noise -> ((n, side, side), (n,))."""
    k1, k2 = jax.random.split(key)
    y = jax.random.bernoulli(k1, 0.5, (n,)).astype(jnp.int32)
    xs = jnp.linspace(-1, 1, side)
    vert = jnp.sign(jnp.sin(8 * xs))[None, :].repeat(side, 0)
    horz = vert.T
    base = jnp.where(y[:, None, None] == 1, vert[None], horz[None])
    x = base + 0.8 * jax.random.normal(k2, (n, side, side))
    return x, y


def image_ctx(cfg, images) -> np.ndarray:
    """(N, H, W) images -> (N, n_ctx_tokens, d_model) patch embeddings:
    contiguous pixel bands, no learned patchifier (the fp w_ctx projection
    inside each block is the learned part)."""
    imgs = np.asarray(images, np.float32)
    n = imgs.shape[0]
    flat = imgs.reshape(n, -1)
    want = cfg.n_ctx_tokens * cfg.d_model
    if flat.shape[1] != want:
        raise ValueError(
            f"image has {flat.shape[1]} pixels; arch {cfg.name} expects "
            f"n_ctx_tokens*d_model = {cfg.n_ctx_tokens}*{cfg.d_model} = {want}")
    return flat.reshape(n, cfg.n_ctx_tokens, cfg.d_model)


def train_classifier(cfg, *, steps: int = 150, lr: float = 0.1,
                     n_train: int = 512, seed: int = 0):
    """Train the LM-shaped classifier end-to-end (STE through the binary
    layers) on the stripe task.  Returns (params, train_accuracy).

    The model is queried exactly the way it is served: one QUERY_TOKEN
    prompt, image as ctx, class = argmax over the full vocab at the last
    position — so training also suppresses the non-class token ids and
    greedy serve-time sampling emits a class id.
    """
    from repro.models import lm  # deferred: lm imports the block registry

    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    imgs, y = synthetic_images(jax.random.PRNGKey(seed + 1), n_train)
    ctx = jnp.asarray(image_ctx(cfg, imgs))
    tokens = jnp.full((n_train, 1), QUERY_TOKEN, jnp.int32)

    def loss_fn(p):
        logits, _ = lm.forward(cfg, p, tokens, ctx)
        logp = jax.nn.log_softmax(logits[:, -1, :cfg.vocab]
                                  .astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(loss_fn)(p)
        return jax.tree.map(lambda w, gw: w - lr * gw, p, g), l

    for _ in range(steps):
        params, _ = step(params)

    logits, _ = lm.forward(cfg, params, tokens, ctx)
    acc = float(jnp.mean(
        jnp.argmax(logits[:, -1, :cfg.vocab], -1) == y))
    return params, acc
