"""Block-contract registry (DESIGN.md §16).

Every block kind registers a :class:`BlockContract` — its serving contract
*as data*: where its decode state lives (shared paged pool vs dense
per-slot vs nothing), which block-table class its pool reads and whether
that table is a recycling ring, whether its cached content is stable
enough to prefix-share, and whether it routes experts.  Consumers
(``models/lm.py``'s spec/step/prefill builders, the serve engine's
admission and prefix-eligibility gates, the paged split/merge plumbing)
read these declarations instead of switching on kind strings, so adding a
block kind — or a whole serving workload built from one — means writing
one module and registering it; no consumer changes.

The registry is deliberately tiny and import-free (no jax, no blocks):
``blocks.py`` registers the nine built-in kinds at import, satellite
modules (e.g. :mod:`repro.models.bcnn`) register theirs, and tests may
register throwaway kinds under :func:`temporary`.

Contract semantics:

``paged_kv``
    The kind's decode state includes a shared :class:`PagedKVCache` pool
    (no batch axis; addressed through per-slot block tables).  Implies
    ``table_class`` is set.
``per_slot_state``
    The kind's decode state includes dense per-slot leaves (recurrent
    carries, cross-attn ``ctx_kv``) that ride the batch axis and are
    sliced/frozen per slot.  Both flags may be set (Whisper's decoder
    block: self-attn pool + ctx_kv), or neither (a stateless block).
``table_class``
    Name of the block-table class the pool is addressed through
    (``"full"`` monotone, ``"win"`` ring today; a new kind may name a new
    class and every consumer sizes/allocates it generically).
``window``
    The table is a sliding-window *ring*: physical blocks recycle in
    place, capacity is ``window + chunk - 1`` tokens, and contents are
    never stable (which is why a windowed kind cannot be prefix-shared).
``prefix_shareable``
    The kind's cached blocks fully encode its sequential state, so a
    prefix skipped at prefill can be rebuilt by mapping cached blocks.
    **Fail-closed**: the default is False, and the serve engine only
    enables prefix caching when every decoder kind declares True — a kind
    that says nothing is ineligible.
``decodes``
    The kind participates in the autoregressive decode path (False for
    encoder-only kinds, which only ever run inside ``lm.encode``).
``routed_experts``
    The kind's FFN routes tokens to ``cfg.top_k`` of ``cfg.n_experts``
    experts (active-parameter accounting discounts the unrouted ones).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Iterator


@dataclasses.dataclass(frozen=True)
class BlockContract:
    """A block kind's declared serving contract (see module docstring)."""

    kind: str
    paged_kv: bool = False
    per_slot_state: bool = False
    table_class: str | None = None
    window: bool = False
    prefix_shareable: bool = False
    decodes: bool = True
    routed_experts: bool = False

    def __post_init__(self):
        if not self.kind:
            raise ValueError("contract needs a non-empty kind name")
        if self.paged_kv and self.table_class is None:
            raise ValueError(
                f"kind {self.kind!r}: a paged-pool state needs a "
                f"table_class to address the pool through")
        if self.window and self.table_class is None:
            raise ValueError(
                f"kind {self.kind!r}: window ring semantics describe a "
                f"block table; declare its table_class")
        if self.window and self.prefix_shareable:
            raise ValueError(
                f"kind {self.kind!r}: a window ring recycles physical "
                f"blocks in place — its contents are never stable enough "
                f"to prefix-share (DESIGN.md §15)")


_KINDS: dict[str, type] = {}


def register(cls: type) -> type:
    """Class decorator: register a block component under its contract's
    kind name.  The class must carry a ``contract: BlockContract`` and the
    block surface (``defs/fwd/decode/chunk/state_spec/...`` — the
    conformance suite in ``tests/test_registry.py`` pins the full list for
    every registered kind).  Re-registering a kind is an error; use
    :func:`temporary` for test doubles."""
    contract = getattr(cls, "contract", None)
    if not isinstance(contract, BlockContract):
        raise TypeError(
            f"{cls.__name__} must declare a BlockContract as `contract`")
    if contract.kind in _KINDS:
        raise ValueError(f"block kind {contract.kind!r} already registered "
                         f"by {_KINDS[contract.kind].__name__}")
    for attr in ("defs", "fwd", "state_spec"):
        if not callable(getattr(cls, attr, None)):
            raise TypeError(f"{cls.__name__} ({contract.kind!r}) lacks "
                            f"required block method {attr}()")
    _KINDS[contract.kind] = cls
    return cls


def unregister(kind: str) -> None:
    _KINDS.pop(kind, None)


@contextlib.contextmanager
def temporary(cls: type) -> Iterator[type]:
    """Register ``cls`` for the duration of a with-block (tests)."""
    register(cls)
    try:
        yield cls
    finally:
        unregister(cls.contract.kind)


def get(kind: str) -> type:
    try:
        return _KINDS[kind]
    except KeyError:
        raise KeyError(
            f"unknown block kind {kind!r} — registered: "
            f"{sorted(_KINDS)} (import the module that registers it)"
        ) from None


def contract(kind: str) -> BlockContract:
    return get(kind).contract


def kinds() -> list[str]:
    """Registered kind names, sorted (stable test parameterization)."""
    return sorted(_KINDS)


def items() -> list[tuple[str, Any]]:
    return sorted(_KINDS.items())


def view() -> dict[str, Any]:
    """The live kind->class table (mutate via register/unregister only)."""
    return _KINDS
