"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM) and Griffin's RG-LRU.

These are the sub-quadratic archs that carry the ``long_500k`` shape: their
per-token state is sequence-length independent (mLSTM: per-head matrix
memory; sLSTM: per-head scalars; RG-LRU: a width-d vector).

Numerics: all recurrences run in f32 with log-domain stabilizers (m-state)
following arXiv:2405.04517; the chunkwise-parallel mLSTM (training path) is
tested bit-close against the sequential oracle.  RG-LRU trains via
``jax.lax.associative_scan`` (log-depth — the sequence-parallel story).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.params import ParamDef

F32 = jnp.float32


# ---------------------------------------------------------------------------
# causal depthwise conv1d (shared by mLSTM and RG-LRU blocks)
# ---------------------------------------------------------------------------

def conv1d(x: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, D), kernel (W, D) depthwise causal: y_t = sum_w k_w x_{t-w}."""
    w = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    s = x.shape[1]
    return sum(xp[:, i:i + s] * kernel[w - 1 - i].astype(x.dtype)
               for i in range(w))


def conv1d_carry(buf: jnp.ndarray, x: jnp.ndarray, kernel: jnp.ndarray):
    """Chunked-prefill form: like :func:`conv1d` but the left context is the
    ``(B, W-1, D)`` carry buffer from the previous chunk instead of zeros
    (identical to conv1d when ``buf`` is zero — the fresh-slot case)."""
    w = kernel.shape[0]
    xp = jnp.concatenate([buf.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    return sum(xp[:, i:i + s] * kernel[w - 1 - i].astype(x.dtype)
               for i in range(w))


def conv1d_carry_out(buf: jnp.ndarray, x: jnp.ndarray, valid_len):
    """New carry buffer after a chunk: the last W-1 *valid* inputs.  With
    ``valid_len`` < W-1 the tail of the old buffer is retained (padding
    tokens at the chunk end never enter the history)."""
    w1 = buf.shape[1]
    hist = jnp.concatenate([buf.astype(x.dtype), x], axis=1)
    return jax.lax.dynamic_slice_in_dim(hist, valid_len, w1, axis=1)


def conv1d_step(buf: jnp.ndarray, x: jnp.ndarray, kernel: jnp.ndarray):
    """Decode step. buf (B, W-1, D) holds previous inputs; x (B, 1, D).
    Returns (y (B, 1, D), new buf)."""
    w = kernel.shape[0]
    hist = jnp.concatenate([buf, x], axis=1)              # (B, W, D)
    # hist[w-1] is the current token and must meet kernel[0] (see conv1d:
    # kernel[j] multiplies x_{t-j}), so the kernel is reversed here.
    y = jnp.einsum("bwd,wd->bd", hist.astype(F32),
                   kernel[::-1].astype(F32))
    return y[:, None].astype(x.dtype), hist[:, 1:]


# ---------------------------------------------------------------------------
# mLSTM (matrix memory) — sequential oracle + chunkwise-parallel training form
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, NH, dh, dh) stabilized matrix memory C~ = C*exp(-m)
    n: jnp.ndarray   # (B, NH, dh)
    m: jnp.ndarray   # (B, NH)

    @classmethod
    def zeros(cls, b, nh, dh):
        return cls(jnp.zeros((b, nh, dh, dh), F32), jnp.zeros((b, nh, dh), F32),
                   jnp.full((b, nh), -1e30, F32))

    @classmethod
    def abstract(cls, b, nh, dh):
        return cls(jax.ShapeDtypeStruct((b, nh, dh, dh), F32),
                   jax.ShapeDtypeStruct((b, nh, dh), F32),
                   jax.ShapeDtypeStruct((b, nh), F32))


def mlstm_step(state: MLSTMState, q, k, v, i_raw, f_raw):
    """One token. q/k/v (B, NH, dh); i_raw/f_raw (B, NH). Returns (h, state)."""
    lf = jax.nn.log_sigmoid(f_raw.astype(F32))
    m_new = jnp.maximum(lf + state.m, i_raw.astype(F32))
    fp = jnp.exp(lf + state.m - m_new)
    ip = jnp.exp(i_raw.astype(F32) - m_new)
    k32, v32, q32 = k.astype(F32), v.astype(F32), q.astype(F32)
    c = fp[..., None, None] * state.c + ip[..., None, None] * (
        v32[..., :, None] * k32[..., None, :])
    n = fp[..., None] * state.n + ip[..., None] * k32
    num = jnp.einsum("bhij,bhj->bhi", c, q32)
    den = jnp.abs(jnp.einsum("bhj,bhj->bh", n, q32))
    den = jnp.maximum(den, jnp.exp(-m_new))
    h = num / den[..., None]
    return h, MLSTMState(c, n, m_new)


def mlstm_sequential(q, k, v, i_raw, f_raw, state: MLSTMState):
    """Oracle: scan mlstm_step over time. q/k/v (B, S, NH, dh)."""
    def step(st, xs):
        qt, kt, vt, it, ft = xs
        h, st = mlstm_step(st, qt, kt, vt, it, ft)
        return st, h
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_raw, f_raw))
    state, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), state


def mlstm_chunkwise(q, k, v, i_raw, f_raw, state: MLSTMState, chunk: int,
                    unroll: bool = False):
    """Chunkwise-parallel mLSTM: intra-chunk quadratic + inter-chunk state.

    q/k/v: (B, S, NH, dh); i_raw/f_raw: (B, S, NH).  Ragged tails are padded
    with state-neutral gates (i = -inf: nothing inserted; f = +inf: no decay)
    so the returned boundary state equals the unpadded sequential state.
    Matches mlstm_sequential (tests assert allclose).
    """
    b, s, nh, dh = q.shape
    pad = (-s) % chunk
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, padw) for a in (q, k, v))
        i_raw = jnp.pad(i_raw, padw[:3], constant_values=-1e30)
        f_raw = jnp.pad(f_raw, padw[:3], constant_values=1e30)
    out_s = s
    s = s + pad
    ncs = s // chunk

    def to_chunks(x):
        return jnp.moveaxis(
            x.reshape(b, ncs, chunk, *x.shape[2:]), 1, 0)  # (ncs, B, chunk, ...)

    qc, kc, vc, ic, fc = map(to_chunks, (q, k, v, i_raw, f_raw))

    def one_chunk(st: MLSTMState, xs):
        qt, kt, vt, it, ft = xs                   # (B, L, NH, ...)
        qt, kt, vt = (a.astype(F32) for a in (qt, kt, vt))
        it, ft = it.astype(F32), ft.astype(F32)
        lf = jax.nn.log_sigmoid(ft)               # (B, L, NH)
        bcum = jnp.cumsum(lf, axis=1)             # inclusive cumsum b_s
        g = bcum[:, -1]                           # (B, NH) total decay

        # log-scales: inter a_t = b_t + m_prev ; intra D_ts = b_t - b_s + i_s
        a_inter = bcum + st.m[:, None, :]                       # (B, L, NH)
        dmat = (bcum[:, :, None, :] - bcum[:, None, :, :]
                + it[:, None, :, :])                            # (B, t, s, NH)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        m_t = jnp.maximum(a_inter, dmat.max(axis=2))            # (B, L, NH)

        w_inter = jnp.exp(a_inter - m_t)                        # (B, L, NH)
        w_intra = jnp.exp(dmat - m_t[:, :, None, :])            # (B, t, s, NH)

        sqk = jnp.einsum("blhd,bshd->blsh", qt, kt)             # (B, t, s, NH)
        num = (jnp.einsum("blsh,blsh,bshd->blhd", w_intra, sqk, vt)
               + w_inter[..., None] * jnp.einsum("blhd,bhed->blhe", qt, st.c))
        den = (jnp.einsum("blsh,blsh->blh", w_intra, sqk)
               + w_inter * jnp.einsum("blhd,bhd->blh", qt, st.n))
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h = num / den[..., None]                                # (B, L, NH, dh)

        # boundary update
        scale_s = g[:, None, :] - bcum + it                     # (B, L, NH)
        m_new = jnp.maximum(g + st.m, scale_s.max(axis=1))
        w_old = jnp.exp(g + st.m - m_new)
        w_s = jnp.exp(scale_s - m_new[:, None, :])              # (B, L, NH)
        c_new = (w_old[..., None, None] * st.c
                 + jnp.einsum("blh,blhd,blhe->bhde", w_s, vt, kt))
        n_new = (w_old[..., None] * st.n
                 + jnp.einsum("blh,blhd->bhd", w_s, kt))
        return MLSTMState(c_new, n_new, m_new), h

    if unroll:
        hs = []
        for j in range(ncs):
            state, hj = one_chunk(state, jax.tree.map(
                lambda a: a[j], (qc, kc, vc, ic, fc)))
            hs.append(hj)
        hs = jnp.stack(hs)
    else:
        state, hs = jax.lax.scan(one_chunk, state, (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, nh, dh)
    return h[:, :out_s], state


# ---------------------------------------------------------------------------
# sLSTM — scalar memory with exponential gating (sequential by construction)
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, D) stabilized cell
    n: jnp.ndarray   # (B, D)
    m: jnp.ndarray   # (B, D)
    h: jnp.ndarray   # (B, D) output (enters the recurrence)

    @classmethod
    def zeros(cls, b, d):
        z = jnp.zeros((b, d), F32)
        return cls(z, z, jnp.full((b, d), -1e30, F32), z)

    @classmethod
    def abstract(cls, b, d):
        sd = jax.ShapeDtypeStruct((b, d), F32)
        return cls(sd, sd, sd, sd)


def slstm_step(state: SLSTMState, x_gates, r_kernel, nh: int):
    """x_gates: (B, 4D) preactivations from the input; r_kernel (4, NH, dh, dh)
    block-diagonal recurrent weights applied to h."""
    b, d4 = x_gates.shape
    d = d4 // 4
    dh = d // nh
    hprev = state.h.reshape(b, nh, dh)
    rec = jnp.einsum("bhd,ghde->bghe", hprev, r_kernel.astype(F32))  # (B,4,NH,dh)
    gates = x_gates.astype(F32).reshape(b, 4, nh, dh) + rec
    zt, it, ft, ot = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
    zt = jnp.tanh(zt).reshape(b, d)
    ot = jax.nn.sigmoid(ot).reshape(b, d)
    it = it.reshape(b, d)
    lf = jax.nn.log_sigmoid(ft).reshape(b, d)
    m_new = jnp.maximum(lf + state.m, it)
    fp, ip = jnp.exp(lf + state.m - m_new), jnp.exp(it - m_new)
    c = fp * state.c + ip * zt
    n = fp * state.n + ip
    h = ot * c / jnp.maximum(n, jnp.exp(-m_new))
    return SLSTMState(c, n, m_new, h), h


def slstm_sequence(x_gates, r_kernel, state: SLSTMState, nh: int,
                   valid: jnp.ndarray | None = None):
    """x_gates (B, S, 4D) -> h (B, S, D). True recurrence: lax.scan over S.

    ``valid`` (B, S) bool gates the state update per step (chunked prefill:
    padding tokens at the chunk end pass the state through unchanged)."""
    if valid is None:
        def step(st, xg):
            return slstm_step(st, xg, r_kernel, nh)
        state, hs = jax.lax.scan(step, state, jnp.moveaxis(x_gates, 1, 0))
    else:
        def step(st, xs):
            xg, vt = xs
            new, h = slstm_step(st, xg, r_kernel, nh)
            keep = vt[:, None]
            new = jax.tree.map(lambda a, b: jnp.where(keep, a, b), new, st)
            return new, h
        state, hs = jax.lax.scan(step, state,
                                 (jnp.moveaxis(x_gates, 1, 0),
                                  jnp.moveaxis(valid, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), state


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

class RGLRUState(NamedTuple):
    h: jnp.ndarray   # (B, D) f32

    @classmethod
    def zeros(cls, b, d):
        return cls(jnp.zeros((b, d), F32))

    @classmethod
    def abstract(cls, b, d):
        return cls(jax.ShapeDtypeStruct((b, d), F32))


def rglru(x: jnp.ndarray, r_gate: jnp.ndarray, i_gate: jnp.ndarray,
          lam: jnp.ndarray, c: float, state: RGLRUState,
          valid: jnp.ndarray | None = None):
    """Sequence form via associative scan (log-depth).

    x, r_gate, i_gate: (B, S, D) (gates are pre-sigmoid); lam: (D,) raw Λ.
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(lam) * sigmoid(r_t)).
    ``valid`` (B, S) bool forces padding steps to the exact identity
    (a_t = 1, b_t = 0), so the boundary state of a ragged chunked-prefill
    piece equals the unpadded state.
    """
    log_a = (-c * jax.nn.softplus(lam.astype(F32))
             * jax.nn.sigmoid(r_gate.astype(F32)))            # (B, S, D)
    gated = jax.nn.sigmoid(i_gate.astype(F32)) * x.astype(F32)
    if valid is not None:
        log_a = jnp.where(valid[..., None], log_a, 0.0)
        gated = jnp.where(valid[..., None], gated, 0.0)
    a = jnp.exp(log_a)
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    # prepend the carry as the first element, scan, drop it
    a_all = jnp.concatenate([jnp.ones_like(state.h[:, None]), a], axis=1)
    b_all = jnp.concatenate([state.h[:, None], b_t], axis=1)
    _, h_all = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    h = h_all[:, 1:]
    return h.astype(x.dtype), RGLRUState(h_all[:, -1])


def rglru_step(x, r_gate, i_gate, lam, c: float, state: RGLRUState):
    """One decode token: x/r/i (B, 1, D)."""
    log_a = (-c * jax.nn.softplus(lam.astype(F32))
             * jax.nn.sigmoid(r_gate[:, 0].astype(F32)))
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i_gate[:, 0].astype(F32)) * x[:, 0].astype(F32)
    h = a * state.h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    return h[:, None].astype(x.dtype), RGLRUState(h)
