"""Mixture-of-Experts FFN: top-k routing with grouped GShard-style dispatch.

Tokens are processed in groups (one dispatch problem per group) so the
dispatch/combine one-hots stay O(group_len^2 * k) regardless of expert
count; experts are sharded over the "ep" logical axis (-> mesh "model"), so
the dispatch einsum lowers to the canonical all-to-all pattern.

Capacity: C = ceil(group_len * top_k / E * capacity_factor); overflow tokens
are dropped (their combine weight is zero — the residual path carries them),
standard GShard/Switch behavior.

The router stays full-precision even under quant="xnor" (binary routers
collapse; XNOR-Net also exempts the network's decision layers — DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models.params import ParamDef

F32 = jnp.float32


def moe_defs(cfg, n: int) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    return {
        "router": ParamDef((n, d, e), (None, "fsdp", None), F32),
        "w1": ParamDef((n, e, d, ff), (None, "ep", "fsdp", None), cfg.dtype),
        "w3": ParamDef((n, e, d, ff), (None, "ep", "fsdp", None), cfg.dtype),
        "w2": ParamDef((n, e, ff, d), (None, "ep", None, "fsdp"), cfg.dtype),
    }


def group_len(cfg) -> int:
    """Dispatch-tensor budget: size ~ group_len^2 * k * cf (dtype bytes)."""
    return 512 if cfg.top_k > 2 else 1024


def capacity(cfg, tg: int) -> int:
    return max(1, int(tg * cfg.top_k / cfg.n_experts * cfg.capacity_factor))


def moe_ffn(cfg, p: dict, x: jnp.ndarray, valid: jnp.ndarray | None = None):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    ``valid`` (B, S) bool excludes padding tokens (chunked-prefill ragged
    tails) from routing entirely: they occupy no expert capacity, their
    combine weight is zero, and the aux loss ignores them.
    """
    b, s, d = x.shape
    tg = min(group_len(cfg), s)
    assert (b * s) % tg == 0, (b, s, tg)
    g = (b * s) // tg
    xg = x.reshape(g, tg, d)

    xg = constrain(xg, "batch", None, None)
    logits = jnp.einsum("gtd,de->gte", xg.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (G, T, E)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)                 # (G, T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, tg)
    mask = jax.nn.one_hot(idx, e, dtype=F32)                     # (G, T, k, E)
    if valid is not None:
        mask = mask * valid.reshape(g, tg)[:, :, None, None].astype(F32)
    # position of each (token, choice) within its expert queue; choices of
    # earlier tokens and earlier k-slots go first (choice-major priority).
    prio = jnp.moveaxis(mask, 2, 1).reshape(g, k * tg, e)
    pos = jnp.cumsum(prio, axis=1) - prio
    pos = jnp.moveaxis(pos.reshape(g, k, tg, e), 1, 2)           # (G, T, k, E)
    keep = (pos < c).astype(F32) * mask
    pos_sel = jnp.sum(pos * keep, axis=-1)                       # (G, T, k)
    gate_kept = gates * jnp.sum(keep, axis=-1)                   # (G, T, k)
    pos_oh = jax.nn.one_hot(pos_sel, c, dtype=F32)               # (G, T, k, C)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate_kept, keep, pos_oh)
    dispatch = (combine > 0).astype(x.dtype)                     # (G, T, E, C)

    xe = jnp.einsum("gtec,gtd->egcd", dispatch, xg)              # (E, G, C, d)
    xe = constrain(xe, "ep", "batch", None, None)   # the all-to-all boundary
    h = (jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, p["w1"]))
         * jnp.einsum("egcd,edf->egcf", xe, p["w3"]))
    h = constrain(h, "ep", "batch", None, None)
    ye = jnp.einsum("egcf,efd->egcd", h, p["w2"])                # (E, G, C, d)
    ye = constrain(ye, "ep", "batch", None, None)
    y = jnp.einsum("gtec,egcd->gtd", combine.astype(ye.dtype), ye)
    y = constrain(y, "batch", None, None)

    # Switch/GShard load-balancing loss: E * sum_e f_e * P_e (means over
    # valid tokens only — padding must pollute neither factor)
    if valid is not None:
        v = valid.reshape(g, tg, 1).astype(F32)
        denom = jnp.maximum(jnp.sum(v), 1.0)
        f_e = jnp.sum(mask[:, :, 0, :], axis=(0, 1)) / denom
        p_e = jnp.sum(probs * v, axis=(0, 1)) / denom
    else:
        f_e = jnp.mean(mask[:, :, 0, :], axis=(0, 1))            # top-1 frac
        p_e = jnp.mean(probs, axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(f_e * p_e)
    return y.reshape(b, s, d).astype(x.dtype), aux
