"""Parameter definition machinery.

Every model declares its parameters once as a pytree of :class:`ParamDef`
(shape + dtype + logical sharding spec + init scale).  Three products derive
from that single declaration:

* ``abstract(defs)``   -> pytree of ShapeDtypeStruct (dry-run lowering —
                          no allocation, the 512-device path),
* ``pspecs(defs)``     -> pytree of jax.sharding.PartitionSpec,
* ``init(defs, key)``  -> real arrays (CPU-scale smoke tests / examples).

Logical axes used in specs (mapped to mesh axes in distributed/sharding.py):
  "fsdp"   — parameter shards over the data axis (ZeRO-3 style)
  "tp"     — tensor-parallel over the model axis (heads / d_ff / vocab)
  "ep"     — expert-parallel over the model axis
  None     — replicated
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class ParamDef(NamedTuple):
    shape: tuple
    spec: tuple             # logical axis per dim ("fsdp"/"tp"/"ep"/None)
    dtype: Any = jnp.float32
    init: str = "normal"    # "normal" | "zeros" | "ones" | "embed"
    scale: float | None = None  # None => 1/sqrt(fan_in)
    binarize: bool = False  # binarizable linear under quant="xnor": packed to
                            # sign-planes for serving (routers/norms/embeddings
                            # /lm-head stay full precision — DESIGN.md §5)


def abstract(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def logical_specs(defs):
    return jax.tree.map(lambda d: d.spec, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def pspecs(defs, rules: dict[str, Any]):
    """Map logical axes to mesh axes per ``rules`` (e.g. {"tp": "model"})."""
    def one(d):
        return P(*(rules.get(a) if a is not None else None for a in d.spec))
    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def init(defs, key: jax.Array):
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, d.dtype))
        else:
            if d.scale is not None:
                s = d.scale
            elif d.init == "embed" or len(d.shape) < 2:
                s = 1.0
            else:
                # stacked-layer weights: fan_in is the second-to-last dim
                s = 1.0 / math.sqrt(d.shape[-2])
            out.append((s * jax.random.normal(k, d.shape)).astype(d.dtype))
    return jax.tree.unflatten(treedef, out)


def count(defs) -> int:
    return sum(math.prod(d.shape) for d in jax.tree.leaves(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)))


# ---------------------------------------------------------------------------
# packed-weight residency (serve form of binarizable linears)
# ---------------------------------------------------------------------------


def pack(defs, tree, impl: str = "auto"):
    """Replace every ``binarize``-marked float leaf with its packed form.

    The returned tree holds :class:`repro.core.xnor_layers.PackedLinear`
    nodes (uint32 sign planes + f32 beta) where the defs mark binarizable
    linears — the float weights for those leaves are *absent* from the
    result, which is the packed-residency contract: at serve time the
    binary filters only exist as bit-planes (a 16x footprint cut vs bf16).
    All other leaves pass through unchanged.  Idempotent: leaves that are
    already ``PackedLinear`` pass through too, so a tree loaded via
    ``ckpt.restore_packed`` can be handed to consumers that pack by default
    (``ServeEngine``) without double-packing.
    """
    from repro.core import xnor_layers

    def one(d, w):
        if d.binarize and not isinstance(w, xnor_layers.PackedLinear):
            return xnor_layers.pack_linear(w, impl=impl)
        return w
    return jax.tree.map(one, defs, tree,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def pack_abstract(defs):
    """ShapeDtypeStruct tree of :func:`pack` output (restore-`like` trees)."""
    from repro.core import bitpack, xnor_layers

    def one(d):
        if not d.binarize:
            return jax.ShapeDtypeStruct(d.shape, d.dtype)
        *lead, k, n = d.shape
        kw = bitpack.packed_width(k)
        return xnor_layers.PackedLinear(
            jax.ShapeDtypeStruct((*lead, n, kw), jnp.uint32),
            jax.ShapeDtypeStruct((*lead, n), jnp.float32), k=k)
    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))
