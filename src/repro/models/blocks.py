"""Decoder blocks (one per kind) + the scanned-segment machinery.

A model's depth plan is a list of (kind, count) segments
(``ArchConfig.segments()``).  Within a segment all layers share a kind, so
their parameters are stacked on a leading axis and the segment is applied
with ``jax.lax.scan`` — keeping the HLO size O(#segments), not O(#layers),
which is what makes 512-device lower+compile tractable for 48-layer models.

Block kinds:
  attn   — GQA self-attention + SwiGLU FFN (dense transformers)
  local  — sliding-window GQA + FFN (RecurrentGemma attention layers)
  moe    — GQA self-attention + top-k MoE FFN
  cross  — cross-attention to modality context + FFN (Llama-3.2-Vision)
  enc    — bidirectional self-attention + FFN (Whisper encoder)
  dec    — self-attn + cross-attn + FFN (Whisper decoder)
  rglru  — Griffin recurrent block (conv1d + RG-LRU) + FFN
  mlstm  — xLSTM mLSTM block (own up/down projections, no FFN)
  slstm  — xLSTM sLSTM block (post-up GLU projection)

Every kind implements:
  defs(cfg, n)                          stacked ParamDefs
  fwd(cfg, p, x, ctx, opts)             -> (x, aux, state|None)
  decode(cfg, p, x, state, pos, ctx)    -> (x, state)
  state_spec(cfg, batch, s_max, abstract) decode-state pytree per layer

Paged serving (DESIGN.md §14) adds a parallel surface:
  paged_state_spec(...)                 per-layer state with KV caches
                                        replaced by shared PagedKVCache pools
  paged_split / paged_merge             separate the pool (shared, no batch
                                        axis) from dense per-slot leaves
  decode(..., table=)                   gather/scatter through a block table
  chunk(...)                           one chunked-prefill piece (B=1, S=C)

Each kind declares a :class:`BlockContract` (DESIGN.md §16) naming its
state layout, table class, and prefix-shareability; the paged surface is
*generated* from that contract by :class:`PagedLayout`, and every
consumer — the segment machinery below, ``lm.py``'s builders, the serve
scheduler's gates — reads contracts instead of matching kind strings.
New kinds register through ``repro.models.registry`` and plug into all
of it without edits here.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.ctx import constrain
from repro.models import attention as attn_mod
from repro.models import layers, moe, registry, ssm
from repro.models.attention import KVCache, PagedKVCache
from repro.models.params import ParamDef
from repro.models.registry import BlockContract, register


class FwdOpts(NamedTuple):
    q_chunk: int = 0          # stream queries in chunks of this size
    want_state: bool = False  # prefill: return decode state
    s_max: int = 0            # cache capacity when want_state
    unroll: bool = False      # unroll inner chunk loops (exact HLO costs)


def _norm_def(cfg, n):
    return ParamDef((n, cfg.d_model), (None, None), jnp.float32, init="ones")


# ---------------------------------------------------------------------------
# attention-family blocks
# ---------------------------------------------------------------------------

def _attn_ffn_defs(cfg, n, window=False, moe_ffn_=False, cross=False,
                   encdec=False):
    defs = {"ln1": _norm_def(cfg, n), "ln2": _norm_def(cfg, n)}
    defs |= {f"attn_{k}": v for k, v in attn_mod.attn_defs(cfg, n, cross=cross).items()}
    if encdec:
        defs["lnx"] = _norm_def(cfg, n)
        defs |= {f"xattn_{k}": v
                 for k, v in attn_mod.attn_defs(cfg, n, cross=True).items()}
    if moe_ffn_:
        defs |= {f"moe_{k}": v for k, v in moe.moe_defs(cfg, n).items()}
    else:
        defs |= {f"ffn_{k}": v for k, v in layers.ffn_defs(cfg, n).items()}
    return defs


def _sub(p: dict, prefix: str) -> dict:
    cut = len(prefix)
    return {k[cut:]: v for k, v in p.items() if k.startswith(prefix)}


def _kv_from_seq(cfg, k, v, s_max, rolling: bool = False):
    """(B, S, KV, dh) k/v -> KVCache of capacity s_max.

    ``rolling=True`` (local windows): keep the trailing s_max tokens laid out
    so that slot == position % s_max, matching decode_attention's rolling
    write (token at position p lands in slot p % s_max).
    """
    s = k.shape[1]
    if rolling and s > s_max:
        k, v = k[:, -s_max:], v[:, -s_max:]
        shift = s % s_max
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
    ck = jnp.moveaxis(k, 1, 2)
    cv = jnp.moveaxis(v, 1, 2)
    pad = s_max - ck.shape[2]
    if pad > 0:
        ck = jnp.pad(ck, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cv = jnp.pad(cv, ((0, 0), (0, 0), (0, pad), (0, 0)))
    if cfg.kv_cache_dtype == "i8":
        return KVCache(attn_mod.i8_encode(cfg, ck), attn_mod.i8_encode(cfg, cv))
    return KVCache(ck.astype(cfg.dtype), cv.astype(cfg.dtype))


class PagedLayout:
    """Contract-driven paged-serving surface.

    The whole ``paged_state_spec``/``paged_split``/``paged_merge`` triple
    is derived from the kind's declared :class:`BlockContract` instead of
    hand-copied per class:

      ``paged_kv`` only         state IS the shared pool (KV caches become
                                PagedKVCache pools, no batch axis)
      ``per_slot_state`` only   paged layout == dense layout — documented
                                exception in DESIGN.md §14 (recurrent state
                                is O(1) per slot; nothing block-granular to
                                page)
      both                      state is a (pool, per_slot) pair (Whisper
                                decoder: self-attn pool + ctx_kv)
      neither                   stateless; None flows through everything

    Kinds with ``paged_kv`` may override :meth:`pool_spec` (default: one
    PagedKVCache pool honoring ``kv_cache_dtype``); kinds with
    ``per_slot_state`` may override :meth:`slot_spec` (default: the dense
    ``state_spec``, correct whenever the dense state is entirely per-slot).
    """

    @classmethod
    def pool_spec(cls, cfg, n_blocks, block_size, abstract):
        mk = PagedKVCache.abstract if abstract else PagedKVCache.zeros
        dt = jnp.int8 if cfg.kv_cache_dtype == "i8" else cfg.dtype
        return mk(cfg, n_blocks, block_size, dtype=dt)

    @classmethod
    def slot_spec(cls, cfg, batch, s_max, abstract):
        return cls.state_spec(cfg, batch, s_max, abstract)

    @classmethod
    def paged_state_spec(cls, cfg, batch, s_max, n_blocks, block_size,
                         abstract):
        c = cls.contract
        if c.paged_kv and c.per_slot_state:
            return (cls.pool_spec(cfg, n_blocks, block_size, abstract),
                    cls.slot_spec(cfg, batch, s_max, abstract))
        if c.paged_kv:
            return cls.pool_spec(cfg, n_blocks, block_size, abstract)
        if c.per_slot_state:
            return cls.slot_spec(cfg, batch, s_max, abstract)
        return None

    @classmethod
    def paged_split(cls, state):
        """-> (shared pool leaves, per-slot leaves)."""
        c = cls.contract
        if c.paged_kv and c.per_slot_state:
            return state[0], state[1]
        if c.paged_kv:
            return state, None
        return None, state

    @classmethod
    def paged_merge(cls, shared, per_slot):
        c = cls.contract
        if c.paged_kv and c.per_slot_state:
            return (shared, per_slot)
        if c.paged_kv:
            return shared
        return per_slot

    # -- slot extraction / injection (DESIGN.md §17: session migration) ------

    @classmethod
    def export_slot(cls, state, slot, ids):
        """Lift one slot's complete state out of the stacked paged layout.

        ``state`` is the segment-stacked paged state (pool leaves
        ``(n, n_blocks, ...)``, per-slot leaves ``(n, batch, ...)``);
        ``slot`` is a device scalar; ``ids`` is this kind's full (W,)
        block-table row (physical block ids; unused entries point at the
        trash block 0, whose gathered garbage is carried along and never
        read).  Returns ``(shared, per_slot)`` payloads — pool blocks in
        table-row order (which is exactly what preserves position->block
        addressing on re-import, including window *rings*, whose
        ``(pos // bs) % W`` mapping is a function of row order alone) and
        the slot's dense leaves at batch width 1.  Contract-generic: no
        kind overrides this."""
        shared, per_slot = cls.paged_split(state)
        sh = None if shared is None else jax.tree.map(
            lambda l: jnp.take(l, ids, axis=1), shared)
        ps = None if per_slot is None else jax.tree.map(
            lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1),
            per_slot)
        return (sh, ps)

    @classmethod
    def import_slot(cls, state, slot, ids, payload):
        """Inverse of :meth:`export_slot`: scatter a payload into ``slot``
        and the blocks named by ``ids`` (the *destination* table row — same
        width, freshly allocated ids).  Unused row entries are 0, so the
        payload's trash-gathered garbage lands back in the trash block —
        harmless by the §14 never-read invariant."""
        sh_p, ps_p = payload
        shared, per_slot = cls.paged_split(state)
        if shared is not None:
            shared = jax.tree.map(
                lambda l, q: l.at[:, ids].set(q.astype(l.dtype)),
                shared, sh_p)
        if per_slot is not None:
            per_slot = jax.tree.map(
                lambda l, q: jax.lax.dynamic_update_slice_in_dim(
                    l, q.astype(l.dtype), slot, axis=1),
                per_slot, ps_p)
        return cls.paged_merge(shared, per_slot)


@register
class AttnBlock(PagedLayout):
    contract = BlockContract("attn", paged_kv=True, table_class="full",
                             prefix_shareable=True)
    causal = True
    window = 0

    @classmethod
    def defs(cls, cfg, n):
        return _attn_ffn_defs(cfg, n)

    @classmethod
    def _ffn(cls, cfg, p, x, valid=None):
        h = layers.rms_norm(x, p["ln2"])
        return x + layers.ffn(cfg, _sub(p, "ffn_"), h), jnp.float32(0.0)

    @classmethod
    def fwd(cls, cfg, p, x, ctx, opts: FwdOpts):
        h = layers.rms_norm(x, p["ln1"])
        ap = _sub(p, "attn_")
        win = cfg.local_window if cls.window else 0
        state = None
        if opts.want_state:
            s = h.shape[1]
            positions = jnp.arange(s)
            k, v = attn_mod._project_kv(cfg, ap, h, positions)
            cap = min(opts.s_max, win) if win else opts.s_max
            state = _kv_from_seq(cfg, k, v, cap, rolling=bool(win))
        y = attn_mod.attention(cfg, ap, h, causal=cls.causal, window=win,
                               q_chunk=opts.q_chunk, unroll=opts.unroll)
        x = x + y
        x, aux = cls._ffn(cfg, p, x)
        return x, aux, state

    @classmethod
    def decode(cls, cfg, p, x, state, pos, ctx, table=None, valid=None):
        h = layers.rms_norm(x, p["ln1"])
        win = cfg.local_window if cls.window else 0
        if table is not None:
            y, state = attn_mod.paged_attention(cfg, _sub(p, "attn_"), h,
                                                state, table, pos, window=win,
                                                valid=valid)
        else:
            y, state = attn_mod.decode_attention(cfg, _sub(p, "attn_"), h,
                                                 state, pos, window=win)
        x = x + y
        x, _ = cls._ffn(cfg, p, x)
        return x, state

    @classmethod
    def chunk(cls, cfg, p, x, state, pos0, valid, n_valid, ctx, table):
        """One chunked-prefill piece: x (1, C, d) at positions
        pos0..pos0+C-1, of which the first ``n_valid`` are real tokens."""
        h = layers.rms_norm(x, p["ln1"])
        win = cfg.local_window if cls.window else 0
        y, state = attn_mod.paged_attention(cfg, _sub(p, "attn_"), h, state,
                                            table, pos0, window=win,
                                            valid=valid)
        x = x + y
        x, _ = cls._ffn(cfg, p, x, valid=valid)
        return x, state

    @classmethod
    def state_spec(cls, cfg, batch, s_max, abstract):
        cap = min(s_max, cfg.local_window) if cls.window else s_max
        mk = KVCache.abstract if abstract else KVCache.zeros
        dt = jnp.int8 if cfg.kv_cache_dtype == "i8" else cfg.dtype
        return mk(cfg, batch, cap, dtype=dt)

    @classmethod
    def state_pspec(cls, cfg, ba, kv_shard: str = "heads", tp_size: int = 16):
        """ba = batch mesh axes; kv_shard: "heads" (TP over KV heads) or
        "seq" (sequence-parallel cache — the softmax reduces over shards,
        XLA inserts the partial-max/sum all-reduces)."""
        if kv_shard == "seq":
            spec = P(ba, None, "model", None)
        else:
            spec = P(ba, "model", None, None)
        return KVCache(spec, spec)


@register
class LocalBlock(AttnBlock):
    # a window ring recycles physical blocks in place — never shareable
    contract = BlockContract("local", paged_kv=True, table_class="win",
                             window=True)
    window = 1


@register
class EncBlock(AttnBlock):
    # encoder-only: runs inside lm.encode, never in the decode path
    contract = BlockContract("enc", paged_kv=True, table_class="full",
                             decodes=False)
    causal = False


@register
class MoeBlock(AttnBlock):
    contract = BlockContract("moe", paged_kv=True, table_class="full",
                             prefix_shareable=True, routed_experts=True)

    @classmethod
    def defs(cls, cfg, n):
        return _attn_ffn_defs(cfg, n, moe_ffn_=True)

    @classmethod
    def _ffn(cls, cfg, p, x, valid=None):
        h = layers.rms_norm(x, p["ln2"])
        y, aux = moe.moe_ffn(cfg, _sub(p, "moe_"), h, valid=valid)
        return x + y, aux


@register
class CrossBlock(PagedLayout):
    # ctx_kv is a pure function of the request's context — rebuilding a
    # shared prefix cannot go stale, so sharing is safe (DESIGN.md §15)
    contract = BlockContract("cross", per_slot_state=True,
                             prefix_shareable=True)

    @classmethod
    def defs(cls, cfg, n):
        return _attn_ffn_defs(cfg, n, cross=True)

    @classmethod
    def fwd(cls, cfg, p, x, ctx, opts: FwdOpts):
        ap = _sub(p, "attn_")
        ctx_kv = attn_mod.make_ctx_kv(cfg, ap, ctx)
        h = layers.rms_norm(x, p["ln1"])
        x = x + attn_mod.cross_attention(cfg, ap, h, ctx_kv)
        h = layers.rms_norm(x, p["ln2"])
        x = x + layers.ffn(cfg, _sub(p, "ffn_"), h)
        state = ctx_kv if opts.want_state else None
        return x, jnp.float32(0.0), state

    @classmethod
    def decode(cls, cfg, p, x, state, pos, ctx, table=None, valid=None):
        h = layers.rms_norm(x, p["ln1"])
        x = x + attn_mod.decode_cross_attention(cfg, _sub(p, "attn_"), h, state)
        h = layers.rms_norm(x, p["ln2"])
        x = x + layers.ffn(cfg, _sub(p, "ffn_"), h)
        return x, state

    @classmethod
    def chunk(cls, cfg, p, x, state, pos0, valid, n_valid, ctx, table):
        # ctx_kv is recomputed from the per-request context each chunk (the
        # dense fwd recomputes it per forward too) and stored as the slot's
        # state so decode can read it without the raw ctx staying resident.
        ap = _sub(p, "attn_")
        ctx_kv = attn_mod.make_ctx_kv(cfg, ap, ctx)
        h = layers.rms_norm(x, p["ln1"])
        x = x + attn_mod.cross_attention(cfg, ap, h, ctx_kv)
        h = layers.rms_norm(x, p["ln2"])
        x = x + layers.ffn(cfg, _sub(p, "ffn_"), h)
        return x, ctx_kv

    @classmethod
    def state_spec(cls, cfg, batch, s_max, abstract):
        shp = (batch, cfg.n_ctx_tokens, cfg.n_kv_heads, cfg.d_head)
        if abstract:
            return (jax.ShapeDtypeStruct(shp, cfg.dtype),) * 2
        return (jnp.zeros(shp, cfg.dtype), jnp.zeros(shp, cfg.dtype))

    @classmethod
    def state_pspec(cls, cfg, ba, kv_shard: str = "heads", tp_size: int = 16):
        # ctx_kv (B, T_ctx, KV, dh): shard the ctx-token axis when divisible
        # (KV heads rarely divide 16-way TP), else replicate (small).
        if cfg.n_ctx_tokens % tp_size == 0:
            return (P(ba, "model", None, None),) * 2
        return (P(ba, None, None, None),) * 2


@register
class DecBlock(PagedLayout):
    """Whisper decoder block: self-attn + cross-attn(encoder) + FFN."""
    contract = BlockContract("dec", paged_kv=True, per_slot_state=True,
                             table_class="full", prefix_shareable=True)

    @classmethod
    def defs(cls, cfg, n):
        return _attn_ffn_defs(cfg, n, encdec=True)

    @classmethod
    def fwd(cls, cfg, p, x, ctx, opts: FwdOpts):
        h = layers.rms_norm(x, p["ln1"])
        ap = _sub(p, "attn_")
        self_state = None
        if opts.want_state:
            s = h.shape[1]
            k, v = attn_mod._project_kv(cfg, ap, h, jnp.arange(s))
            self_state = _kv_from_seq(cfg, k, v, opts.s_max)
        x = x + attn_mod.attention(cfg, ap, h, causal=True,
                                   q_chunk=opts.q_chunk, unroll=opts.unroll)
        xp = _sub(p, "xattn_")
        ctx_kv = attn_mod.make_ctx_kv(cfg, xp, ctx)
        h = layers.rms_norm(x, p["lnx"])
        x = x + attn_mod.cross_attention(cfg, xp, h, ctx_kv)
        h = layers.rms_norm(x, p["ln2"])
        x = x + layers.ffn(cfg, _sub(p, "ffn_"), h)
        state = (self_state, ctx_kv) if opts.want_state else None
        return x, jnp.float32(0.0), state

    @classmethod
    def decode(cls, cfg, p, x, state, pos, ctx, table=None, valid=None):
        self_cache, ctx_kv = state
        h = layers.rms_norm(x, p["ln1"])
        if table is not None:
            y, self_cache = attn_mod.paged_attention(cfg, _sub(p, "attn_"),
                                                     h, self_cache, table,
                                                     pos, valid=valid)
        else:
            y, self_cache = attn_mod.decode_attention(cfg, _sub(p, "attn_"),
                                                      h, self_cache, pos)
        x = x + y
        h = layers.rms_norm(x, p["lnx"])
        x = x + attn_mod.decode_cross_attention(cfg, _sub(p, "xattn_"), h, ctx_kv)
        h = layers.rms_norm(x, p["ln2"])
        x = x + layers.ffn(cfg, _sub(p, "ffn_"), h)
        return x, (self_cache, ctx_kv)

    @classmethod
    def chunk(cls, cfg, p, x, state, pos0, valid, n_valid, ctx, table):
        """ctx here is the *encoded* encoder output (1, T, d) — encoded once
        at admission, not once per chunk."""
        self_cache, _ = state
        h = layers.rms_norm(x, p["ln1"])
        y, self_cache = attn_mod.paged_attention(cfg, _sub(p, "attn_"), h,
                                                 self_cache, table, pos0,
                                                 valid=valid)
        x = x + y
        xp = _sub(p, "xattn_")
        ctx_kv = attn_mod.make_ctx_kv(cfg, xp, ctx)
        h = layers.rms_norm(x, p["lnx"])
        x = x + attn_mod.cross_attention(cfg, xp, h, ctx_kv)
        h = layers.rms_norm(x, p["ln2"])
        x = x + layers.ffn(cfg, _sub(p, "ffn_"), h)
        return x, (self_cache, ctx_kv)

    @classmethod
    def slot_spec(cls, cfg, batch, s_max, abstract):
        # the per-slot half is just ctx_kv; the self-cache pages
        shp = (batch, cfg.n_ctx_tokens, cfg.n_kv_heads, cfg.d_head)
        if abstract:
            return (jax.ShapeDtypeStruct(shp, cfg.dtype),) * 2
        return (jnp.zeros(shp, cfg.dtype), jnp.zeros(shp, cfg.dtype))

    @classmethod
    def state_spec(cls, cfg, batch, s_max, abstract):
        mk = KVCache.abstract if abstract else KVCache.zeros
        # the self-cache honors kv_cache_dtype like AttnBlock's (the i8
        # words _kv_from_seq produces must land in an i8 resident cache or
        # decode_attention skips the fixed-point correction)
        dt = jnp.int8 if cfg.kv_cache_dtype == "i8" else cfg.dtype
        return (mk(cfg, batch, s_max, dtype=dt),
                cls.slot_spec(cfg, batch, s_max, abstract))

    @classmethod
    def state_pspec(cls, cfg, ba, kv_shard: str = "heads", tp_size: int = 16):
        self_spec = AttnBlock.state_pspec(cfg, ba, kv_shard, tp_size)
        return (self_spec, CrossBlock.state_pspec(cfg, ba, kv_shard, tp_size))


# ---------------------------------------------------------------------------
# recurrent blocks
# ---------------------------------------------------------------------------

@register
class RglruBlock(PagedLayout):
    contract = BlockContract("rglru", per_slot_state=True)

    @classmethod
    def defs(cls, cfg, n):
        d = cfg.d_model
        return {
            "ln1": _norm_def(cfg, n), "ln2": _norm_def(cfg, n),
            "w_gate": ParamDef((n, d, d), (None, "fsdp", "tp"), cfg.dtype,
                               binarize=True),
            "w_x": ParamDef((n, d, d), (None, "fsdp", "tp"), cfg.dtype,
                            binarize=True),
            "conv_k": ParamDef((n, cfg.conv_width, d), (None, None, "tp"),
                               jnp.float32, scale=0.5),
            "w_r": ParamDef((n, d, d), (None, "fsdp", "tp"), cfg.dtype,
                            binarize=True),
            "w_i": ParamDef((n, d, d), (None, "fsdp", "tp"), cfg.dtype,
                            binarize=True),
            "lam": ParamDef((n, d), (None, "tp"), jnp.float32, init="ones"),
            "w_out": ParamDef((n, d, d), (None, "tp", "fsdp"), cfg.dtype,
                              binarize=True),
        } | {f"ffn_{k}": v for k, v in layers.ffn_defs(cfg, n).items()}

    @classmethod
    def _mix(cls, cfg, p, h):
        g = jax.nn.gelu(layers.linear(h, p["w_gate"], cfg.quant))
        u = layers.linear(h, p["w_x"], cfg.quant)
        return (constrain(g, "batch", None, "tp"),
                constrain(u, "batch", None, "tp"))

    @classmethod
    def fwd(cls, cfg, p, x, ctx, opts: FwdOpts):
        h = layers.rms_norm(x, p["ln1"])
        g, u = cls._mix(cfg, p, h)
        uc = ssm.conv1d(u, p["conv_k"])
        r = layers.linear(uc, p["w_r"], cfg.quant)
        i = layers.linear(uc, p["w_i"], cfg.quant)
        st0 = ssm.RGLRUState.zeros(x.shape[0], cfg.d_model)
        y, st = ssm.rglru(uc, r, i, p["lam"], cfg.rglru_c, st0)
        x = x + layers.linear(g * y, p["w_out"], cfg.quant)
        h = layers.rms_norm(x, p["ln2"])
        x = x + layers.ffn(cfg, _sub(p, "ffn_"), h)
        state = None
        if opts.want_state:
            w = cfg.conv_width
            buf = u[:, -(w - 1):].astype(cfg.dtype)
            state = (st, buf)
        return x, jnp.float32(0.0), state

    @classmethod
    def decode(cls, cfg, p, x, state, pos, ctx, table=None, valid=None):
        st, buf = state
        h = layers.rms_norm(x, p["ln1"])
        g, u = cls._mix(cfg, p, h)
        uc, buf = ssm.conv1d_step(buf, u, p["conv_k"])
        r = layers.linear(uc, p["w_r"], cfg.quant)
        i = layers.linear(uc, p["w_i"], cfg.quant)
        y, st = ssm.rglru_step(uc, r, i, p["lam"], cfg.rglru_c, st)
        x = x + layers.linear(g * y, p["w_out"], cfg.quant)
        h = layers.rms_norm(x, p["ln2"])
        x = x + layers.ffn(cfg, _sub(p, "ffn_"), h)
        return x, (st, buf.astype(cfg.dtype))

    @classmethod
    def chunk(cls, cfg, p, x, state, pos0, valid, n_valid, ctx, table):
        st, buf = state
        h = layers.rms_norm(x, p["ln1"])
        g, u = cls._mix(cfg, p, h)
        uc = ssm.conv1d_carry(buf, u, p["conv_k"])
        r = layers.linear(uc, p["w_r"], cfg.quant)
        i = layers.linear(uc, p["w_i"], cfg.quant)
        y, st = ssm.rglru(uc, r, i, p["lam"], cfg.rglru_c, st, valid=valid)
        x = x + layers.linear(g * y, p["w_out"], cfg.quant)
        h = layers.rms_norm(x, p["ln2"])
        x = x + layers.ffn(cfg, _sub(p, "ffn_"), h)
        buf = ssm.conv1d_carry_out(buf, u, n_valid).astype(cfg.dtype)
        return x, (st, buf)

    @classmethod
    def state_spec(cls, cfg, batch, s_max, abstract):
        w = cfg.conv_width
        if abstract:
            return (ssm.RGLRUState.abstract(batch, cfg.d_model),
                    jax.ShapeDtypeStruct((batch, w - 1, cfg.d_model), cfg.dtype))
        return (ssm.RGLRUState.zeros(batch, cfg.d_model),
                jnp.zeros((batch, w - 1, cfg.d_model), cfg.dtype))

    @classmethod
    def state_pspec(cls, cfg, ba, kv_shard: str = "heads", tp_size: int = 16):
        return (ssm.RGLRUState(P(ba, "model")), P(ba, None, "model"))


@register
class MlstmBlock(PagedLayout):
    contract = BlockContract("mlstm", per_slot_state=True)

    @classmethod
    def _di(cls, cfg):
        return int(cfg.proj_factor * cfg.d_model)

    @classmethod
    def defs(cls, cfg, n):
        d, di, nh = cfg.d_model, cls._di(cfg), cfg.n_heads
        return {
            "ln1": _norm_def(cfg, n),
            "w_up": ParamDef((n, d, di), (None, "fsdp", "tp"), cfg.dtype,
                             binarize=True),
            "w_gate": ParamDef((n, d, di), (None, "fsdp", "tp"), cfg.dtype,
                               binarize=True),
            "conv_k": ParamDef((n, cfg.conv_width, di), (None, None, "tp"),
                               jnp.float32, scale=0.5),
            "wq": ParamDef((n, di, di), (None, "fsdp", "tp"), cfg.dtype,
                           binarize=True),
            "wk": ParamDef((n, di, di), (None, "fsdp", "tp"), cfg.dtype,
                           binarize=True),
            "wv": ParamDef((n, di, di), (None, "fsdp", "tp"), cfg.dtype,
                           binarize=True),
            "w_if": ParamDef((n, di, 2 * nh), (None, "fsdp", None), jnp.float32),
            "b_if": ParamDef((n, 2 * nh), (None, None), jnp.float32, init="zeros"),
            "out_norm": ParamDef((n, di), (None, "tp"), jnp.float32, init="ones"),
            "w_down": ParamDef((n, di, d), (None, "tp", "fsdp"), cfg.dtype,
                               binarize=True),
        }

    @classmethod
    def _qkvif(cls, cfg, p, u, uc):
        nh = cfg.n_heads
        di = cls._di(cfg)
        dh = di // nh
        b, s = u.shape[:2]
        q = layers.linear(uc, p["wq"], cfg.quant).reshape(b, s, nh, dh)
        k = layers.linear(uc, p["wk"], cfg.quant).reshape(b, s, nh, dh) * (dh ** -0.5)
        v = layers.linear(u, p["wv"], cfg.quant).reshape(b, s, nh, dh)
        gif = jnp.einsum("bsd,dg->bsg", uc.astype(jnp.float32),
                         p["w_if"]) + p["b_if"]
        return q, k, v, gif[..., :nh], gif[..., nh:]

    @classmethod
    def fwd(cls, cfg, p, x, ctx, opts: FwdOpts):
        b, s, d = x.shape
        di, nh = cls._di(cfg), cfg.n_heads
        h = layers.rms_norm(x, p["ln1"])
        u = constrain(layers.linear(h, p["w_up"], cfg.quant),
                      "batch", None, "tp")
        z = constrain(layers.linear(h, p["w_gate"], cfg.quant),
                      "batch", None, "tp")
        uc = jax.nn.silu(ssm.conv1d(u, p["conv_k"]))
        q, k, v, ig, fg = cls._qkvif(cfg, p, u, uc)
        st0 = ssm.MLSTMState.zeros(b, nh, di // nh)
        # chunk loop stays scanned even in unrolled-roofline runs: the
        # intra-chunk D-matrix is O(L^2) and unrolling ncs x layers bodies
        # explodes compile; the resulting HLO-flop undercount is documented
        # analytically in EXPERIMENTS.md SSM note.
        hseq, st = ssm.mlstm_chunkwise(q, k, v, ig, fg, st0,
                                       min(cfg.mlstm_chunk, s))
        hseq = hseq.reshape(b, s, di).astype(x.dtype)
        hseq = layers.rms_norm(hseq, p["out_norm"]) * jax.nn.silu(z)
        x = x + layers.linear(hseq, p["w_down"], cfg.quant)
        state = None
        if opts.want_state:
            w = cfg.conv_width
            state = (st, u[:, -(w - 1):].astype(cfg.dtype))
        return x, jnp.float32(0.0), state

    @classmethod
    def decode(cls, cfg, p, x, state, pos, ctx, table=None, valid=None):
        st, buf = state
        b = x.shape[0]
        di, nh = cls._di(cfg), cfg.n_heads
        h = layers.rms_norm(x, p["ln1"])
        u = layers.linear(h, p["w_up"], cfg.quant)
        z = layers.linear(h, p["w_gate"], cfg.quant)
        uc_lin, buf = ssm.conv1d_step(buf, u, p["conv_k"])
        uc = jax.nn.silu(uc_lin)
        q, k, v, ig, fg = cls._qkvif(cfg, p, u, uc)
        hstep, st = ssm.mlstm_step(st, q[:, 0], k[:, 0], v[:, 0],
                                   ig[:, 0], fg[:, 0])
        hstep = hstep.reshape(b, 1, di).astype(x.dtype)
        hstep = layers.rms_norm(hstep, p["out_norm"]) * jax.nn.silu(z)
        x = x + layers.linear(hstep, p["w_down"], cfg.quant)
        return x, (st, buf.astype(cfg.dtype))

    @classmethod
    def chunk(cls, cfg, p, x, state, pos0, valid, n_valid, ctx, table):
        st, buf = state
        b, c, _ = x.shape
        di = cls._di(cfg)
        h = layers.rms_norm(x, p["ln1"])
        u = layers.linear(h, p["w_up"], cfg.quant)
        z = layers.linear(h, p["w_gate"], cfg.quant)
        uc = jax.nn.silu(ssm.conv1d_carry(buf, u, p["conv_k"]))
        q, k, v, ig, fg = cls._qkvif(cfg, p, u, uc)
        # state-neutral gates at padding positions (i = -inf: nothing
        # inserted; f = +inf: no decay) — the same trick mlstm_chunkwise
        # uses for its own ragged tails, so the boundary state is exact.
        ig = jnp.where(valid[..., None], ig, -1e30)
        fg = jnp.where(valid[..., None], fg, 1e30)
        hseq, st = ssm.mlstm_chunkwise(q, k, v, ig, fg, st,
                                       min(cfg.mlstm_chunk, c))
        hseq = hseq.reshape(b, c, di).astype(x.dtype)
        hseq = layers.rms_norm(hseq, p["out_norm"]) * jax.nn.silu(z)
        x = x + layers.linear(hseq, p["w_down"], cfg.quant)
        buf = ssm.conv1d_carry_out(buf, u, n_valid).astype(cfg.dtype)
        return x, (st, buf)

    @classmethod
    def state_spec(cls, cfg, batch, s_max, abstract):
        di, nh = cls._di(cfg), cfg.n_heads
        w = cfg.conv_width
        if abstract:
            return (ssm.MLSTMState.abstract(batch, nh, di // nh),
                    jax.ShapeDtypeStruct((batch, w - 1, di), cfg.dtype))
        return (ssm.MLSTMState.zeros(batch, nh, di // nh),
                jnp.zeros((batch, w - 1, di), cfg.dtype))

    @classmethod
    def state_pspec(cls, cfg, ba, kv_shard: str = "heads", tp_size: int = 16):
        # mLSTM matrix memory: NH (4) won't divide 16-way TP; shard the
        # first dh axis instead (dh = proj_factor*d/NH = 512, divisible).
        return (ssm.MLSTMState(P(ba, None, "model", None),
                               P(ba, None, "model"), P(ba, None)),
                P(ba, None, "model"))


@register
class SlstmBlock(PagedLayout):
    contract = BlockContract("slstm", per_slot_state=True)

    @classmethod
    def defs(cls, cfg, n):
        d, nh = cfg.d_model, cfg.n_heads
        dh = d // nh
        dff = int(4 * d / 3 / 64) * 64 * 2  # GLU up width (xLSTM 4/3 factor)
        return {
            "ln1": _norm_def(cfg, n),
            "w_gates": ParamDef((n, d, 4 * d), (None, "fsdp", "tp"), cfg.dtype,
                                binarize=True),
            # r_kernel is tiny and nh (4) won't divide 16-way TP: replicate
            "r_kernel": ParamDef((n, 4, nh, dh, dh),
                                 (None, None, None, None, None),
                                 jnp.float32, scale=0.05),
            "ln2": _norm_def(cfg, n),
            "w_up": ParamDef((n, d, dff), (None, "fsdp", "tp"), cfg.dtype,
                             binarize=True),
            "w_down": ParamDef((n, dff // 2, d), (None, "tp", "fsdp"), cfg.dtype,
                               binarize=True),
        }

    @classmethod
    def _post_ffn(cls, cfg, p, x):
        h = layers.rms_norm(x, p["ln2"])
        up = layers.linear(h, p["w_up"], cfg.quant)
        a, g = jnp.split(up, 2, axis=-1)
        return x + layers.linear(a * jax.nn.gelu(g), p["w_down"], cfg.quant)

    @classmethod
    def fwd(cls, cfg, p, x, ctx, opts: FwdOpts):
        b = x.shape[0]
        h = layers.rms_norm(x, p["ln1"])
        gates = constrain(layers.linear(h, p["w_gates"], cfg.quant),
                          "batch", None, "tp")
        st0 = ssm.SLSTMState.zeros(b, cfg.d_model)
        y, st = ssm.slstm_sequence(gates, p["r_kernel"], st0, cfg.n_heads)
        x = x + y.astype(x.dtype)
        x = cls._post_ffn(cfg, p, x)
        return x, jnp.float32(0.0), (st if opts.want_state else None)

    @classmethod
    def decode(cls, cfg, p, x, state, pos, ctx, table=None, valid=None):
        h = layers.rms_norm(x, p["ln1"])
        gates = layers.linear(h, p["w_gates"], cfg.quant)
        state, y = ssm.slstm_step(state, gates[:, 0], p["r_kernel"], cfg.n_heads)
        x = x + y[:, None].astype(x.dtype)
        x = cls._post_ffn(cfg, p, x)
        return x, state

    @classmethod
    def chunk(cls, cfg, p, x, state, pos0, valid, n_valid, ctx, table):
        h = layers.rms_norm(x, p["ln1"])
        gates = layers.linear(h, p["w_gates"], cfg.quant)
        y, state = ssm.slstm_sequence(gates, p["r_kernel"], state,
                                      cfg.n_heads, valid=valid)
        x = x + y.astype(x.dtype)
        x = cls._post_ffn(cfg, p, x)
        return x, state

    @classmethod
    def state_spec(cls, cfg, batch, s_max, abstract):
        mk = ssm.SLSTMState.abstract if abstract else ssm.SLSTMState.zeros
        return mk(batch, cfg.d_model)

    @classmethod
    def state_pspec(cls, cfg, ba, kv_shard: str = "heads", tp_size: int = 16):
        return ssm.SLSTMState(*(P(ba, "model"),) * 4)


# Live view of the registry (satellite kinds registered after import — e.g.
# bcnn's "bindense" — appear here too).  Kept for back-compat; new code
# should go through ``registry.get`` / ``registry.contract``.
KINDS: dict[str, Any] = registry.view()


# ---------------------------------------------------------------------------
# scanned segments
# ---------------------------------------------------------------------------

def segment_defs(cfg, segments=None) -> list:
    return [(kind, n, registry.get(kind).defs(cfg, n))
            for kind, n in (segments or cfg.segments())]


def segment_fwd(cfg, seg_params: list, x, ctx=None,
                opts: FwdOpts = FwdOpts(), remat: bool = False,
                unroll: bool = False):
    """Apply all segments. Returns (x, aux_total, states per segment).

    ``unroll=True`` replaces lax.scan with a Python loop: identical math and
    memory behavior (per-layer remat preserved), but every layer appears in
    the HLO — required for exact cost/collective analysis, since XLA's
    cost_analysis counts a while-loop body once regardless of trip count.
    """
    aux_total = jnp.float32(0.0)
    states = []
    for (kind, n), p in seg_params:
        block = registry.get(kind)

        def body(carry, pl, _block=block):
            xc, aux = carry
            xn, a, st = _block.fwd(cfg, pl, xc, ctx, opts)
            return (xn, aux + a), st

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        if unroll:
            sts = []
            carry = (x, aux_total)
            for i in range(n):
                pl = jax.tree.map(lambda a: a[i], p)
                carry, st_i = body(carry, pl)
                sts.append(st_i)
            (x, aux_total) = carry
            st = (jax.tree.map(lambda *ls: jnp.stack(ls), *sts)
                  if sts[0] is not None else None)
        else:
            (x, aux_total), st = jax.lax.scan(body, (x, aux_total), p)
        states.append(st)
    return x, aux_total, states


def _block_table(block, tables):
    """The block's (B, W) table under paged serving, else None — resolved
    through the kind's declared table class, not its name."""
    if tables is None or not block.contract.paged_kv:
        return None
    return tables[block.contract.table_class]


def _freeze_inactive(block, old, new, active):
    """Keep inactive slots' per-slot state frozen across a decode step.

    Needed once chunked prefill interleaves with decode: a mid-prefill
    slot is in the batch with ``active=False`` and its recurrent carry
    must not advance on the garbage token it is fed.  Shared pool leaves
    pass through (their writes are trash-routed via ``valid``); dense-mode
    KVCache leaves are classified shared too, which is correct — dead rows
    there are inert by overwrite, the historical §13 behavior."""
    shared, ps_new = block.paged_split(new)
    if ps_new is None:
        return new
    _, ps_old = block.paged_split(old)
    sel = lambda nw, ol: jnp.where(
        active.reshape((1, -1) + (1,) * (nw.ndim - 2)), nw, ol)
    return block.paged_merge(shared, jax.tree.map(sel, ps_new, ps_old))


def segment_decode(cfg, seg_params: list, x, states: list, pos, ctx=None,
                   unroll: bool = False, tables: dict | None = None,
                   active=None):
    """``tables`` switches attn-family blocks to the paged gather/scatter
    path: {"full": (B, W), "win": (B, W)} per-slot block tables (DESIGN.md
    §14); their states are then shared PagedKVCache pools.  ``active``
    (B,) bool additionally freezes inactive slots' per-slot state and
    trash-routes their KV writes (mid-prefill slots share the decode
    batch)."""
    valid = None if active is None else active[:, None]
    new_states = []
    for ((kind, n), p), st in zip(seg_params, states):
        block = registry.get(kind)
        table = _block_table(block, tables)

        def body(xc, pst, _block=block, _table=table):
            pl, stl = pst
            xn, stn = _block.decode(cfg, pl, xc, stl, pos, ctx, table=_table,
                                    valid=valid)
            return xn, stn

        if unroll:
            outs = []
            for i in range(n):
                pst = jax.tree.map(lambda a: a[i], (p, st))
                x, stn_i = body(x, pst)
                outs.append(stn_i)
            stn = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
        else:
            x, stn = jax.lax.scan(body, x, (p, st))
        if active is not None:
            stn = _freeze_inactive(block, st, stn, active)
        new_states.append(stn)
    return x, new_states


def segment_chunk(cfg, seg_params: list, x, states: list, slot, pos0,
                  valid, n_valid, ctx=None, tables: dict | None = None,
                  fresh=None):
    """One chunked-prefill piece through every segment (B=1, S=C).

    Per-slot dense leaves (recurrent state, ctx_kv) are sliced out for
    ``slot``, optionally reset to their initial values when ``fresh`` (the
    request's first chunk overwrites whatever the previous tenant left),
    run through the chunk, and scattered back; shared PagedKVCache pools
    pass through whole (the block table confines writes to this slot's
    blocks).  ``tables`` rows here are (1, W) — just this slot's row.
    """
    new_states = []
    for ((kind, n), p), st in zip(seg_params, states):
        block = registry.get(kind)
        table = _block_table(block, tables)
        shared, per_slot = block.paged_split(st)
        ps_slot = None
        if per_slot is not None:
            ps_slot = jax.tree.map(
                lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1),
                per_slot)
            if fresh is not None:
                one = block.paged_state_spec(cfg, 1, 0, 0, 0, False)
                _, init = block.paged_split(one)
                init = jax.tree.map(
                    lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), init)
                ps_slot = jax.tree.map(
                    lambda i, l: jnp.where(fresh, i.astype(l.dtype), l),
                    init, ps_slot)

        def body(xc, pst, _block=block, _table=table):
            pl, sh_l, ps_l = pst
            st_l = _block.paged_merge(sh_l, ps_l)
            xn, st_n = _block.chunk(cfg, pl, xc, st_l, pos0, valid, n_valid,
                                    ctx, _table)
            return xn, _block.paged_split(st_n)

        x, (sh_new, ps_new) = jax.lax.scan(body, x, (p, shared, ps_slot))
        if per_slot is not None:
            ps_new = jax.tree.map(
                lambda full_l, new_l: jax.lax.dynamic_update_slice_in_dim(
                    full_l, new_l.astype(full_l.dtype), slot, axis=1),
                per_slot, ps_new)
        new_states.append(block.paged_merge(sh_new, ps_new))
    return x, new_states


def segment_copy_block(cfg, states: list, src, dst):
    """Copy physical block ``src`` -> ``dst`` in every shared pool leaf
    (the device half of copy-on-write prefix sharing, DESIGN.md §15).

    Per-slot leaves (recurrent carries, ctx_kv) pass through untouched —
    prefix sharing is only enabled for archs whose sequential state lives
    entirely in the paged pools, so there is nothing per-slot to duplicate.
    Block ids are unique across table classes and requests, which makes the
    copy safe to apply to *every* pool: at most one class maps ``src``.
    """
    out = []
    for (kind, _), st in zip(cfg.segments(), states):
        block = registry.get(kind)
        shared, per_slot = block.paged_split(st)
        if shared is not None:
            shared = shared.copy_block(src, dst)
        out.append(block.paged_merge(shared, per_slot))
    return out


def segment_export_slot(cfg, states: list, slot, ids: dict):
    """Extract one slot's state from every segment (DESIGN.md §17).

    ``ids`` maps table class -> this slot's full (W,) block-table row;
    each kind resolves its row through its contract's ``table_class`` —
    the same dispatch :func:`_block_table` uses on the forward path, so
    a kind can never be exported through the wrong table.  Returns a
    tuple of per-segment ``(shared, per_slot)`` payloads.
    """
    out = []
    for (kind, _), st in zip(cfg.segments(), states):
        block = registry.get(kind)
        c = block.contract
        row = ids[c.table_class] if c.paged_kv else None
        out.append(block.export_slot(st, slot, row))
    return tuple(out)


def segment_import_slot(cfg, states: list, slot, ids: dict, payloads):
    """Inverse of :func:`segment_export_slot`: scatter per-segment payloads
    into ``slot`` and the destination table rows ``ids``."""
    out = []
    for (kind, _), st, pl in zip(cfg.segments(), states, payloads):
        block = registry.get(kind)
        c = block.contract
        row = ids[c.table_class] if c.paged_kv else None
        out.append(block.import_slot(st, slot, row, pl))
    return out


def segment_gather_block(cfg, states: list, bid):
    """Read physical block ``bid`` out of every shared pool leaf (the
    integrity scrubber's view of an idle cached block, DESIGN.md §17).
    Returns a per-segment tuple of shared-pool slices (None for segments
    with no paged pool); per-slot state is never block-granular and is
    not part of a block's identity."""
    out = []
    for (kind, _), st in zip(cfg.segments(), states):
        block = registry.get(kind)
        shared, _ = block.paged_split(st)
        out.append(None if shared is None
                   else jax.tree.map(lambda l: l[:, bid], shared))
    return tuple(out)


def segment_states(cfg, segments, batch, s_max, abstract: bool):
    """Stacked decode states per segment (leading axis = layers in segment)."""
    out = []
    for kind, n in segments:
        block = registry.get(kind)
        one = block.state_spec(cfg, batch, s_max, abstract)
        if abstract:
            stacked = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct((n,) + l.shape, l.dtype), one)
        else:
            stacked = jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), one)
        out.append(stacked)
    return out


def segment_paged_states(cfg, segments, batch, s_max, n_blocks: int,
                         block_size: int, abstract: bool):
    """Paged decode states per segment: attn-family KV caches become shared
    ``(n, n_blocks, KV, block_size, dh)`` pools (stacked per layer, no batch
    axis); recurrent / ctx_kv leaves keep the dense ``(n, batch, ...)``
    layout (DESIGN.md §14)."""
    out = []
    for kind, n in segments:
        block = registry.get(kind)
        one = block.paged_state_spec(cfg, batch, s_max, n_blocks, block_size,
                                     abstract)
        if abstract:
            stacked = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct((n,) + l.shape, l.dtype), one)
        else:
            stacked = jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), one)
        out.append(stacked)
    return out


def segment_state_pspecs(cfg, segments, ba, kv_shard: str = "heads",
                         tp_size: int = 16):
    """PartitionSpecs matching segment_states (stack axis unsharded)."""
    out = []
    for kind, n in segments:
        one = registry.get(kind).state_pspec(cfg, ba, kv_shard, tp_size)
        out.append(jax.tree.map(lambda s: P(None, *s), one,
                                is_leaf=lambda x: isinstance(x, P)))
    return out
