"""Shared neural layers: norms, RoPE, projections (with optional XNOR
quantization — the paper's technique as a first-class config axis), SwiGLU
FFN, embeddings.

All functions are pure; parameters are declared via :mod:`repro.models.params`
ParamDefs with logical sharding axes ("fsdp" -> data, "tp"/"ep" -> model).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import xnor_layers
from repro.distributed.ctx import constrain
from repro.models.params import ParamDef


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def linear(x: jnp.ndarray, w, quant: str = "none",
           bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """x (..., K) @ w (K, N). ``quant="xnor"`` routes through the binary
    XNOR-Net path (STE in float domain at train time).

    ``w`` may also be a :class:`repro.core.xnor_layers.PackedLinear` — the
    packed-residency serve form produced by ``lm.pack_params`` — in which
    case the float weight no longer exists and the XNOR-popcount GEMM runs
    over the resident bit-planes (bit-exact with the float sign path).
    """
    if isinstance(w, xnor_layers.PackedLinear):
        y = xnor_layers.xnor_linear_prepacked(x, w.pb, w.beta, valid_k=w.k)
    elif quant == "xnor":
        y = xnor_layers.xnor_linear(x, w.T)
    else:
        y = jnp.einsum("...k,kn->...n", x, w)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


# --- RoPE -------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, dh); positions: (B, S) or (S,). NeoX-style halves."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- SwiGLU FFN ---------------------------------------------------------------

def ffn_defs(cfg, n: int, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w1": ParamDef((n, d, ff), (None, "fsdp", "tp"), cfg.dtype,
                       binarize=True),
        "w3": ParamDef((n, d, ff), (None, "fsdp", "tp"), cfg.dtype,
                       binarize=True),
        "w2": ParamDef((n, ff, d), (None, "tp", "fsdp"), cfg.dtype,
                       binarize=True),
    }


def ffn(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(linear(x, p["w1"], cfg.quant)) * linear(x, p["w3"], cfg.quant)
    h = constrain(h, "batch", None, "tp")
    return constrain(linear(h, p["w2"], cfg.quant), "batch", None, None)


# --- embedding / unembedding --------------------------------------------------

def embed_defs(cfg) -> dict:
    v = cfg.padded_vocab
    return {
        "tokens": ParamDef((v, cfg.d_model), ("tp", "fsdp"),
                           cfg.dtype, init="embed"),
        "final_norm": ParamDef((cfg.d_model,), (None,), jnp.float32, init="ones"),
        # lm_head d-axis unsharded: fsdp on the contraction dim makes GSPMD
        # all-gather the (tokens, vocab) f32 logits over the data axis
        # (37 GiB/step measured) instead of this 68 MB/chip weight.
        "lm_head": ParamDef((cfg.d_model, v), (None, "tp"), cfg.dtype),
    }


def embed(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return constrain(jnp.take(p["tokens"], tokens, axis=0),
                     "batch", None, None)


def logits(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, p["final_norm"])
    # lm_head stays full precision even under quant="xnor" (XNOR-Net keeps
    # first/last layers full precision; DESIGN.md §5).
    return constrain(jnp.einsum("...d,dv->...v", x, p["lm_head"]),
                     "batch", None, "tp")
