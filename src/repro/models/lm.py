"""Language-model assembly: embeddings + scanned segments + head, for all
ten assigned architectures (decoder-only, VLM cross-attn, and enc-dec).

Public surface (all pure functions of (cfg, params, ...)):
  param_defs / abstract_params / param_pspecs / init_params
  forward(cfg, params, tokens, ctx)          -> (logits, aux)
  loss_fn(cfg, params, batch)                -> (loss, metrics)
  prefill(cfg, params, tokens, ctx, s_max)   -> (last_logits, DecodeState)
  decode_step(cfg, params, token, state)     -> (logits, DecodeState)

``ctx`` is the stubbed modality context: precomputed patch embeddings (vlm)
or encoder frames (audio); None for text-only archs.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import blocks, layers
from repro.models import params as pdefs
from repro.models import registry
from repro.models.blocks import FwdOpts


# ---------------------------------------------------------------------------
# parameter declaration
# ---------------------------------------------------------------------------

def param_defs(cfg) -> dict:
    defs: dict[str, Any] = {"embed": layers.embed_defs(cfg)}
    for i, (kind, n, d) in enumerate(blocks.segment_defs(cfg)):
        defs[f"seg{i}_{kind}"] = d
    if cfg.is_encdec():
        enc_segs = cfg.encoder_segments()
        for i, (kind, n, d) in enumerate(blocks.segment_defs(cfg, enc_segs)):
            defs[f"enc{i}_{kind}"] = d
        defs["enc_norm"] = pdefs.ParamDef((cfg.d_model,), (None,),
                                          jnp.float32, init="ones")
    return defs


def abstract_params(cfg):
    return pdefs.abstract(param_defs(cfg))


def param_pspecs(cfg, rules: dict):
    return pdefs.pspecs(param_defs(cfg), rules)


def init_params(cfg, key: jax.Array):
    return pdefs.init(param_defs(cfg), key)


def pack_params(cfg, params, impl: str = "auto"):
    """Serve-resident form of ``params``: every binarizable linear is packed
    once to ``PackedLinear`` sign-planes + beta (the float weight leaves the
    tree — packed residency, DESIGN.md §13).  Identity for quant="none"
    archs: there is nothing binary to pack."""
    if cfg.quant != "xnor":
        return params
    return pdefs.pack(param_defs(cfg), params, impl=impl)


def packed_abstract_params(cfg):
    """Abstract tree matching :func:`pack_params` output."""
    if cfg.quant != "xnor":
        return abstract_params(cfg)
    return pdefs.pack_abstract(param_defs(cfg))


def param_count(cfg) -> int:
    return pdefs.count(param_defs(cfg))


def active_param_count(cfg) -> int:
    """Params touched per token: excludes the embedding table gather and
    non-routed experts (MODEL_FLOPS accounting, DESIGN.md §9)."""
    total = pdefs.count(param_defs(cfg))
    inactive = cfg.vocab * cfg.d_model          # embedding table
    if cfg.n_experts:
        per_expert = 3 * cfg.d_model * cfg.d_ff_expert
        n_routed = sum(1 for k in cfg.layer_kinds()
                       if registry.contract(k).routed_experts)
        inactive += n_routed * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


def model_flops(cfg, kind: str, tokens: int) -> float:
    """6·N_active·D for training, 2·N_active·D for inference forward."""
    n = active_param_count(cfg)
    return (6.0 if kind == "train" else 2.0) * n * tokens


def _seg_params(cfg, params, enc: bool = False):
    """[( (kind, n), stacked-params ), ...] in depth order."""
    segs = cfg.encoder_segments() if enc else cfg.segments()
    prefix = "enc" if enc else "seg"
    out = []
    for i, (kind, n) in enumerate(segs):
        out.append(((kind, n), params[f"{prefix}{i}_{kind}"]))
    return out


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def encode(cfg, params, frames: jnp.ndarray, q_chunk: int = 0) -> jnp.ndarray:
    """Whisper-style encoder over stubbed frame embeddings (B, T, d)."""
    x, _, _ = blocks.segment_fwd(cfg, _seg_params(cfg, params, enc=True),
                                 frames.astype(cfg.dtype), None,
                                 FwdOpts(q_chunk=q_chunk))
    return layers.rms_norm(x, params["enc_norm"])


def forward(cfg, params, tokens: jnp.ndarray, ctx: jnp.ndarray | None = None,
            q_chunk: int = 0, remat: bool = False, unroll: bool = False):
    """tokens (B, S) -> (logits (B, S, V), aux)."""
    x = layers.embed(params["embed"], tokens).astype(cfg.dtype)
    if cfg.is_encdec():
        assert ctx is not None, "enc-dec arch needs encoder frames"
        ctx = encode(cfg, params, ctx, q_chunk)
    elif ctx is not None:
        ctx = ctx.astype(cfg.dtype)
    x, aux, _ = blocks.segment_fwd(cfg, _seg_params(cfg, params), x, ctx,
                                   FwdOpts(q_chunk=q_chunk, unroll=unroll),
                                   remat=remat, unroll=unroll)
    return layers.logits(cfg, params["embed"], x), aux


def loss_fn(cfg, params, batch: dict, q_chunk: int = 0, remat: bool = True,
            unroll: bool = False):
    """Next-token CE (labels pre-shifted by the data pipeline; -1 = pad).

    The picked-logit term is a one-hot contraction, NOT take_along_axis:
    gathering along the vocab axis defeats the vocab (TP) sharding — GSPMD
    replicates the full (tokens, vocab) f32 logits on every chip (hundreds
    of GiB at production shapes).  The iota==label formulation partitions
    cleanly (local compare/multiply + a reduction over the sharded axis).
    """
    logits, aux = forward(cfg, params, batch["tokens"], batch.get("ctx"),
                          q_chunk=q_chunk, remat=remat, unroll=unroll)
    labels = batch["labels"]
    valid = labels >= 0
    labels = jnp.maximum(labels, 0)
    logits32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits32, axis=-1)
    onehot = (labels[..., None]
              == jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1))
    picked = jnp.sum(logits32 * onehot, axis=-1)
    ll = picked - lse
    denom = jnp.maximum(jnp.sum(valid), 1)
    ce = -jnp.sum(ll * valid) / denom
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux,
                  "tokens": jnp.sum(valid).astype(jnp.int32)}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    pos: jnp.ndarray          # int32 tokens consumed: scalar (homogeneous
                              # batch) or (B,) per-slot (continuous batching)
    seg_states: tuple         # per-segment stacked block states
    ctx: Any = None           # encoded modality context (or None)


def decode_state_spec(cfg, batch: int, s_max: int, abstract: bool = True,
                      per_slot_pos: bool = False):
    """The resident serving state for (arch, batch, cache length).

    ``per_slot_pos=True`` gives the continuous-batching layout: ``pos`` is a
    (batch,) vector so heterogeneous requests can share the batch, each slot
    advancing independently (repro.serve).
    """
    seg_states = blocks.segment_states(cfg, cfg.segments(), batch, s_max,
                                       abstract)
    ctx = None
    if cfg.n_ctx_tokens and not cfg.is_encdec():
        shp = (batch, cfg.n_ctx_tokens, cfg.d_model)
        ctx = (jax.ShapeDtypeStruct(shp, cfg.dtype) if abstract
               else jnp.zeros(shp, cfg.dtype))
    pshape = (batch,) if per_slot_pos else ()
    pos = (jax.ShapeDtypeStruct(pshape, jnp.int32) if abstract
           else jnp.zeros(pshape, jnp.int32))
    return DecodeState(pos, tuple(seg_states), ctx)


def paged_table_widths(cfg, s_max: int, block_size: int,
                       prefill_chunk: int) -> dict:
    """Block-table widths per cache class for the paged serve layout.

    Each decoder kind with a paged pool declares (via its BlockContract)
    which table class addresses it and whether that table is a window
    *ring*.  Monotone classes get ``ceil(s_max / bs)`` blocks; ring
    classes get capacity ``W * bs >= window + C - 1``, which guarantees
    that scatter-then-attend chunked prefill (chunk size C) never
    overwrites an in-window key.  Kinds sharing a class take the max
    width.  Archs with no KV cache at all (pure recurrent) return {}.
    """
    bs = block_size
    widths: dict[str, int] = {}
    for kind, _ in cfg.segments():
        c = registry.contract(kind)
        if not c.paged_kv:
            continue
        if c.window:
            cap = min(s_max, cfg.local_window + max(prefill_chunk, 1) - 1)
        else:
            cap = s_max
        w = -(-cap // bs)
        widths[c.table_class] = max(widths.get(c.table_class, 0), w)
    return widths


def paged_decode_layer_classes(cfg) -> dict:
    """Paged decoder layers per block-table class.

    The roofline floor for a decode step streams each paged layer's live
    K/V once (``analysis.decode_roofline_bytes``); this is the layer-count
    side of that accounting, derived from the same BlockContract registry
    as :func:`paged_table_widths` so the two can never disagree about
    which layers are paged.
    """
    counts: dict[str, int] = {}
    for kind, n in cfg.segments():
        c = registry.contract(kind)
        if c.paged_kv:
            counts[c.table_class] = counts.get(c.table_class, 0) + n
    return counts


def paged_decode_state_spec(cfg, batch: int, s_max: int, *, n_blocks: int,
                            block_size: int, abstract: bool = True):
    """The block-paged resident serving state (DESIGN.md §14).

    Attn-family KV caches are shared ``(n_blocks, KV, block_size, dh)``
    pools addressed through host-owned per-slot block tables; ``pos`` is
    per-slot; recurrent per-slot state stays dense (it is O(1) per slot).
    ``ctx`` is never kept resident — chunked prefill receives the modality
    context as a program input and stores the derived ctx_kv per slot.
    """
    seg_states = blocks.segment_paged_states(cfg, cfg.segments(), batch,
                                             s_max, n_blocks, block_size,
                                             abstract)
    pos = (jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
           else jnp.zeros((batch,), jnp.int32))
    return DecodeState(pos, tuple(seg_states), None)


def decode_state_pspecs(cfg, ba, kv_shard: str = "heads", tp_size: int = 16):
    """PartitionSpecs mirroring decode_state_spec (ba = batch mesh axes)."""
    from jax.sharding import PartitionSpec as P
    seg = blocks.segment_state_pspecs(cfg, cfg.segments(), ba, kv_shard,
                                      tp_size)
    ctx = None
    if cfg.n_ctx_tokens and not cfg.is_encdec():
        ctx = P(ba, None, None)
    return DecodeState(P(), tuple(seg), ctx)


def prefill(cfg, params, tokens: jnp.ndarray, ctx: jnp.ndarray | None,
            s_max: int, q_chunk: int = 0, unroll: bool = False):
    x = layers.embed(params["embed"], tokens).astype(cfg.dtype)
    if cfg.is_encdec():
        ctx = encode(cfg, params, ctx, q_chunk)
    elif ctx is not None:
        ctx = ctx.astype(cfg.dtype)
    opts = FwdOpts(q_chunk=q_chunk, want_state=True, s_max=s_max,
                   unroll=unroll)
    x, _, states = blocks.segment_fwd(cfg, _seg_params(cfg, params), x, ctx,
                                      opts, unroll=unroll)
    logits = layers.logits(cfg, params["embed"], x[:, -1:])
    pos = jnp.asarray(tokens.shape[1], jnp.int32)
    keep_ctx = ctx if (cfg.is_encdec() or cfg.n_ctx_tokens) else None
    return logits, DecodeState(pos, tuple(states), keep_ctx)


def decode_step(cfg, params, token: jnp.ndarray, state: DecodeState,
                unroll: bool = False, active: jnp.ndarray | None = None):
    """token (B, 1) int32 -> (logits (B, 1, V), new state).

    ``state.pos`` may be a scalar (homogeneous batch) or a (B,) vector
    (continuous batching: per-slot positions).  ``active`` (B,) bool gates
    the position advance per slot: an inactive slot's pos freezes, so its
    (dead) cache line is rewritten in place each step instead of walking
    forward — the slot state stays inert until an admission overwrites it.
    Inactive rows still flow through the network (their logits are garbage
    the scheduler ignores); under MoE their tokens also compete for expert
    capacity, so the serve layer feeds a constant token id in dead slots.
    """
    x = layers.embed(params["embed"], token).astype(cfg.dtype)
    x, new_states = blocks.segment_decode(cfg, _seg_params(cfg, params), x,
                                          list(state.seg_states), state.pos,
                                          state.ctx, unroll=unroll)
    logits = layers.logits(cfg, params["embed"], x)
    inc = 1 if active is None else active.astype(jnp.int32)
    return logits, DecodeState(state.pos + inc, tuple(new_states), state.ctx)


def paged_decode_step(cfg, params, token: jnp.ndarray, state: DecodeState,
                      tables: dict, active: jnp.ndarray | None = None):
    """One token for every slot against the block-paged resident state.

    ``tables`` {"full"/"win": (B, W) int32} are host-owned device data —
    they change as blocks are allocated and freed without ever retracing.
    ``active`` additionally gates the paged batch's inactive rows: their
    per-slot recurrent state freezes and their KV writes are trash-routed,
    so a mid-prefill slot (chunked prefill interleaves with decode) rides
    along inertly; dead slots' table rows are zeroed by the host as well.
    """
    x = layers.embed(params["embed"], token).astype(cfg.dtype)
    x, new_states = blocks.segment_decode(cfg, _seg_params(cfg, params), x,
                                          list(state.seg_states), state.pos,
                                          state.ctx, tables=tables,
                                          active=active)
    logits = layers.logits(cfg, params["embed"], x)
    inc = 1 if active is None else active.astype(jnp.int32)
    return logits, DecodeState(state.pos + inc, tuple(new_states), state.ctx)


def prefill_chunk_step(cfg, params, tokens: jnp.ndarray, state: DecodeState,
                       slot, n_valid, tables: dict,
                       ctx: jnp.ndarray | None = None, fresh=None, start=0):
    """One chunked-prefill piece for resident slot ``slot``.

    tokens (1, C) — positions ``pos0 .. pos0+C-1`` of the prompt with
    ``pos0 = state.pos[slot]`` when continuing (``fresh`` false) and
    ``start`` when the slot was just admitted; only the first ``n_valid``
    tokens are real, the rest are padding (every prompt runs through this
    one program in fixed-C pieces — one trace for the whole mixed-length
    workload).  ``start`` is 0 for a cold prompt and the skip point under
    prefix caching (DESIGN.md §15): positions 0..start-1 are already held
    in shared cache blocks mapped by this slot's table, so the resumed
    chunk scatters and attends from ``start`` as if it had computed the
    prefix itself.  ``ctx`` is the request's modality context: *encoded*
    frames for enc-dec archs (:func:`encode` runs once at admission), raw
    patch embeddings for vlm.  ``tables`` rows are this slot's (1, W)
    block-table rows.
    Returns (logits of the last valid position (1, 1, V), new state).
    """
    c = tokens.shape[1]
    pos0 = jnp.where(jnp.asarray(fresh if fresh is not None else False),
                     jnp.asarray(start, jnp.int32),
                     state.pos[slot]).astype(jnp.int32)
    valid = (jnp.arange(c) < n_valid)[None]                    # (1, C)
    x = layers.embed(params["embed"], tokens).astype(cfg.dtype)
    if ctx is not None:
        ctx = ctx.astype(cfg.dtype)
    x, new_states = blocks.segment_chunk(cfg, _seg_params(cfg, params), x,
                                         list(state.seg_states), slot, pos0,
                                         valid, n_valid, ctx, tables,
                                         fresh=fresh)
    xlast = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    logits = layers.logits(cfg, params["embed"], xlast)
    pos = state.pos.at[slot].set(pos0 + n_valid)
    return logits, DecodeState(pos, tuple(new_states), state.ctx)


def prefix_cache_eligible(cfg) -> bool:
    """Whether prefix sharing over the paged pools is sound for this arch.

    Sharing reconstructs a request's entire sequential state from cached
    blocks, so every decoder kind must declare ``prefix_shareable`` in its
    BlockContract — **fail-closed**: a kind that says nothing is
    ineligible, and one such kind anywhere in the stack disables sharing
    for the arch.  The built-in kinds that (correctly) don't declare it
    (DESIGN.md §15):

    * recurrent kinds (rglru/mlstm/slstm) carry dense per-slot state that
      is not block-granular — a skipped prefix would leave the carry cold;
    * local sliding-window layers use block *rings* whose physical blocks
      are recycled in place, so their contents are never stable enough to
      register, and a resumed chunk could not rebuild the in-window keys
      (the registry rejects a window+shareable contract outright).

    MoE declares it: its KV is ordinary paged attention state (the §14
    capacity-grouping caveat exempts it from cross-path token identity,
    not from sharing).
    """
    kinds = {k for k, _ in cfg.segments()}
    return bool(kinds) and all(registry.contract(k).prefix_shareable
                               for k in kinds)


def prefix_table_class(cfg) -> str | None:
    """The block-table class shared prefixes are registered under.

    Prefix sharing maps *stable* cached blocks between requests, so the
    share class is the table class addressed by the arch's shareable paged
    kinds.  Returns None (sharing off) when the arch has no such class or
    its shareable pools span several classes — the registration protocol
    hashes one table row per request, so a single class must cover every
    pool being rebuilt.
    """
    classes = set()
    for kind, _ in cfg.segments():
        c = registry.contract(kind)
        if c.paged_kv and c.prefix_shareable:
            classes.add(c.table_class)
    return classes.pop() if len(classes) == 1 else None


def paged_copy_block(cfg, state: DecodeState, src, dst) -> DecodeState:
    """Copy-on-write block duplication ``dst := src`` across every shared
    pool (DESIGN.md §15).  ``src``/``dst`` are device scalars — the serve
    engine jits this once per engine and calls it for any pair."""
    seg = blocks.segment_copy_block(cfg, list(state.seg_states), src, dst)
    return DecodeState(state.pos, tuple(seg), state.ctx)


# ---------------------------------------------------------------------------
# session migration: slot extraction / injection (DESIGN.md §17)
# ---------------------------------------------------------------------------


def export_slot(cfg, state: DecodeState, slot, ids: dict):
    """One slot's complete sequential state as a self-contained tree.

    ``ids`` maps table class -> the slot's full (W,) block-table row (host
    tables are device data here).  The payload is ``{"pos": (1,) int32,
    "segs": per-segment (shared, per_slot) pairs}`` — paged KV blocks in
    table-row order plus dense per-slot carries at batch width 1.  Pure
    gather: jitted once per engine, the exporting slot is untouched.
    Defined for the paged layout only (``paged_decode_state_spec``); the
    dense layout classifies whole KV caches as shared pools, which this
    row-gather addressing cannot represent.
    """
    pos = jax.lax.dynamic_slice_in_dim(state.pos, slot, 1, axis=0)
    segs = blocks.segment_export_slot(cfg, list(state.seg_states), slot, ids)
    return {"pos": pos, "segs": segs}


def import_slot(cfg, state: DecodeState, slot, ids: dict,
                payload) -> DecodeState:
    """Inverse of :func:`export_slot`: seat a payload into resident slot
    ``slot`` with ``ids`` the *destination* table rows (same widths,
    freshly allocated block ids).  Re-import is content-faithful even when
    the source blocks were shared/COW prefix blocks — blocks travel by
    value, so the destination holds a private content-identical copy and
    re-registers with its own prefix index."""
    pos = jax.lax.dynamic_update_slice_in_dim(
        state.pos, payload["pos"].astype(jnp.int32), slot, axis=0)
    seg = blocks.segment_import_slot(cfg, list(state.seg_states), slot, ids,
                                     payload["segs"])
    return DecodeState(pos, tuple(seg), state.ctx)


def export_slot_spec(cfg, state_like, slot_ids_widths: dict):
    """Shape/dtype tree of :func:`export_slot`'s payload for this engine
    geometry — the ``like`` tree a migration checkpoint restores against
    (:func:`repro.checkpoint.ckpt.restore` needs exact shapes/dtypes to
    address and decrypt leaves).  ``state_like`` is the engine's resident
    state (or its abstract spec); ``slot_ids_widths`` maps table class ->
    table width W."""
    ids = {c: jax.ShapeDtypeStruct((w,), jnp.int32)
           for c, w in slot_ids_widths.items()}
    return jax.eval_shape(
        lambda st, rows: export_slot(cfg, st, jnp.int32(0), rows),
        state_like, ids)


def gather_block(cfg, state: DecodeState, bid):
    """Physical block ``bid``'s contents across every shared pool — the
    integrity scrubber's unit of verification for idle cached blocks
    (DESIGN.md §17)."""
    return blocks.segment_gather_block(cfg, list(state.seg_states), bid)
