"""GQA attention: full / local-window / cross, with chunked-query streaming
for long prefills and KV-cache decode.

Layout conventions:
  activations  (B, S, d_model)
  q            (B, S, H, dh)
  k, v         (B, S, KV, dh) — expanded to (B, S, H, dh) in the batched
               (train/prefill) paths when q_per_kv > 1: repeating KV to full
               heads is mathematically identical to grouped attention and
               keeps the TP sharding on the head axis.  Sharding the packed
               GQA layout instead pads KV (4) up to the model axis (16),
               which GSPMD resolves by sharding d_head — producing multi-GiB
               score all-reduces (measured, EXPERIMENTS.md §Perf iter 1).
  KV cache     (B, KV, S_max, dh) — decode keeps the compact GQA form (the
               cache is the memory bottleneck; never expanded).
  paged cache  (n_blocks, KV, block_size, dh) — the block-paged serve form
               (DESIGN.md §14): a shared pool with no batch axis, addressed
               through per-slot int32 block tables.
Scores accumulate in f32; softmax is f32 with max subtraction.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.kernels import ops, paged_attn
from repro.models import layers
from repro.models.params import ParamDef

NEG_INF = -1e30


def i8_encode(cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Fixed-point encode for the int8 decode cache (scale is a config
    axis: ``cfg.kv_i8_scale``, default 32 — values are RMS-normed/RoPE'd,
    |k| < ~4, so 32 gives ~2% rounding)."""
    return jnp.clip(jnp.round(x.astype(jnp.float32) * cfg.kv_i8_scale),
                    -127, 127).astype(jnp.int8)


def attn_defs(cfg, n: int, cross: bool = False) -> dict:
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    defs = {
        "wq": ParamDef((n, d, H * dh), (None, "fsdp", "tp"), cfg.dtype,
                       binarize=True),
        "wk": ParamDef((n, d, KV * dh), (None, "fsdp", "tp"), cfg.dtype,
                       binarize=True),
        "wv": ParamDef((n, d, KV * dh), (None, "fsdp", "tp"), cfg.dtype,
                       binarize=True),
        "wo": ParamDef((n, H * dh, d), (None, "tp", "fsdp"), cfg.dtype,
                       binarize=True),
    }
    if cfg.qkv_bias and not cross:
        defs |= {
            "bq": ParamDef((n, H * dh), (None, "tp"), cfg.dtype, init="zeros"),
            "bk": ParamDef((n, KV * dh), (None, "tp"), cfg.dtype, init="zeros"),
            "bv": ParamDef((n, KV * dh), (None, "tp"), cfg.dtype, init="zeros"),
        }
    if cfg.qk_norm:
        defs |= {
            "q_norm": ParamDef((n, dh), (None, None), jnp.float32, init="ones"),
            "k_norm": ParamDef((n, dh), (None, None), jnp.float32, init="ones"),
        }
    return defs


def _project_q(cfg, p, x, positions):
    """-> (B, S, H, dh)"""
    b, s, _ = x.shape
    q = layers.linear(x, p["wq"], cfg.quant, p.get("bq"))
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
    if positions is not None:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
    return constrain(q, "batch", None, "tp", None)


def _project_kv(cfg, p, x, positions):
    """-> k, v each (B, S, KV, dh) (compact GQA form)."""
    b, s, _ = x.shape
    k = layers.linear(x, p["wk"], cfg.quant, p.get("bk"))
    v = layers.linear(x, p["wv"], cfg.quant, p.get("bv"))
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        k = layers.rms_norm(k, p["k_norm"])
    if positions is not None:
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _expand_kv(cfg, k, v):
    """(B, S, KV, dh) -> (B, S, H, dh): repeat each KV head q_per_kv times."""
    if cfg.q_per_kv == 1:
        return k, v
    k = jnp.repeat(k, cfg.q_per_kv, axis=2)
    v = jnp.repeat(v, cfg.q_per_kv, axis=2)
    return (constrain(k, "batch", None, "tp", None),
            constrain(v, "batch", None, "tp", None))


def _sdpa(cfg, q, k, v, mask):
    """MHA core: q (B,Sq,H,dh), k/v (B,Sk,H,dh), mask (1|B,Sq,Sk) or None."""
    scale = cfg.d_head ** -0.5
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _causal_mask(sq, sk, q0, window: int = 0):
    """(1, sq, sk) boolean: query i (global pos q0+i) sees key j iff
    j <= q0+i and (no window or j > q0+i-window)."""
    qpos = q0 + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None]


def attention(cfg, p: dict, x: jnp.ndarray, *, causal: bool = True,
              window: int = 0, q_chunk: int = 0,
              positions: jnp.ndarray | None = None,
              unroll: bool = False) -> jnp.ndarray:
    """Self-attention over a full sequence (training / prefill).

    ``q_chunk > 0`` streams queries in chunks (bounds the live score tensor
    to q_chunk x S — the XLA-level flash-attention analogue, used for 32k
    prefills).  ``window > 0`` restricts keys to a trailing local window;
    the chunked path then slices K/V to the reachable 2*window band instead
    of masking the full sequence.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q = _project_q(cfg, p, x, positions)
    k, v = _expand_kv(cfg, *_project_kv(cfg, p, x, positions))

    if not q_chunk or s <= q_chunk:
        mask = _causal_mask(s, s, 0, window) if causal else None
        out = _sdpa(cfg, q, k, v, mask)
    else:
        assert s % q_chunk == 0, (s, q_chunk)
        n_chunks = s // q_chunk

        if window and window % q_chunk == 0:
            # local: each q chunk reaches keys in [(i+1)*C - W - C, (i+1)*C)
            span = window + q_chunk

            def chunk_fn(carry, i):
                q0 = i * q_chunk
                qc = jax.lax.dynamic_slice_in_dim(q, q0, q_chunk, axis=1)
                k0 = q0 + q_chunk - span
                kc = _slice_pad(k, k0, span)
                vc = _slice_pad(v, k0, span)
                mask = _band_mask(q_chunk, span, q0, k0, window)
                return carry, _sdpa(cfg, qc, kc, vc, mask)
        else:
            def chunk_fn(carry, i):
                q0 = i * q_chunk
                qc = jax.lax.dynamic_slice_in_dim(q, q0, q_chunk, axis=1)
                mask = _causal_mask(q_chunk, s, q0, window) if causal else None
                return carry, _sdpa(cfg, qc, k, v, mask)

        if unroll:
            outs = jnp.stack([chunk_fn((), jnp.int32(i))[1]
                              for i in range(n_chunks)])
        else:
            _, outs = jax.lax.scan(chunk_fn, (), jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, cfg.n_heads, cfg.d_head)

    out = out.reshape(b, s, cfg.n_heads * cfg.d_head)
    out = constrain(out, "batch", None, "tp")
    return constrain(layers.linear(out, p["wo"], cfg.quant),
                     "batch", None, None)


def _slice_pad(x, start, size):
    """dynamic_slice along axis 1 allowing negative start (clamps; the mask
    kills out-of-range positions)."""
    start = jnp.maximum(start, 0)
    return jax.lax.dynamic_slice_in_dim(x, start, size, axis=1)


def _band_mask(sq, span, q0, k0, window):
    k0 = jnp.maximum(k0, 0)
    qpos = q0 + jnp.arange(sq)[:, None]
    kpos = k0 + jnp.arange(span)[None, :]
    m = (kpos <= qpos) & (kpos > qpos - window)
    return m[None]


# --- cross-attention ----------------------------------------------------------

def cross_attention(cfg, p: dict, x: jnp.ndarray, ctx_kv) -> jnp.ndarray:
    """ctx_kv: (k, v) each (B, T_ctx, KV, dh) — precomputed from the context
    (vision patches / encoder output) once per sequence."""
    b, s, _ = x.shape
    q = _project_q(cfg, p, x, None)        # no RoPE across modalities
    k, v = _expand_kv(cfg, *ctx_kv)
    out = _sdpa(cfg, q, k, v, None)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head)
    return constrain(layers.linear(out, p["wo"], cfg.quant),
                     "batch", None, None)


def make_ctx_kv(cfg, p: dict, ctx: jnp.ndarray):
    return _project_kv(cfg, p, ctx, None)


# --- KV-cache decode ----------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray   # (B, KV, S_max, dh)
    v: jnp.ndarray   # (B, KV, S_max, dh)

    @classmethod
    def zeros(cls, cfg, batch: int, s_max: int, dtype=None):
        shp = (batch, cfg.n_kv_heads, s_max, cfg.d_head)
        dt = dtype or cfg.dtype
        return cls(jnp.zeros(shp, dt), jnp.zeros(shp, dt))

    @classmethod
    def abstract(cls, cfg, batch: int, s_max: int, dtype=None):
        shp = (batch, cfg.n_kv_heads, s_max, cfg.d_head)
        dt = dtype or cfg.dtype
        return cls(jax.ShapeDtypeStruct(shp, dt),
                   jax.ShapeDtypeStruct(shp, dt))


def decode_attention(cfg, p: dict, x: jnp.ndarray, cache: KVCache,
                     pos: jnp.ndarray, window: int = 0):
    """One-token attention against a resident cache (compact GQA form).

    x: (B, 1, d). pos: int32 — current position (cache holds pos valid
    entries before this call).  Either a scalar (homogeneous batch: one
    slice-update covers all rows) or a (B,) vector (continuous-batching
    serve: each slot advances independently, writes scatter per row).
    Returns (out (B, 1, d), new cache).  For local layers the cache is a
    rolling buffer of size window and the write position wraps
    (pos % window).
    """
    b = x.shape[0]
    s_max = cache.k.shape[2]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    pos_b = jnp.broadcast_to(pos, (b,))
    positions = pos_b[:, None]
    q = _project_q(cfg, p, x, positions)          # (B, 1, H, dh)
    q = q.reshape(b, 1, cfg.n_kv_heads, cfg.q_per_kv, cfg.d_head)
    k, v = _project_kv(cfg, p, x, positions)

    slot_b = pos_b % s_max if window else pos_b
    knew = jnp.moveaxis(k, 1, 2)   # (B, KV, 1, dh)
    vnew = jnp.moveaxis(v, 1, 2)
    i8 = cache.k.dtype == jnp.int8
    if i8:  # fixed-point low-bit cache (paper-domain: quantized residency)
        knew, vnew = i8_encode(cfg, knew), i8_encode(cfg, vnew)
    if per_slot:
        upd = jax.vmap(lambda c, new, s:
                       jax.lax.dynamic_update_slice_in_dim(c, new, s, axis=1))
        ck = upd(cache.k, knew.astype(cache.k.dtype), slot_b)
        cv = upd(cache.v, vnew.astype(cache.v.dtype), slot_b)
    else:
        slot = pos % s_max if window else pos
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache.k, knew.astype(cache.k.dtype), slot, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, vnew.astype(cache.v.dtype), slot, axis=2)

    scale = cfg.d_head ** -0.5
    if i8:
        scale = scale / cfg.kv_i8_scale
    scores = jnp.einsum("bqkgd,bksd->bkgqs", q, ck.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(s_max)
    if window:
        # rolling buffer: slot s holds absolute position
        # (pos - ((slot - s) mod s_max)); valid iff within window and <= pos
        age = (slot_b[:, None] - kpos[None, :]) % s_max          # (B, s_max)
        valid = age < jnp.minimum(window, pos_b[:, None] + 1)
    else:
        valid = kpos[None, :] <= pos_b[:, None]                  # (B, s_max)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bqkgd", probs.astype(q.dtype),
                     cv.astype(q.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if i8:
        out = out / cfg.kv_i8_scale
    out = out.reshape(b, 1, cfg.n_heads * cfg.d_head)
    return layers.linear(out, p["wo"], cfg.quant), KVCache(ck, cv)


def decode_cross_attention(cfg, p: dict, x: jnp.ndarray, ctx_kv):
    return cross_attention(cfg, p, x, ctx_kv)


# --- block-paged KV cache (DESIGN.md §14) -------------------------------------

class PagedKVCache(NamedTuple):
    """Shared block pool: ``n_blocks`` blocks of ``block_size`` token slots
    each, in the compact GQA form.  Unlike :class:`KVCache` there is no
    batch axis — slots address blocks through per-slot int32 block tables
    (host-owned device data), so resident memory is proportional to tokens
    actually cached, not ``slots x s_max``.  Physical block 0 is reserved
    as the trash block: writes from inactive slots / padding tokens are
    routed there and never read back."""

    k: jnp.ndarray   # (n_blocks, KV, block_size, dh)
    v: jnp.ndarray   # (n_blocks, KV, block_size, dh)

    @property
    def block_size(self) -> int:
        return self.k.shape[2]

    @classmethod
    def zeros(cls, cfg, n_blocks: int, block_size: int, dtype=None):
        shp = (n_blocks, cfg.n_kv_heads, block_size, cfg.d_head)
        dt = dtype or cfg.dtype
        return cls(jnp.zeros(shp, dt), jnp.zeros(shp, dt))

    @classmethod
    def abstract(cls, cfg, n_blocks: int, block_size: int, dtype=None):
        shp = (n_blocks, cfg.n_kv_heads, block_size, cfg.d_head)
        dt = dtype or cfg.dtype
        return cls(jax.ShapeDtypeStruct(shp, dt),
                   jax.ShapeDtypeStruct(shp, dt))

    def copy_block(self, src, dst) -> "PagedKVCache":
        """Physical block copy ``dst := src`` in both pools — the device
        side of copy-on-write prefix sharing (DESIGN.md §15): a request
        whose next scatter would land in a block it shares read-only first
        duplicates that block into a private one and repoints its table row.
        Accepts the bare ``(n_blocks, ...)`` pool or the layer-stacked
        ``(n_layers, n_blocks, ...)`` resident form (block axis = ndim-4);
        ``src``/``dst`` are device scalars, so one jitted copy program
        serves every (donor, recipient) pair without retracing."""
        axis = self.k.ndim - 4

        def cp(a):
            row = jax.lax.dynamic_index_in_dim(a, src, axis, keepdims=True)
            return jax.lax.dynamic_update_index_in_dim(a, row, dst, axis)
        return PagedKVCache(cp(self.k), cp(self.v))


def paged_attention(cfg, p: dict, x: jnp.ndarray, cache: PagedKVCache,
                    table: jnp.ndarray, pos: jnp.ndarray, *, window: int = 0,
                    valid: jnp.ndarray | None = None):
    """Attention through a per-slot block table: scatter the new tokens into
    the pool, gather K/V back through the table, and mask by position.

    One function covers both serve regimes:
      decode          — x (B, 1, d), per-slot ``pos`` (B,), B = slots;
      chunked prefill — x (1, C, d), scalar-ish ``pos`` (1,) = chunk start.

    ``table`` (B, W) holds physical block ids; token at absolute position q
    lives at block ``table[b, (q // bs) % W]``, offset ``q % bs``.  For full
    (non-window) tables ``W * bs >= s_max`` so the ring modulus is the
    identity; for local layers the table is a block ring of capacity
    ``W * bs >= window + C - 1`` (older blocks are recycled — blocks that
    fall out of the window never stay resident).  ``valid`` (B, C) routes
    padding / dead-slot writes to the reserved trash block 0.
    Returns (out (B, C, d), new cache).
    """
    b, c, _ = x.shape
    bs = cache.k.shape[2]
    w = table.shape[1]
    cap = w * bs
    pos = jnp.asarray(pos, jnp.int32)
    qpos = jnp.broadcast_to(pos, (b,))[:, None] + jnp.arange(c)[None, :]
    q = _project_q(cfg, p, x, qpos)                  # (B, C, H, dh)
    q = q.reshape(b, c, cfg.n_kv_heads, cfg.q_per_kv, cfg.d_head)
    k, v = _project_kv(cfg, p, x, qpos)              # (B, C, KV, dh)

    i8 = cache.k.dtype == jnp.int8
    if i8:
        k, v = i8_encode(cfg, k), i8_encode(cfg, v)
    phys = jnp.take_along_axis(table, (qpos // bs) % w, axis=1)   # (B, C)
    if valid is not None:
        phys = jnp.where(valid, phys, 0)             # trash block
    off = qpos % bs
    # advanced indices (phys, off) broadcast to (B, C); the KV slice stays:
    # scatter target shape (B, C, KV, dh).  Distinct live tokens always hit
    # distinct (block, offset) pairs (BlockPool uniqueness + ring sizing);
    # only trash-block writes may collide, and those are never read.
    ck = cache.k.at[phys, :, off].set(k.astype(cache.k.dtype))
    cv = cache.v.at[phys, :, off].set(v.astype(cache.v.dtype))

    scale = cfg.d_head ** -0.5
    if i8:
        scale = scale / cfg.kv_i8_scale
    if c == 1 and ops.fused_mode(cfg.fused_decode) == "kernel":
        # single-dispatch decode: the Pallas kernel walks the block table via
        # scalar prefetch and streams pool blocks through VMEM (DESIGN.md
        # §18) — the gather/mask/softmax/PV chain below is its reference
        # twin (exact in real arithmetic, allclose in floats), kept as the
        # production path on ref/interpret backends so cross-layout token
        # pins stay bitwise.  Chunked prefill (c > 1) always takes the
        # unfused path.
        out = paged_attn.paged_decode_attention(
            q[:, 0], ck, cv, table, qpos[:, 0], window=window,
            scale=float(scale),
            out_scale=float(1.0 / cfg.kv_i8_scale) if i8 else 1.0,
            interpret=ops._resolve("auto") != "pallas")
        out = out.reshape(b, 1, cfg.n_heads * cfg.d_head).astype(x.dtype)
        return layers.linear(out, p["wo"], cfg.quant), PagedKVCache(ck, cv)

    gk = jnp.moveaxis(ck[table], 1, 2).reshape(b, cfg.n_kv_heads, cap,
                                               cfg.d_head)
    gv = jnp.moveaxis(cv[table], 1, 2).reshape(b, cfg.n_kv_heads, cap,
                                               cfg.d_head)
    scores = jnp.einsum("bqkgd,bksd->bkgqs", q, gk.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    kslot = jnp.arange(cap)[None, None, :]
    if window:
        # ring: slot s holds the latest position p with p % cap == s; the
        # ring capacity >= window + C - 1 guarantees every in-window key of
        # every chunk query is still resident (DESIGN.md §14).
        age = (qpos[:, :, None] % cap - kslot) % cap           # (B, C, cap)
        valid_k = age < jnp.minimum(window, qpos[:, :, None] + 1)
    else:
        valid_k = kslot <= qpos[:, :, None]                    # (B, C, cap)
    scores = jnp.where(valid_k[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bqkgd", probs.astype(q.dtype),
                     gv.astype(q.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if i8:
        out = out / cfg.kv_i8_scale
    out = out.reshape(b, c, cfg.n_heads * cfg.d_head)
    return layers.linear(out, p["wo"], cfg.quant), PagedKVCache(ck, cv)
