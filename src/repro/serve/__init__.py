"""Continuous-batching XNOR serve engine (DESIGN.md §13–§14).

Public surface:
  Request / Session / synthetic_trace — the request model,
  SlotPool / BlockPool                — pure scheduling bookkeeping (slots,
                                        paged-KV block allocation),
  ServeEngine / ServeReport           — the engine itself,
  EngineStats                         — counters incl. block occupancy.
"""

from repro.serve.scheduler import (BlockPool, EngineStats, ServeEngine,
                                   ServeReport, SlotPool)
from repro.serve.session import Request, Session, synthetic_trace

__all__ = ["BlockPool", "EngineStats", "Request", "ServeEngine",
           "ServeReport", "Session", "SlotPool", "synthetic_trace"]
