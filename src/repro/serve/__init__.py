"""Continuous-batching XNOR serve engine (DESIGN.md §13–§17).

Module map (the replica-ready split, §17):
  session.py   — Request / Session / synthetic traces (the request model),
  pools.py     — SlotPool / BlockPool: pure scheduling bookkeeping (slots,
                 refcounted paged-KV block allocation, idle LRU tier),
  prefix.py    — PrefixIndex: content-addressed prefix cache index,
  stats.py     — EngineStats / ServeReport: counters and run reports,
  engine.py    — ServeEngine + its jitted programs (prefill / chunked
                 prefill / decode / insert / COW) and session export/import,
  router.py    — Router: N engine replicas, least-loaded admission, live
                 session migration, kill-drill draining, integrity scrubber,
  workloads.py — TranscriptionService / ClassifierService drivers (§16).

Everything below re-exports from those modules; importing from
``repro.serve`` is the stable surface and survives internal splits.
"""

from repro.serve.engine import ServeEngine
from repro.serve.pools import BlockPool, SlotPool
from repro.serve.prefix import PrefixIndex
from repro.serve.router import Router, RouterReport
from repro.serve.session import (Request, Session, TranscriptStream,
                                 synthetic_audio_trace, synthetic_trace)
from repro.serve.stats import EngineStats, ServeReport
from repro.serve.workloads import ClassifierService, TranscriptionService

__all__ = ["BlockPool", "ClassifierService", "EngineStats", "PrefixIndex",
           "Request", "Router", "RouterReport", "ServeEngine", "ServeReport",
           "Session", "SlotPool", "TranscriptStream", "TranscriptionService",
           "synthetic_audio_trace", "synthetic_trace"]
