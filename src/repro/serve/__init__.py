"""Continuous-batching XNOR serve engine (DESIGN.md §13–§15).

Public surface:
  Request / Session / synthetic_trace — the request model,
  SlotPool / BlockPool                — pure scheduling bookkeeping (slots,
                                        refcounted paged-KV block allocation),
  PrefixIndex                         — content-addressed prefix cache index,
  ServeEngine / ServeReport           — the engine itself,
  EngineStats                         — counters incl. block occupancy and
                                        prefix-cache hit rate.
"""

from repro.serve.scheduler import (BlockPool, EngineStats, PrefixIndex,
                                   ServeEngine, ServeReport, SlotPool)
from repro.serve.session import Request, Session, synthetic_trace

__all__ = ["BlockPool", "EngineStats", "PrefixIndex", "Request",
           "ServeEngine", "ServeReport", "Session", "SlotPool",
           "synthetic_trace"]
