"""Continuous-batching XNOR serve engine (DESIGN.md §13).

Public surface:
  Request / Session / synthetic_trace — the request model,
  SlotPool                            — pure scheduling bookkeeping,
  ServeEngine / ServeReport           — the engine itself.
"""

from repro.serve.scheduler import ServeEngine, ServeReport, SlotPool
from repro.serve.session import Request, Session, synthetic_trace

__all__ = ["Request", "ServeEngine", "ServeReport", "Session", "SlotPool",
           "synthetic_trace"]
