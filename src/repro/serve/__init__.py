"""Continuous-batching XNOR serve engine (DESIGN.md §13–§16).

Public surface:
  Request / Session / synthetic_trace — the request model,
  TranscriptStream / synthetic_audio_trace — streaming-audio inputs,
  SlotPool / BlockPool                — pure scheduling bookkeeping (slots,
                                        refcounted paged-KV block allocation),
  PrefixIndex                         — content-addressed prefix cache index,
  ServeEngine / ServeReport           — the engine itself,
  TranscriptionService / ClassifierService — workload drivers over the
                                        unchanged engine core (§16),
  EngineStats                         — counters incl. block occupancy and
                                        prefix-cache hit rate.
"""

from repro.serve.scheduler import (BlockPool, EngineStats, PrefixIndex,
                                   ServeEngine, ServeReport, SlotPool)
from repro.serve.session import (Request, Session, TranscriptStream,
                                 synthetic_audio_trace, synthetic_trace)
from repro.serve.workloads import ClassifierService, TranscriptionService

__all__ = ["BlockPool", "ClassifierService", "EngineStats", "PrefixIndex",
           "Request", "ServeEngine", "ServeReport", "Session", "SlotPool",
           "TranscriptStream", "TranscriptionService",
           "synthetic_audio_trace", "synthetic_trace"]
