"""Replicated serving tier with verified live session migration
(DESIGN.md §17).

:class:`Router` fronts N :class:`repro.serve.engine.ServeEngine` replicas,
each pinned to its own ``launch.mesh`` sub-mesh (one device slice per
replica — genuinely side-by-side under the multi-device CI mode) and
watched by a :class:`repro.distributed.fault.StragglerPolicy` fed the
replica's per-step wall time:

* **admission** routes each request to the least-loaded alive replica
  (in-flight + queued; ties to the lowest index — deterministic);
* **migration** moves a *live* session between replicas through an
  encrypted checkpoint: the source engine's :meth:`export_session` wire
  tree is written with :func:`repro.checkpoint.ckpt.save` (first hop) or
  :func:`~repro.checkpoint.ckpt.save_delta` (later hops — unchanged
  leaves such as the prompt, modality ctx and any still-identical KV
  prefix resolve through the chain instead of being re-stored), and the
  destination restores against a spec derived from (cfg, geometry,
  request) — never from the file — then :meth:`import_session` re-admits
  it token-identically under the schedule-independent (rid, step)
  seed-folding contract;
* **kill drill**: :meth:`kill` marks a replica dead, resubmits its queued
  sessions, and drains every admitted session onto surviving replicas via
  migration checkpoints, stepping the survivors when they are momentarily
  full — every in-flight request finishes with zero token divergence;
* a background **integrity scrubber** (:class:`IntegrityScrubber`) walks
  each replica every router epoch: an incremental
  :class:`repro.core.incremental.DigestCache` pass over the resident
  packed weights (identity tier: zero dispatch while nothing changed) and
  over idle cached KV blocks (baselined per (block, idle-stamp) so a
  legitimately recycled block is re-baselined, not flagged), surfacing
  mismatches in ``EngineStats.scrub_corruptions``.
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np
import jax

from repro.checkpoint import ckpt
from repro.core.incremental import DigestCache
from repro.core.verify import leaf_key
from repro.distributed.fault import StragglerPolicy
from repro.launch.mesh import make_replica_meshes
from repro.serve.engine import ServeEngine
from repro.serve.session import Request, Session
from repro.serve.stats import EngineStats, ServeReport


class IntegrityScrubber:
    """Background digest verification of one replica's resident state.

    Weights: the first pass records a per-leaf digest baseline of the
    engine's (packed) params through a :class:`DigestCache`; later passes
    re-digest — the cache's identity tier makes an unchanged pass free —
    and any digest that moved against the baseline is a corruption (the
    params of a serving engine are immutable by contract).

    Idle cached KV blocks: each idle block's pool contents are digested
    and baselined per ``(bid, idle_stamp)``; while the block stays in the
    idle tier its bytes must not move (nothing may write a cached block —
    DESIGN.md §15), so a moved digest is a corruption.  A block that was
    revived, rewritten by a new holder and re-idled carries a new stamp
    and is re-baselined instead of flagged.
    """

    def __init__(self, engine: ServeEngine, cache: DigestCache | None = None):
        self.engine = engine
        self.cache = cache if cache is not None else DigestCache()
        self._weight_baseline: dict[str, bytes] | None = None
        # bid -> (idle stamp, {leaf key: digest bytes})
        self._block_baseline: dict[int, tuple[int, dict[str, bytes]]] = {}

    @staticmethod
    def _flat_digests(tree) -> dict[str, bytes]:
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        return {leaf_key(p): np.asarray(d).tobytes() for p, d in flat}

    def scrub(self) -> int:
        """One pass; returns mismatches found (also accumulated into the
        engine's ``scrub_*`` counters)."""
        eng, st = self.engine, self.engine.stats
        bad = 0
        digs = self._flat_digests(self.cache.digests(eng.params))
        if self._weight_baseline is None:
            self._weight_baseline = digs
        else:
            bad += sum(1 for k, v in digs.items()
                       if v != self._weight_baseline[k])
        st.scrub_weight_leaves += len(digs)
        if eng.paged and eng.blocks is not None:
            idle = set(eng.blocks.idle_blocks)
            for bid in list(self._block_baseline):
                if bid not in idle:
                    del self._block_baseline[bid]
            for bid in sorted(idle):
                stamp = eng.blocks.idle_stamp(bid)
                digs = self._flat_digests(
                    self.cache.digests({f"idle_block/{bid}":
                                        eng.gather_block(bid)}))
                base = self._block_baseline.get(bid)
                if base is not None and base[0] == stamp:
                    bad += sum(1 for k, v in digs.items() if v != base[1][k])
                else:
                    self._block_baseline[bid] = (stamp, digs)
            st.scrub_idle_blocks += len(idle)
        st.scrub_passes += 1
        st.scrub_corruptions += bad
        return bad


@dataclasses.dataclass
class ReplicaHandle:
    """One replica: engine + sub-mesh + its fault-detection state."""

    index: int
    engine: ServeEngine
    mesh: object                       # this replica's launch.mesh sub-mesh
    device: object                     # mesh.devices.flat[0]: where it runs
    policy: StragglerPolicy
    scrubber: IntegrityScrubber
    alive: bool = True

    @property
    def load(self) -> int:
        """In-flight + queued — the admission routing metric."""
        return len(self.engine.pool.active) + self.engine.pool.queued

    def can_accept(self, request: Request) -> bool:
        """Whether an import/submit of ``request`` fits right now (free
        slot, and a wholly-fresh block reservation fits the pool)."""
        eng = self.engine
        if not eng.pool.free_slots:
            return False
        if eng.blocks is None:
            return True
        need = sum(eng._blocks_per_class(request.prompt.shape[0],
                                         request.max_new_tokens).values())
        return need <= eng.blocks.reclaimable


@dataclasses.dataclass
class RouterReport:
    """Outcome of one :meth:`Router.run`: the merged session map plus
    per-replica stats and the migration/fault event log."""

    sessions: dict[int, Session]
    wall: float
    replicas: list[EngineStats]
    migrations: list[tuple[int, int, int, int]]   # (rid, src, dst, ckpt step)
    straggler_events: list[tuple[int, int, str]]  # (router step, replica, verdict)
    killed: list[int]

    @property
    def generated(self) -> int:
        return sum(len(s.tokens) for s in self.sessions.values())

    @property
    def tok_per_s(self) -> float:
        return self.generated / max(self.wall, 1e-9)

    def tokens(self, rid: int) -> np.ndarray:
        return np.asarray(self.sessions[rid].tokens, np.int32)

    @property
    def scrub_passes(self) -> int:
        return sum(r.scrub_passes for r in self.replicas)

    @property
    def scrub_corruptions(self) -> int:
        return sum(r.scrub_corruptions for r in self.replicas)

    def serve_report(self) -> ServeReport:
        """The merged sessions as a :class:`ServeReport` so the quantile
        helpers (latency / ttft / queue-wait) apply across replicas."""
        agg = EngineStats()
        for r in self.replicas:
            for f in ("decode_steps", "prefills", "prefill_chunks",
                      "migrations_out", "migrations_in", "scrub_passes",
                      "scrub_weight_leaves", "scrub_idle_blocks",
                      "scrub_corruptions", "prefix_hits", "prefix_tokens",
                      "prompt_tokens", "fresh_blocks", "cow_copies"):
                setattr(agg, f, getattr(agg, f) + getattr(r, f))
        return ServeReport(sessions=dict(self.sessions), wall=self.wall,
                           decode_steps=agg.decode_steps,
                           prefills=agg.prefills, stats=agg)


class Router:
    """N-replica serving tier with live migration (DESIGN.md §17).

    Every replica is a full :class:`ServeEngine` over the *same* (cfg,
    params, s_max, block_size, prefill_chunk, temperature, seed) — the
    migration token-identity contract — pinned to its own sub-mesh
    device.  ``slots`` / ``n_blocks`` are per-replica and may differ from
    the source at import time without affecting tokens (the seed contract
    is schedule-independent).

    ``ckpt_dir`` is where migration wires land, one directory per request
    (``rid_<rid>/``), encrypted under ``root_key``; successive migrations
    of the same request extend a delta chain.  ``epoch_steps`` sets the
    scrubber cadence in router steps (0 disables).
    """

    def __init__(self, cfg, params, n_replicas: int, *, slots: int,
                 s_max: int, ckpt_dir: str, root_key: str = "serve-mig",
                 epoch_steps: int = 8, eos_id: int | None = None,
                 temperature: float = 0.0, seed: int = 0, pack: bool = True,
                 block_size: int = 0, prefill_chunk: int = 0,
                 n_blocks: int = 0, prefix_cache: bool = True,
                 straggler_factor: float = 2.0):
        if n_replicas < 1:
            raise ValueError(f"need at least one replica, got {n_replicas}")
        self.ckpt_dir = ckpt_dir
        self.root_key = root_key
        self.epoch_steps = int(epoch_steps)
        self.replicas: list[ReplicaHandle] = []
        meshes = make_replica_meshes(n_replicas)
        for i, mesh in enumerate(meshes):
            dev = mesh.devices.flat[0]
            with jax.default_device(dev):
                eng = ServeEngine(cfg, params, slots=slots, s_max=s_max,
                                  eos_id=eos_id, temperature=temperature,
                                  seed=seed, pack=pack, paged=True,
                                  block_size=block_size,
                                  prefill_chunk=prefill_chunk,
                                  n_blocks=n_blocks,
                                  prefix_cache=prefix_cache)
            self.replicas.append(ReplicaHandle(
                index=i, engine=eng, mesh=mesh, device=dev,
                policy=StragglerPolicy(straggler_factor=straggler_factor),
                scrubber=IntegrityScrubber(eng)))
        self._requests: dict[int, Request] = {}
        self._where: dict[int, int] = {}          # rid -> replica index
        self._mig_step: dict[int, int] = {}       # rid -> last ckpt step
        self._mig_cache: dict[int, DigestCache] = {}
        self._step = 0
        self.migrations: list[tuple[int, int, int, int]] = []
        self.straggler_events: list[tuple[int, int, str]] = []
        self.killed: list[int] = []

    # -- admission -----------------------------------------------------------

    def _alive(self) -> list[ReplicaHandle]:
        return [h for h in self.replicas if h.alive]

    def submit(self, request: Request) -> Session:
        """Route to the least-loaded alive replica (ties: lowest index)."""
        alive = self._alive()
        if not alive:
            raise RuntimeError("no alive replica")
        h = min(alive, key=lambda h: (h.load, h.index))
        session = h.engine.submit(request)
        self._requests[request.rid] = request
        self._where[request.rid] = h.index
        return session

    # -- the step loop -------------------------------------------------------

    def step(self) -> bool:
        """Advance every alive replica one engine step, feed each step's
        wall time to its straggler policy, and scrub on epoch boundaries.
        Returns False once every alive replica is drained."""
        self._step += 1
        busy = False
        for h in self._alive():
            if h.engine.pool.idle():
                continue
            t0 = time.monotonic()
            with jax.default_device(h.device):
                busy |= h.engine.step()
            verdict = h.policy.observe(self._step, time.monotonic() - t0)
            if verdict != "ok":
                self.straggler_events.append((self._step, h.index, verdict))
        if self.epoch_steps and self._step % self.epoch_steps == 0:
            self.scrub()
        return busy

    def scrub(self) -> int:
        """One scrubber pass over every alive replica; returns mismatches."""
        bad = 0
        for h in self._alive():
            with jax.default_device(h.device):
                bad += h.scrubber.scrub()
        return bad

    # -- migration -----------------------------------------------------------

    def _wire_dir(self, rid: int) -> str:
        return os.path.join(self.ckpt_dir, f"rid_{rid}")

    def migrate(self, rid: int, src: int, dst: int) -> Session:
        """Move live session ``rid`` from replica ``src`` to ``dst``
        through an encrypted (delta) checkpoint.  The source slot is
        released only after the wire is durably written; the destination
        restores against its own derived spec and re-admits
        token-identically."""
        if src == dst:
            raise ValueError(f"migrate({rid}): src == dst == {src}")
        hs, hd = self.replicas[src], self.replicas[dst]
        if not hd.alive:
            raise RuntimeError(f"migrate({rid}): replica {dst} is dead")
        request = self._requests[rid]
        if not hd.can_accept(request):
            raise RuntimeError(f"migrate({rid}): replica {dst} is full")
        with jax.default_device(hs.device):
            wire = hs.engine.export_session(rid)
        d = self._wire_dir(rid)
        step = self._mig_step.get(rid, 0) + 1
        cache = self._mig_cache.setdefault(rid, DigestCache())
        if step == 1:
            ckpt.save(d, step, wire, root_key=self.root_key)
            cache.digests(wire)        # prime: exact dirtiness on hop 2
            cache.mark_saved()
        else:
            # delta vs the previous hop: the prompt, ctx and any KV
            # prefix blocks identical since the last migration resolve
            # through the chain instead of being re-stored
            ckpt.save_delta(d, step, wire, root_key=self.root_key,
                            cache=cache)
        self._mig_step[rid] = step
        with jax.default_device(hd.device):
            like = hd.engine.export_spec(request)
            restored, _ = ckpt.restore(d, step, like, root_key=self.root_key)
            session = hd.engine.import_session(request, restored)
        hs.engine.release_migrated(rid)
        self._where[rid] = dst
        self.migrations.append((rid, src, dst, step))
        return session

    # -- fault drill ---------------------------------------------------------

    def kill(self, index: int) -> None:
        """Kill-a-replica drill: mark ``index`` dead, resubmit its queued
        sessions to the survivors, and drain every admitted session onto
        them via migration checkpoints — stepping the survivors forward
        whenever none can momentarily accept (finishing requests free
        slots and blocks, so the drain always makes progress)."""
        h = self.replicas[index]
        if not h.alive:
            raise RuntimeError(f"replica {index} is already dead")
        if len(self._alive()) < 2:
            raise RuntimeError("kill(): no surviving replica to drain onto")
        h.alive = False
        self.killed.append(index)
        for sess in h.engine.pool.drain_queue():
            rid = sess.request.rid
            del h.engine.sessions[rid]
            new = self.submit(sess.request)
            new.t_submit = sess.t_submit   # queue time survives the reroute
        admitted = sorted(s.request.rid
                          for s in h.engine.pool.active.values())
        for rid in admitted:
            dst = self._await_capacity(self._requests[rid])
            self.migrate(rid, index, dst.index)

    def _await_capacity(self, request: Request,
                        max_steps: int = 100_000) -> ReplicaHandle:
        """The least-loaded alive replica that can accept ``request``,
        stepping the alive replicas until one can."""
        for _ in range(max_steps):
            fits = [h for h in self._alive() if h.can_accept(request)]
            if fits:
                return min(fits, key=lambda h: (h.load, h.index))
            if not self.step():
                break     # everyone drained yet nobody fits: impossible
        raise RuntimeError(
            f"no replica can accept request {request.rid} "
            f"(prompt {request.prompt.shape[0]}, "
            f"budget {request.max_new_tokens})")

    # -- drive to completion -------------------------------------------------

    def run(self, kill_at: int | None = None,
            victim: int | None = None) -> RouterReport:
        """Drain every replica; with ``kill_at`` set, run the fault drill
        at that router step (victim defaults to the most-loaded replica —
        the worst case for the survivors)."""
        t0 = time.monotonic()
        while True:
            if kill_at is not None and self._step + 1 >= kill_at \
                    and len(self._alive()) > 1:
                v = victim if victim is not None else max(
                    self._alive(), key=lambda h: (h.load, -h.index)).index
                self.kill(v)
                kill_at = None
            if not self.step():
                break
        sessions = {rid: self.replicas[idx].engine.sessions[rid]
                    for rid, idx in self._where.items()}
        return RouterReport(sessions=sessions,
                            wall=time.monotonic() - t0,
                            replicas=[h.engine.stats for h in self.replicas],
                            migrations=list(self.migrations),
                            straggler_events=list(self.straggler_events),
                            killed=list(self.killed))
