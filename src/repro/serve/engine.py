"""Continuous-batching decode engine (DESIGN.md §13–§14).

The paper's application regime — binary filters resident in the CiM array,
XNOR-popcount as the serve-time inner loop — needs a *request-level* engine
on top of the token-level serve path.  This module provides it:

* a FIFO request queue and a fixed pool of batch **slots** over one resident
  :class:`repro.models.lm.DecodeState` (per-slot position vector);
* a **block-paged KV cache** (default, DESIGN.md §14): attention state
  lives in a shared block pool addressed through host-owned per-slot block
  tables (:class:`repro.serve.pools.BlockPool` allocates; tables are device
  *data*), so cache memory is proportional to tokens actually held, not
  ``slots x s_max``; ``paged=False`` keeps the slot-dense layout — the two
  are token-identical (MoE excepted, see §14);
* **admission**: a freed slot is immediately refilled.  Paged: the
  request's worst-case blocks are reserved (OOM backpressure holds the
  FIFO head otherwise) and the prompt is consumed by **chunked prefill** —
  fixed ``prefill_chunk``-sized pieces through ONE jitted program, so
  prefill compiles once for any prompt-length mix and long prompts
  interleave with decode in bounded slices.  Dense: exact-length batch-1
  prefill scattered into the slot (one trace per distinct length);
* **eviction** on EOS or max-token budget: the slot is marked free and its
  blocks return to the pool; dead rows are inert (position frozen via the
  active mask, table rows zeroed so frozen re-writes land in the reserved
  trash block);
* **one jitted decode program** for the whole run: position vector, active
  mask, block tables, sampling seeds are device *data*, never trace
  constants, so slots joining/leaving and blocks moving never retrace;
* **session export/import** (DESIGN.md §17): a live slot's complete state —
  paged KV blocks gathered through its table rows, per-slot recurrent /
  window carries, position, generated tokens, chunked-prefill progress —
  lifts out as a flat array tree plus host metadata and re-admits into any
  engine with the same (cfg, geometry), token-identically under the
  schedule-independent (rid, step) seed-folding contract.  The replicated
  tier (:mod:`repro.serve.router`) moves it between replicas as an
  encrypted delta checkpoint.

With ``pack=True`` (default) and a ``quant="xnor"`` arch the resident
params are the packed form (:func:`repro.models.lm.pack_params`): binary
filter planes + beta, float weights absent — packed-weight residency (runs
on both cache layouts).

Scheduling bookkeeping lives in :mod:`repro.serve.pools` (pure host logic,
unit-testable without a model), the content-addressed prefix index in
:mod:`repro.serve.prefix`, and counters/reports in
:mod:`repro.serve.stats`; this module owns the jitted programs and the
engine loop that drives them.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm
from repro.serve.pools import BlockPool, SlotPool
from repro.serve.prefix import PrefixIndex
from repro.serve.session import Request, Session
from repro.serve.stats import EngineStats, ServeReport

# ---------------------------------------------------------------------------
# jitted programs (module level: one trace cache per (cfg, shapes))
# ---------------------------------------------------------------------------


def _sample_tokens(cfg, logits, key, seeds, temperature: float):
    """Last-position sampling, sliced to the true vocab (pad ids never
    sampled).  Per-row keys fold the host-computed (rid, step) seed into the
    engine key, so draws depend only on the request and its token index —
    never on slot assignment or batch composition (determinism under a
    fixed seed, whatever the schedule)."""
    lg = logits[:, -1, :cfg.vocab].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]

    def one(row, seed):
        g = jax.random.gumbel(jax.random.fold_in(key, seed), row.shape,
                              jnp.float32)
        return jnp.argmax(row / temperature + g, axis=-1).astype(jnp.int32)
    return jax.vmap(one)(lg, seeds)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "s_max", "temperature"))
def _prefill_program(cfg, params, tokens, ctx, key, seeds, *, s_max: int,
                     temperature: float):
    """(1, P) prompt -> (first sampled token (1, 1), DecodeState for B=1)."""
    logits, state = lm.prefill(cfg, params, tokens, ctx, s_max=s_max)
    return _sample_tokens(cfg, logits, key, seeds, temperature), state


@functools.partial(jax.jit, static_argnames=("cfg", "temperature"),
                   donate_argnames=("state",))
def _decode_program(cfg, params, tokens, state, active, key, seeds, *,
                    temperature: float):
    """One token for every slot; inactive slots' positions stay frozen."""
    logits, state = lm.decode_step(cfg, params, tokens, state, active=active)
    return _sample_tokens(cfg, logits, key, seeds, temperature), state


@functools.partial(jax.jit, donate_argnames=("resident",))
def _insert_program(resident: lm.DecodeState, one: lm.DecodeState, slot):
    """Scatter a freshly prefilled B=1 state into resident slot ``slot``.

    Segment-state leaves are layer-stacked with batch at axis 1
    ((n_layers, B, ...)); ``ctx`` and ``pos`` carry batch at axis 0.  The
    resident tree follows ``lm.decode_state_spec``: for enc-dec archs its
    ``ctx`` is None (cross-attn KV lives inside the per-layer states; the
    decode path never reads ``DecodeState.ctx``), so the prefill state's
    encoder output is dropped rather than kept resident.
    """
    seg = jax.tree.map(lambda r, o: r.at[:, slot].set(o[:, 0]),
                       resident.seg_states, one.seg_states)
    pos = resident.pos.at[slot].set(one.pos)
    ctx = (resident.ctx if resident.ctx is None
           else resident.ctx.at[slot].set(one.ctx[0]))
    return lm.DecodeState(pos, seg, ctx)


@dataclasses.dataclass
class _PrefillProgress:
    """Host bookkeeping for one slot's in-flight chunked prefill."""

    session: Session
    padded: np.ndarray          # prompt suffix zero-padded to n_chunks * C
    p_len: int                  # suffix length = prompt length - skip
    n_chunks: int
    next_chunk: int
    ctx: Any                    # encoded (enc-dec) / raw (vlm) ctx, or None
    seeds: Any                  # (1,) device seeds for the prefill sample
    rows: dict                  # this slot's (1, W) block-table rows
    skip: int = 0               # positions served from shared prefix blocks
    chain: list = dataclasses.field(default_factory=list)
                                # full prompt's (key, parent, tokens) chain
    registered: int = 0         # prompt blocks registered so far


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Continuous-batching serve engine over one resident decode state.

    Args:
      cfg: ArchConfig. ``quant="xnor"`` archs serve from packed weights
        unless ``pack=False``.
      params: float param tree (as from ``lm.init_params`` / ``ckpt``);
        packed at construction when applicable — the float copies of
        binarized linears are not retained by the engine.
      slots: resident batch width (concurrent requests).
      s_max: per-slot cache capacity; every request needs
        ``len(prompt) + max_new_tokens - 1 <= s_max``.
      eos_id: token id that terminates a request early (None: budget only).
      temperature: 0 = greedy (deterministic); > 0 = gumbel sampling with
        schedule-independent per-(request, step) keys.
      seed: engine sampling seed.
      pack: keep binarizable linears packed-resident (xnor archs only).
      prefix_cache: content-addressed prefix sharing over the paged pool
        (DESIGN.md §15; paged engines only).  Auto-disabled for archs whose
        state cannot be rebuilt from cached blocks (recurrent carries,
        local window rings) — ``engine.prefix_caching`` reports the
        effective setting.
    """

    def __init__(self, cfg, params, *, slots: int, s_max: int,
                 eos_id: int | None = None, temperature: float = 0.0,
                 seed: int = 0, pack: bool = True, paged: bool = True,
                 block_size: int = 0, prefill_chunk: int = 0,
                 n_blocks: int = 0, prefix_cache: bool = True):
        self.cfg = cfg
        self.slots = slots
        self.s_max = s_max
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self.params = lm.pack_params(cfg, params) if pack else params
        self.pool = SlotPool(slots)
        self.sessions: dict[int, Session] = {}
        self._key = jax.random.PRNGKey(seed)
        self.paged = bool(paged)
        self.stats = EngineStats()
        self._step_idx = 0                 # engine steps since construction
        if self.paged:
            self.block_size = block_size or cfg.block_size
            self.prefill_chunk = prefill_chunk or cfg.prefill_chunk
            self._widths = lm.paged_table_widths(cfg, s_max, self.block_size,
                                                 self.prefill_chunk)
            per_slot_worst = sum(self._widths.values())
            if n_blocks <= 0:
                # default: enough for every slot at full table width (the
                # paged layout is then never *smaller* than dense; callers
                # shrink n_blocks to oversubscribe slots at equal memory)
                n_blocks = 1 + slots * max(per_slot_worst, 1)
            self.n_blocks = n_blocks
            self.blocks = BlockPool(n_blocks) if self._widths else None
            self.stats.blocks_total = n_blocks - 1 if self.blocks else 0
            # prefix caching (DESIGN.md §15): only for archs whose whole
            # sequential state is reconstructible from the paged pools —
            # prefix_cache_eligible is fail-closed over each kind's
            # declared prefix_shareable contract flag (recurrent carries
            # and local window *rings* don't declare it).  The table class
            # shared prefixes register under comes from the same contracts.
            self._share_cls = lm.prefix_table_class(cfg)
            self._prefix = (PrefixIndex(self.block_size)
                            if prefix_cache and self.blocks is not None
                            and self._share_cls is not None
                            and lm.prefix_cache_eligible(cfg) else None)
            # host-owned block tables, mirrored to device on change
            self._tables = {c: np.zeros((slots, w), np.int32)
                            for c, w in self._widths.items()}
            self._dev_tables = None
            self._state = lm.paged_decode_state_spec(
                cfg, slots, s_max, n_blocks=n_blocks,
                block_size=self.block_size, abstract=False)
            self._build_paged_programs()
        else:
            # the single source of truth for the resident layout is
            # lm.decode_state_spec (the same tree the dry-run lowers)
            self._state = lm.decode_state_spec(cfg, slots, s_max,
                                               abstract=False,
                                               per_slot_pos=True)
            self._dense_prefill_lens: set[int] = set()
            self._prefix = None
            self._share_cls = None
        # host-side mirrors of the device batch (tiny, moved every step)
        self._tokens = np.zeros((slots, 1), np.int32)
        self._active = np.zeros((slots,), bool)
        # slots mid-chunked-prefill: slot -> _PrefillProgress (paged only;
        # dense prefill is a single exact-length program, nothing to slice)
        self._prefilling: dict[int, _PrefillProgress] = {}
        # memoized FIFO-head prefix plan: ((rid, index generation), plan)
        self._plan_cache: tuple[tuple[int, int], tuple] | None = None

    def _build_paged_programs(self):
        """Per-engine jits so trace counts are observable: the python side
        effect on ``stats`` runs at trace time only, so ``prefill_traces``
        counts compilations — the chunked-prefill contract pins it to 1."""
        cfg, temperature = self.cfg, self.temperature

        def chunk_fn(params, tokens, state, slot, n_valid, tables, ctx,
                     fresh, start, key, seeds):
            self.stats.prefill_traces += 1
            logits, state = lm.prefill_chunk_step(cfg, params, tokens, state,
                                                  slot, n_valid, tables, ctx,
                                                  fresh=fresh, start=start)
            return (_sample_tokens(cfg, logits, key, seeds, temperature),
                    state)

        def decode_fn(params, tokens, state, tables, active, key, seeds):
            self.stats.decode_traces += 1
            logits, state = lm.paged_decode_step(cfg, params, tokens, state,
                                                 tables, active=active)
            return (_sample_tokens(cfg, logits, key, seeds, temperature),
                    state)

        self._chunk_program = jax.jit(chunk_fn, donate_argnums=(2,))
        self._paged_decode_program = jax.jit(decode_fn, donate_argnums=(2,))
        # copy-on-write block duplication: src/dst are device scalars, so
        # one program covers every (donor, recipient) pair without retracing
        self._cow_program = jax.jit(
            lambda state, src, dst: lm.paged_copy_block(cfg, state, src, dst),
            donate_argnums=(0,))
        self._encode_program = None
        if cfg.is_encdec():
            self._encode_program = jax.jit(
                lambda params, frames: lm.encode(cfg, params, frames))
        # session migration (§17): slot/ids are device data, payload shapes
        # are fixed by (cfg, geometry) — one trace each for the whole run
        self._export_program = jax.jit(
            lambda state, slot, rows: lm.export_slot(cfg, state, slot, rows))
        self._import_program = jax.jit(
            lambda state, slot, rows, payload: lm.import_slot(
                cfg, state, slot, rows, payload),
            donate_argnums=(0,))
        self._gather_block_program = jax.jit(
            lambda state, bid: lm.gather_block(cfg, state, bid))

    def _blocks_per_class(self, prompt_len: int,
                          max_new_tokens: int) -> dict[str, int]:
        """Worst-case block reservation per table class for one request:
        positions 0..P+G-2 are cached, window classes cap at their ring
        width.  Single source for both the admission gate and the actual
        allocation — they must never drift apart."""
        nb = -(-(prompt_len + max_new_tokens - 1) // self.block_size)
        return {c: min(nb, w) for c, w in self._widths.items()}

    def _blocks_needed(self, prompt_len: int, max_new_tokens: int) -> int:
        return sum(self._blocks_per_class(prompt_len,
                                          max_new_tokens).values())

    # -- request lifecycle ---------------------------------------------------

    def submit(self, request: Request) -> Session:
        if request.rid in self.sessions:
            raise ValueError(f"duplicate request id {request.rid}")
        need = request.prompt.shape[0] + request.max_new_tokens - 1
        if need > self.s_max:
            raise ValueError(
                f"request {request.rid} needs {need} cache positions, "
                f"engine capacity is s_max={self.s_max}")
        if self.paged and self.blocks is not None:
            nb = self._blocks_needed(request.prompt.shape[0],
                                     request.max_new_tokens)
            if nb > self.blocks.capacity:
                raise ValueError(
                    f"request {request.rid} needs {nb} blocks, pool "
                    f"capacity is {self.blocks.capacity} "
                    f"(n_blocks={self.n_blocks} incl. trash block)")
        session = Session(request, t_submit=time.monotonic())
        self.sessions[request.rid] = session
        self.pool.submit(session)
        return session

    def _seed_for(self, rid: int, step: int) -> int:
        return (rid * 1_000_003 + step) % (2**31 - 1)

    def _finish(self, session: Session, reason: str) -> None:
        session.finish_reason = reason
        session.t_done = time.monotonic()
        if session.slot is not None and session.slot in self.pool.active:
            slot = session.slot
            self.pool.evict(slot)
            self._active[slot] = False
            self._tokens[slot] = 0   # dead slots feed a constant token id
                                     # (keeps MoE capacity competition quiet)
            if self.paged:
                # eviction returns every block the request held; the zeroed
                # table row routes the dead slot's frozen re-writes to the
                # trash block so reallocated blocks are never corrupted.
                # Cached blocks (registered below / during prefill) park in
                # the pool's idle tier instead of freeing.
                if self.blocks is not None:
                    if self._prefix is not None:
                        self._register_finished(session, slot)
                    self.blocks.free(session.request.rid)
                for t in self._tables.values():
                    t[slot, :] = 0
                self._dev_tables = None

    def _register_finished(self, session: Session, slot: int) -> None:
        """Register the request's full written blocks on release — prompt
        *and* generated region: positions 0..P+G-2 are written (the last
        sampled token never is), so every full block's contents are final
        and a later prompt extending this one past its prompt shares the
        decode region too."""
        req = session.request
        written = req.prompt.shape[0] + len(session.tokens) - 1
        seq = req.prompt
        if len(session.tokens) > 1:
            seq = np.concatenate(
                [seq, np.asarray(session.tokens[:-1], np.int32)])
        row = self._tables[self._share_cls][slot]
        chain = self._prefix.chain(seq[:written], req.ctx)
        for i, (key, parent, toks) in enumerate(chain):
            bid = int(row[i])
            if self._prefix.register(key, parent, bid, toks):
                self.blocks.set_cached(bid)
        self.stats.prefix_cached_blocks = len(self._prefix)

    def _ctx_for(self, req: Request):
        if req.ctx is not None:
            ctx = jnp.asarray(np.asarray(req.ctx)[None])
            if self.paged and self.cfg.is_encdec():
                # encode once at admission; chunks consume the frames
                ctx = self._encode_program(self.params, ctx)
            return ctx
        if self.cfg.n_ctx_tokens:
            raise ValueError(
                f"arch {self.cfg.name} needs per-request ctx; request "
                f"{req.rid} has none")
        return None

    def _post_prefill(self, session: Session, slot: int, tok) -> bool:
        """Record the prefill-sampled token; returns True when the request
        survives into the decode batch."""
        t = int(np.asarray(tok)[0, 0])
        session.tokens.append(t)
        session.t_first = time.monotonic()
        session.step_first = self._step_idx
        if self.eos_id is not None and t == self.eos_id:
            self._finish(session, "eos")
            return False
        if session.request.max_new_tokens == 1:
            self._finish(session, "length")
            return False
        self._tokens[slot, 0] = t
        self._active[slot] = True
        return True

    @property
    def prefix_caching(self) -> bool:
        """Whether prefix sharing is effectively on for this engine."""
        return self._prefix is not None

    # -- session migration (DESIGN.md §17) -----------------------------------

    def _require_paged(self, what: str) -> None:
        if not self.paged:
            raise RuntimeError(
                f"{what} requires the block-paged layout: the dense layout "
                "has no per-slot block addressing to extract state through")

    def export_session(self, rid: int) -> dict:
        """Lift a live admitted session out of the engine as a flat wire
        tree: paged KV blocks gathered through the slot's table rows,
        per-slot carries, position, generated tokens, chunked-prefill
        progress and timing — everything the destination needs beyond the
        :class:`Request` itself.  Pure read: the slot keeps running until
        :meth:`release_migrated`.  Every leaf shape is a function of
        (cfg, engine geometry, request) only, so the destination can derive
        the restore spec via :meth:`export_spec` without trusting the wire.
        """
        self._require_paged("export_session")
        session = self.sessions[rid]
        slot = session.slot
        if slot is None or slot not in self.pool.active:
            raise RuntimeError(
                f"request {rid} is not admitted; queued sessions migrate by "
                "resubmission, finished ones by their tokens")
        prog = self._prefilling.get(slot)
        rows = {c: jnp.asarray(t[slot]) for c, t in self._tables.items()}
        payload = self._export_program(self._state, jnp.int32(slot), rows)
        req = session.request
        toks = np.zeros((req.max_new_tokens,), np.int32)
        toks[:len(session.tokens)] = session.tokens
        meta = np.array([
            req.rid, req.max_new_tokens, len(session.tokens),
            int(self._active[slot]), int(prog is not None),
            prog.next_chunk if prog is not None else 0,
            prog.skip if prog is not None else 0,
            int(self._tokens[slot, 0]),
        ], np.int64)

        def _t(v):
            return np.nan if v is None else float(v)
        times = np.array([session.t_submit, _t(session.t_admit),
                          _t(session.t_first), _t(session.step_first),
                          _t(session.t_done)], np.float64)
        wire = {"meta": meta, "times": times, "tokens": toks,
                "prompt": np.asarray(req.prompt, np.int32),
                "state": jax.tree.map(np.asarray, payload)}
        if req.ctx is not None:
            wire["ctx"] = np.asarray(req.ctx)
        return wire

    def export_spec(self, request: Request) -> dict:
        """Shape/dtype tree of :meth:`export_session`'s wire for this
        engine's geometry — the ``like`` tree the migration checkpoint is
        restored against (shapes come from (cfg, geometry, request), never
        from the stored file)."""
        self._require_paged("export_spec")
        spec = {
            "meta": jax.ShapeDtypeStruct((8,), np.int64),
            "times": jax.ShapeDtypeStruct((5,), np.float64),
            "tokens": jax.ShapeDtypeStruct((request.max_new_tokens,),
                                           np.int32),
            "prompt": jax.ShapeDtypeStruct(request.prompt.shape, np.int32),
            "state": lm.export_slot_spec(self.cfg, self._state, self._widths),
        }
        if request.ctx is not None:
            c = np.asarray(request.ctx)
            spec["ctx"] = jax.ShapeDtypeStruct(c.shape, c.dtype)
        return spec

    def release_migrated(self, rid: int) -> None:
        """Drop a session whose state has been exported elsewhere: free the
        slot and its blocks without the finish-path side effects (no
        finish_reason, no t_done, no prefix registration — the request is
        still in flight, just not here).  Blocks this request's prefill
        already registered as a donor stay cached: their pool contents are
        untouched by release, so the index's content promise still holds."""
        self._require_paged("release_migrated")
        session = self.sessions.pop(rid)
        slot = session.slot
        self._prefilling.pop(slot, None)
        self.pool.evict(slot)
        self._active[slot] = False
        self._tokens[slot] = 0
        if self.blocks is not None:
            self.blocks.free(rid)
        for t in self._tables.values():
            t[slot, :] = 0
        self._dev_tables = None
        self.stats.migrations_out += 1

    def import_session(self, request: Request, wire: dict) -> Session:
        """Re-admit an exported session token-identically: seat it in a
        free slot, allocate fresh private blocks at this engine's table
        widths, scatter the wire payload, and rebuild host bookkeeping —
        including mid-flight chunked-prefill progress.  Shared/COW prefix
        blocks arrive by value and re-register against *this* engine's
        prefix index as the prefill advances.  Token identity needs the
        same (cfg, s_max, block_size, prefill_chunk, temperature, seed) as
        the source; geometry that differs only in slots/n_blocks is fine
        (the schedule-independent (rid, step) seed contract)."""
        self._require_paged("import_session")
        rid = request.rid
        if rid in self.sessions:
            raise ValueError(f"duplicate request id {rid}")
        meta = np.asarray(wire["meta"])
        if int(meta[0]) != rid:
            raise ValueError(
                f"wire is for request {int(meta[0])}, not {rid}")
        if not np.array_equal(np.asarray(wire["prompt"]),
                              np.asarray(request.prompt)):
            raise ValueError(f"request {rid}: wire prompt differs from the "
                             "submitted prompt")
        p_len = request.prompt.shape[0]
        if p_len + request.max_new_tokens - 1 > self.s_max:
            raise ValueError(f"request {rid} does not fit s_max={self.s_max}")
        if not self.pool.free_slots:
            raise RuntimeError("import_session: no free slot")
        per = self._blocks_per_class(p_len, request.max_new_tokens)
        if self.blocks is not None:
            if sum(per.values()) > self.blocks.reclaimable:
                raise RuntimeError("import_session: not enough free blocks")
        n_tok = int(meta[2])
        session = Session(request, t_submit=float(wire["times"][0]))
        session.tokens = [int(t) for t in np.asarray(wire["tokens"])[:n_tok]]

        def _t(v):
            return None if np.isnan(v) else float(v)
        times = np.asarray(wire["times"])
        session.t_admit = _t(times[1])
        session.t_first = _t(times[2])
        session.step_first = (None if np.isnan(times[3])
                              else int(times[3]))
        slot = self.pool.free_slots[0]
        self.pool.place(session, slot)
        self.sessions[rid] = session
        if self.blocks is not None:
            fresh = {c: self._alloc_blocks(rid, n) for c, n in per.items()}
            for c, ids in fresh.items():
                row = self._tables[c][slot]
                row[:] = 0
                row[:len(ids)] = ids
            self.stats.fresh_blocks += sum(len(v) for v in fresh.values())
            self.stats.observe_blocks(self.blocks.in_use)
        self._dev_tables = None
        rows = {c: jnp.asarray(t[slot]) for c, t in self._tables.items()}
        payload = jax.tree.map(jnp.asarray, wire["state"])
        self._state = self._import_program(self._state, jnp.int32(slot),
                                           rows, payload)
        self._tokens[slot, 0] = int(meta[7])
        self._active[slot] = bool(meta[3])
        if bool(meta[4]):           # mid-chunked-prefill: rebuild progress
            skip, next_chunk = int(meta[6]), int(meta[5])
            c = self.prefill_chunk
            n_suffix = p_len - skip
            n_chunks = -(-n_suffix // c)
            padded = np.zeros((n_chunks * c,), np.int32)
            padded[:n_suffix] = request.prompt[skip:]
            chain = ([] if self._prefix is None
                     else self._prefix.chain(request.prompt, request.ctx))
            self._prefilling[slot] = _PrefillProgress(
                session=session, padded=padded, p_len=n_suffix,
                n_chunks=n_chunks, next_chunk=next_chunk,
                ctx=self._ctx_for(request),
                seeds=jnp.asarray([self._seed_for(rid, 0)], jnp.int32),
                rows=self._slot_table_rows(slot), skip=skip, chain=chain)
        self.stats.migrations_in += 1
        return session

    def gather_block(self, bid: int):
        """Host copy of physical block ``bid`` across every shared pool —
        the scrubber's unit of verification for idle cached blocks."""
        self._require_paged("gather_block")
        out = self._gather_block_program(self._state, jnp.int32(bid))
        return jax.tree.map(np.asarray, out)

    def _prefix_plan(self, req: Request) -> tuple[list[int], int, int | None]:
        """``(shared, skip, cow_src)`` for one request: which cached blocks
        it can map read-only, how many prompt positions that skips, and the
        shared block its first write would land in (the copy-on-write
        source), if any.  Pure lookup — residency changes at admission.

        The divergence block (the registered block extending the matched
        chain, matching ``d >= 0`` further tokens) is mapped whenever at
        least one full block matched or ``d > 0`` — the uniform rule that
        makes "exactly one COW per divergence" hold at block boundaries
        too; a request that matches nothing takes the wholly-fresh path.
        ``skip`` is capped at P-1: the prefill always recomputes at least
        the last prompt position, because it must emit that logit row —
        which also means a full-prompt hit COWs the block holding position
        P-1 rather than writing a donor's block."""
        p_len = req.prompt.shape[0]
        if self._prefix is None:
            return [], 0, None
        ids, n_full, child = self._prefix.lookup(req.prompt, req.ctx)
        shared = list(ids)
        skip = n_full * self.block_size
        if child is not None and (n_full > 0 or child[1] > 0):
            shared.append(child[0])
            skip += child[1]
        skip = min(skip, p_len - 1)
        if skip <= 0:
            return [], 0, None
        w0 = skip // self.block_size
        cow = shared[w0] if w0 < len(shared) else None
        return shared, skip, cow

    def _fresh_needed(self, req: Request,
                      plan: tuple[list[int], int, int | None]) -> dict:
        """Fresh-block need per table class given a prefix plan: shared
        blocks cost nothing, the COW target costs one extra."""
        shared, _, cow = plan
        per = self._blocks_per_class(req.prompt.shape[0], req.max_new_tokens)
        if shared:
            per = dict(per)
            per[self._share_cls] -= len(shared) - (1 if cow is not None else 0)
        return per

    def _alloc_blocks(self, rid: int, n: int) -> list[int]:
        """Alloc with eviction: when the free list runs short, reclaim the
        LRU idle cached blocks and drop their index entries (the admission
        gate already checked free + idle covers the need)."""
        short = n - self.blocks.available
        if short > 0:
            for bid in self.blocks.evict_idle(short):
                self._prefix.drop_block(bid)
                self.stats.prefix_evictions += 1
            self.stats.prefix_cached_blocks = len(self._prefix)
        return self.blocks.alloc(rid, n)

    def _head_plan(self, req: Request) -> tuple[list[int], int, int | None]:
        """The FIFO head's prefix plan, memoized on (rid, index
        generation): a head blocked on blocks or slots is re-polled every
        engine step, and the plan — an O(P) chain hash plus child scans —
        only changes when the index does (revival/idling of blocks moves
        residency tiers, never index contents)."""
        if self._prefix is None:
            return self._prefix_plan(req)
        tag = (req.rid, self._prefix.generation)
        if self._plan_cache is None or self._plan_cache[0] != tag:
            self._plan_cache = (tag, self._prefix_plan(req))
        return self._plan_cache[1]

    def _admissible_paged(self) -> tuple | None:
        """The FIFO head's prefix plan when it can be admitted, else None.
        OOM backpressure gates on *fresh* blocks needed (shared blocks are
        free) against free + evictable-idle — the head waits, no skipping
        (determinism and no starvation).

        Idle blocks the plan itself shares don't count as evictable: admit
        revives them (refcount 1) before allocating, so they can't also
        cover the fresh need.  When that deficit is the only thing blocking
        the head and nothing is in flight — no active request will ever
        free another block, so waiting would deadlock — the head degrades
        to a wholly-fresh plan, which :meth:`submit`'s capacity check
        guarantees fits once the idle tier is evicted."""
        head = self.pool.peek()
        if head is None or not self.pool.free_slots:
            return None
        plan = self._head_plan(head.request)
        if self.blocks is None:
            return plan
        need = sum(self._fresh_needed(head.request, plan).values())
        revived = sum(1 for b in plan[0] if self.blocks.is_idle(b))
        if need <= self.blocks.reclaimable - revived:
            return plan
        if plan[0] and not self.pool.active:
            fresh = ([], 0, None)
            n = sum(self._fresh_needed(head.request, fresh).values())
            if n <= self.blocks.reclaimable:
                return fresh
        return None

    def _slot_table_rows(self, slot: int) -> dict:
        return {c: jnp.asarray(t[slot:slot + 1])
                for c, t in self._tables.items()}

    def _admit_paged(self) -> None:
        """Admission under the block-paged layout: map the request's shared
        prefix blocks read-only, reserve fresh blocks for the remainder
        (evicting idle cached blocks LRU-first under pressure), COW the
        divergence block if the first write would land in shared cache, and
        queue the chunked prefill of the unshared suffix.  The chunks
        themselves are dispatched by :meth:`_prefill_step` — ONE per engine
        step per admitting slot — so a long prompt interleaves with the
        decode batch in bounded ``prefill_chunk``-sized slices instead of
        blocking it head-of-line."""
        while (plan := self._admissible_paged()) is not None:
            session, slot = self.pool.admit()
            req = session.request
            session.t_admit = time.monotonic()
            p_len = req.prompt.shape[0]
            shared, skip, cow_src = plan
            if self.blocks is not None:
                if shared:
                    self.blocks.share(req.rid, shared)
                fresh = {cls_name: self._alloc_blocks(req.rid, n)
                         for cls_name, n in
                         self._fresh_needed(req, plan).items()}
                for cls_name, ids in fresh.items():
                    row = self._tables[cls_name][slot]
                    row[:] = 0
                    if cls_name == self._share_cls and shared:
                        row[:len(shared)] = shared
                        tail = ids
                        if cow_src is not None:
                            # repoint the first-write block at a private
                            # copy; the device copy below runs before any
                            # subsequently dispatched program can write it
                            row[skip // self.block_size] = ids[0]
                            tail = ids[1:]
                        row[len(shared):len(shared) + len(tail)] = tail
                    else:
                        row[:len(ids)] = ids
                self._dev_tables = None
                if cow_src is not None:
                    self._state = self._cow_program(
                        self._state, jnp.int32(cow_src),
                        jnp.int32(fresh[self._share_cls][0]))
                    self.blocks.drop(req.rid, cow_src)
                    self.stats.cow_copies += 1
                self.stats.fresh_blocks += sum(len(v) for v in fresh.values())
                self.stats.observe_blocks(self.blocks.in_use)
            self.stats.prompt_tokens += p_len
            if shared:
                self.stats.prefix_hits += 1
                self.stats.prefix_shared_blocks += len(shared)
                self.stats.prefix_tokens += skip
            c = self.prefill_chunk
            n_suffix = p_len - skip
            n_chunks = -(-n_suffix // c)
            padded = np.zeros((n_chunks * c,), np.int32)
            padded[:n_suffix] = req.prompt[skip:]
            chain = ([] if self._prefix is None
                     else self._prefix.chain(req.prompt, req.ctx))
            self._prefilling[slot] = _PrefillProgress(
                session=session, padded=padded, p_len=n_suffix,
                n_chunks=n_chunks, next_chunk=0, ctx=self._ctx_for(req),
                seeds=jnp.asarray([self._seed_for(req.rid, 0)], jnp.int32),
                rows=self._slot_table_rows(slot), skip=skip, chain=chain)
            self.stats.prefills += 1

    def _register_upto(self, prog: _PrefillProgress, slot: int,
                       n_done: int) -> None:
        """Register the prompt's first ``n_done`` full blocks (those wholly
        covered by dispatched chunks) in the prefix index.  Device programs
        execute in dispatch order, so by the time any later-admitted
        sharer's gather runs, the content the key promises is in place —
        this is what lets a request share with a *still-prefilling* donor
        (the mid-prefill divergence case).  Already-registered keys (the
        blocks this request itself shares) no-op via keep-first."""
        row = self._tables[self._share_cls][slot]
        n = min(n_done, len(prog.chain))
        while prog.registered < n:
            key, parent, toks = prog.chain[prog.registered]
            bid = int(row[prog.registered])
            if self._prefix.register(key, parent, bid, toks):
                self.blocks.set_cached(bid)
            prog.registered += 1
        self.stats.prefix_cached_blocks = len(self._prefix)

    def _prefill_step(self) -> None:
        """Advance every in-flight chunked prefill by exactly one chunk;
        a prompt that finishes joins the decode batch this same step."""
        for slot in sorted(self._prefilling):
            prog = self._prefilling[slot]
            c = self.prefill_chunk
            j = prog.next_chunk
            piece = jnp.asarray(prog.padded[None, j * c:(j + 1) * c])
            n_valid = min(c, prog.p_len - j * c)
            tok, self._state = self._chunk_program(
                self.params, piece, self._state, jnp.int32(slot),
                jnp.int32(n_valid), prog.rows, prog.ctx,
                jnp.asarray(j == 0), jnp.int32(prog.skip), self._key,
                prog.seeds)
            self.stats.prefill_chunks += 1
            prog.next_chunk += 1
            if self._prefix is not None:
                done = prog.skip + min((j + 1) * c, prog.p_len)
                self._register_upto(prog, slot, done // self.block_size)
            if prog.next_chunk == prog.n_chunks:
                del self._prefilling[slot]
                self._post_prefill(prog.session, slot, tok)

    def _admit(self) -> None:
        """Fill every free slot from the queue (prefill + scatter insert)."""
        if self.paged:
            return self._admit_paged()
        while self.pool.admissible():
            session, slot = self.pool.admit()
            req = session.request
            session.t_admit = time.monotonic()
            tokens = jnp.asarray(req.prompt[None, :], jnp.int32)
            ctx = self._ctx_for(req)
            seeds = jnp.asarray([self._seed_for(req.rid, 0)], jnp.int32)
            self._dense_prefill_lens.add(req.prompt.shape[0])
            self.stats.prefill_traces = len(self._dense_prefill_lens)
            tok, one = _prefill_program(
                self.cfg, self.params, tokens, ctx, self._key, seeds,
                s_max=self.s_max, temperature=self.temperature)
            self.stats.prefills += 1
            if self._post_prefill(session, slot, tok):
                self._state = _insert_program(self._state, one,
                                              jnp.int32(slot))

    def _device_tables(self) -> dict:
        if self._dev_tables is None:
            self._dev_tables = {c: jnp.asarray(t)
                                for c, t in self._tables.items()}
        return self._dev_tables

    def decode_roofline(self) -> dict:
        """AOT roofline audit of this engine's decode step (nothing runs).

        Re-traces the paged decode step side-effect-free on abstract avals
        (so ``decode_traces``, which pins real program compilations, is
        untouched), compiles it ahead-of-time, and returns the
        ``analysis.roofline`` dict augmented with the analytic per-step
        byte floor (``roofline_bytes``), ``achieved_bytes`` and the jaxpr
        ``dispatches`` count — which is also recorded in
        ``stats.decode_dispatches``.  The serve benchmarks render this via
        ``report.serve_decode_row``; the fused/unfused comparison is the
        same engine audited under different ``cfg.fused_decode`` settings.
        """
        if not self.paged:
            raise ValueError("decode_roofline needs the paged layout")
        from repro.roofline import analysis
        cfg, temperature = self.cfg, self.temperature

        def fn(params, tokens, state, tables, active, key, seeds):
            logits, state = lm.paged_decode_step(cfg, params, tokens, state,
                                                 tables, active=active)
            return (_sample_tokens(cfg, logits, key, seeds, temperature),
                    state)

        args = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            (self.params, jnp.asarray(self._tokens), self._state,
             self._device_tables(), jnp.asarray(self._active), self._key,
             jnp.zeros((self.slots,), jnp.int32)))
        self.stats.decode_dispatches = analysis.dispatch_count(
            jax.make_jaxpr(fn)(*args))
        r = analysis.roofline(jax.jit(fn).lower(*args).compile())
        param_bytes = sum(x.size * jnp.dtype(x.dtype).itemsize
                          for x in jax.tree_util.tree_leaves(self.params))
        kv_itemsize = (1 if cfg.kv_cache_dtype == "i8"
                       else jnp.dtype(cfg.dtype).itemsize)
        r["roofline_bytes"] = analysis.decode_roofline_bytes(
            param_bytes=param_bytes, widths=self._widths,
            layers_per_class=lm.paged_decode_layer_classes(cfg),
            slots=self.slots, block_size=self.block_size,
            n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            kv_itemsize=kv_itemsize)
        r["achieved_bytes"] = r["hlo_bytes_per_chip"]
        r["dispatches"] = self.stats.decode_dispatches
        return r

    def _decode_once(self) -> None:
        """One batched decode step; append/evict per active slot (slots
        still mid-prefill ride along inertly and are skipped here)."""
        active_sessions = {s: sess for s, sess in self.pool.active.items()
                           if s not in self._prefilling}
        seeds = np.zeros((self.slots,), np.int32)
        for slot, sess in active_sessions.items():
            seeds[slot] = self._seed_for(sess.request.rid, len(sess.tokens))
        if self.paged:
            toks, self._state = self._paged_decode_program(
                self.params, jnp.asarray(self._tokens), self._state,
                self._device_tables(), jnp.asarray(self._active), self._key,
                jnp.asarray(seeds))
            if self.blocks is not None:
                self.stats.observe_blocks(self.blocks.in_use)
        else:
            toks, self._state = _decode_program(
                self.cfg, self.params, jnp.asarray(self._tokens), self._state,
                jnp.asarray(self._active), self._key, jnp.asarray(seeds),
                temperature=self.temperature)
        self.stats.decode_steps += 1
        toks = np.asarray(toks)                     # the per-step sync point
        for slot, sess in active_sessions.items():
            t = int(toks[slot, 0])
            sess.tokens.append(t)
            self._tokens[slot, 0] = t
            if self.eos_id is not None and t == self.eos_id:
                self._finish(sess, "eos")
            elif len(sess.tokens) >= sess.request.max_new_tokens:
                self._finish(sess, "length")

    def step(self) -> bool:
        """Admit, advance in-flight prefills by one chunk each, then decode
        once; returns False when fully drained."""
        self._step_idx += 1
        self._admit()
        if self._prefilling:
            self._prefill_step()
        if any(s not in self._prefilling for s in self.pool.active):
            self._decode_once()
        return not self.pool.idle()

    def run(self) -> ServeReport:
        """Drain queue + slots; returns the per-request report."""
        t0 = time.monotonic()
        while self.step():
            pass
        return ServeReport(sessions=dict(self.sessions),
                           wall=time.monotonic() - t0,
                           decode_steps=self.stats.decode_steps,
                           prefills=self.stats.prefills,
                           stats=self.stats)
