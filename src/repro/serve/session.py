"""Request/session model for the continuous-batching serve engine.

A :class:`Request` is what a user submits: a prompt, a generation budget,
optional modality context.  A :class:`Session` is the engine's per-request
record — slot assignment, emitted tokens, timing marks — and survives the
request's whole lifecycle (queued -> admitted -> decoding -> finished).

The synthetic trace generator lives here too: serving benchmarks and the
launch entry point both replay a seeded mixed-length trace through the
engine, so throughput numbers are comparable across runs and machines.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request.

    ``max_new_tokens`` counts every emitted token including the one sampled
    from the prefill logits (matching ``serve_step.generate(n_new)``).
    """

    rid: int
    prompt: np.ndarray                 # (P,) int32 token ids
    max_new_tokens: int
    ctx: Any = None                    # (T_ctx, d) modality context, or None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(f"prompt must be a non-empty 1-D token vector, "
                             f"got shape {self.prompt.shape}")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {self.max_new_tokens}")


@dataclasses.dataclass
class Session:
    """Engine-side lifecycle record of one request."""

    request: Request
    t_submit: float
    slot: int | None = None            # resident slot while decoding
    tokens: list[int] = dataclasses.field(default_factory=list)
    finish_reason: str | None = None   # "eos" | "length"
    t_admit: float | None = None
    t_first: float | None = None       # first token emitted (end of prefill)
    t_done: float | None = None
    step_first: int | None = None      # engine step of the first token — the
                                       # schedule-depth TTFT, deterministic
                                       # where wall TTFT is machine noise

    @property
    def done(self) -> bool:
        return self.finish_reason is not None

    @property
    def latency(self) -> float:
        """Submit-to-last-token wall time (NaN while still in flight)."""
        return float("nan") if self.t_done is None else \
            self.t_done - self.t_submit

    @property
    def ttft(self) -> float:
        """Submit-to-first-token wall time (NaN before the first token)."""
        return float("nan") if self.t_first is None else \
            self.t_first - self.t_submit

    @property
    def queue_wait(self) -> float:
        """Submit-to-admission wall time (NaN while still queued) — the
        scheduling share of TTFT; the remainder is prefill compute."""
        return float("nan") if self.t_admit is None else \
            self.t_admit - self.t_submit


@dataclasses.dataclass
class TranscriptStream:
    """One streaming-transcription input: an ordered sequence of fixed-size
    encoder windows (each ``(n_ctx_tokens, d_model)`` frame embeddings).

    Windows are transcribed *incrementally*: window ``w+1``'s decode prompt
    is conditioned on the transcript emitted for windows ``0..w``, so a
    stream is a chain of dependent one-window sessions — the serve-level
    shape of streaming ASR.  Streams are independent of each other and
    interleave freely in the engine's slot pool.
    """

    sid: int
    windows: list                      # [(n_ctx_tokens, d_model) float32]

    def __post_init__(self):
        if self.sid < 0:
            raise ValueError(f"stream id must be >= 0, got {self.sid}")
        if not self.windows:
            raise ValueError(f"stream {self.sid} has no windows")


def synthetic_audio_trace(n_streams: int, n_windows: int, *,
                          n_ctx_tokens: int, d_model: int,
                          seed: int = 0) -> list[TranscriptStream]:
    """Seeded synthetic audio streams: ``n_streams`` streams of
    ``n_windows`` frame-embedding windows each.  Like :func:`synthetic_trace`
    the draws depend only on (seed, knobs) — never on any engine schedule —
    so transcription outputs are comparable across slot counts and runs."""
    rng = np.random.default_rng(seed)
    out = []
    for sid in range(n_streams):
        windows = [rng.standard_normal((n_ctx_tokens, d_model))
                   .astype(np.float32) * 0.1 for _ in range(n_windows)]
        out.append(TranscriptStream(sid=sid, windows=windows))
    return out


def synthetic_trace(n_requests: int, vocab: int, *, seed: int = 0,
                    prompt_lens: tuple = (4, 8, 12, 16),
                    new_tokens: tuple = (4, 8, 12),
                    n_ctx_tokens: int = 0, d_model: int = 0,
                    prefix_frac: float = 0.0,
                    prefix_len: int = 0) -> list[Request]:
    """Seeded mixed-length request trace.

    Prompt and budget draws are independent per request, so slots free at
    staggered times and the admission path (prefill interleaved with decode)
    is genuinely exercised.  ``n_ctx_tokens > 0`` attaches a per-request
    modality context (vlm / enc-dec archs).

    ``prefix_len > 0`` models the production regime where most prompts
    open with one shared system prompt: a ``prefix_frac`` fraction of
    requests get ``prefix_len`` common leading tokens (and, for ctx archs,
    one shared ctx object — prefix sharing is keyed per-ctx).  The shared
    material and the membership coin come from a *separate* seeded stream,
    so the per-request draws — and with them every existing trace — are
    bit-identical to the ``prefix_len=0`` trace modulo the prepended
    prefix, and the trace depends only on (seed, knobs), never on any
    engine schedule.
    """
    rng = np.random.default_rng(seed)
    shared_prefix = shared_ctx = prng = None
    if prefix_len:
        prng = np.random.default_rng([seed, 0xC1A])
        shared_prefix = prng.integers(0, vocab, size=prefix_len) \
            .astype(np.int32)
        if n_ctx_tokens:
            shared_ctx = (prng.standard_normal((n_ctx_tokens, d_model))
                          .astype(np.float32) * 0.1)
    out = []
    for rid in range(n_requests):
        p = int(rng.choice(prompt_lens))
        n = int(rng.choice(new_tokens))
        prompt = rng.integers(0, vocab, size=p).astype(np.int32)
        ctx = None
        if n_ctx_tokens:
            ctx = (rng.standard_normal((n_ctx_tokens, d_model))
                   .astype(np.float32) * 0.1)
        if prefix_len and prng.random() < prefix_frac:
            prompt = np.concatenate([shared_prefix, prompt])
            if n_ctx_tokens:
                ctx = shared_ctx
        out.append(Request(rid=rid, prompt=prompt, max_new_tokens=n, ctx=ctx))
    return out
