"""Continuous-batching decode engine (DESIGN.md §13–§14).

The paper's application regime — binary filters resident in the CiM array,
XNOR-popcount as the serve-time inner loop — needs a *request-level* engine
on top of the token-level serve path.  This module provides it:

* a FIFO request queue and a fixed pool of batch **slots** over one resident
  :class:`repro.models.lm.DecodeState` (per-slot position vector);
* a **block-paged KV cache** (default, DESIGN.md §14): attention state
  lives in a shared block pool addressed through host-owned per-slot block
  tables (:class:`BlockPool` allocates; tables are device *data*), so cache
  memory is proportional to tokens actually held, not ``slots x s_max``;
  ``paged=False`` keeps the slot-dense layout — the two are
  token-identical (MoE excepted, see §14);
* **admission**: a freed slot is immediately refilled.  Paged: the
  request's worst-case blocks are reserved (OOM backpressure holds the
  FIFO head otherwise) and the prompt is consumed by **chunked prefill** —
  fixed ``prefill_chunk``-sized pieces through ONE jitted program, so
  prefill compiles once for any prompt-length mix and long prompts
  interleave with decode in bounded slices.  Dense: exact-length batch-1
  prefill scattered into the slot (one trace per distinct length);
* **eviction** on EOS or max-token budget: the slot is marked free and its
  blocks return to the pool; dead rows are inert (position frozen via the
  active mask, table rows zeroed so frozen re-writes land in the reserved
  trash block);
* **one jitted decode program** for the whole run: position vector, active
  mask, block tables, sampling seeds are device *data*, never trace
  constants, so slots joining/leaving and blocks moving never retrace.

With ``pack=True`` (default) and a ``quant="xnor"`` arch the resident
params are the packed form (:func:`repro.models.lm.pack_params`): binary
filter planes + beta, float weights absent — packed-weight residency (runs
on both cache layouts).

Scheduling bookkeeping (:class:`SlotPool`, :class:`BlockPool`) is pure
host logic, separated from the jitted programs so it is unit-testable
without a model; :class:`EngineStats` counts steps, traces, and block-pool
occupancy (peak/mean blocks in use) for the benchmarks.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm
from repro.serve.session import Request, Session


class SlotPool:
    """Slot bookkeeping: FIFO admission into the lowest free slot.

    Pure host-side state machine (no jax) — determinism of the whole engine
    reduces to this class being deterministic, which the unit tests pin.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._free = list(range(n_slots))        # kept sorted ascending
        self._queue: collections.deque[Session] = collections.deque()
        self._active: dict[int, Session] = {}

    # -- queue side ----------------------------------------------------------

    def submit(self, session: Session) -> None:
        self._queue.append(session)

    @property
    def queued(self) -> int:
        return len(self._queue)

    def peek(self) -> Session | None:
        """The session the next admit() would pop (FIFO head), or None."""
        return self._queue[0] if self._queue else None

    # -- slot side -----------------------------------------------------------

    @property
    def free_slots(self) -> list[int]:
        return list(self._free)

    @property
    def active(self) -> dict[int, Session]:
        return dict(self._active)

    def admissible(self) -> bool:
        return bool(self._queue) and bool(self._free)

    def admit(self) -> tuple[Session, int]:
        """Pop the oldest queued session into the lowest free slot."""
        if not self._queue:
            raise RuntimeError("admit() with an empty queue")
        if not self._free:
            raise RuntimeError("admit() with no free slot")
        session = self._queue.popleft()
        slot = self._free.pop(0)
        session.slot = slot
        self._active[slot] = session
        return session, slot

    def evict(self, slot: int) -> Session:
        """Free a slot; its session leaves the active set."""
        if slot not in self._active:
            raise KeyError(f"slot {slot} is not active")
        session = self._active.pop(slot)
        self._free.append(slot)
        self._free.sort()
        return session

    def idle(self) -> bool:
        return not self._queue and not self._active


class BlockPool:
    """Host allocator for the shared paged-KV block pool (DESIGN.md §14).

    Physical block 0 is the reserved *trash* block — dead-slot and padding
    writes are routed there and never read — so ids 1..n_blocks-1 are
    allocatable.  Allocation is lowest-id-first and per-request (free by
    request id reclaims everything the request held), which keeps the whole
    engine deterministic for a fixed trace.  Pure host logic, like
    :class:`SlotPool`, so it is unit-testable without a model.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError(
                f"need at least 2 blocks (block 0 is the reserved trash "
                f"block), got {n_blocks}")
        self.n_blocks = n_blocks
        self._free = list(range(1, n_blocks))    # kept sorted ascending
        self._held: dict[int, list[int]] = {}    # rid -> block ids

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the trash block)."""
        return self.n_blocks - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, rid: int, n: int) -> list[int]:
        """n lowest free block ids, charged to request ``rid``."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: request {rid} needs {n} blocks, "
                f"{len(self._free)} free (admission must gate on available)")
        ids = self._free[:n]
        del self._free[:n]
        self._held.setdefault(rid, []).extend(ids)
        return ids

    def free(self, rid: int) -> int:
        """Return every block held by ``rid``; returns how many."""
        ids = self._held.pop(rid, [])
        self._free.extend(ids)
        self._free.sort()
        return len(ids)

    def held(self, rid: int) -> list[int]:
        return list(self._held.get(rid, []))


@dataclasses.dataclass
class EngineStats:
    """Engine-side counters, including block-pool occupancy (peak / mean
    blocks in use) so benchmarks can report memory utilization alongside
    tok/s.  ``prefill_traces`` counts the distinct prefill programs this
    engine demanded: actual compilations of the paged engine's per-engine
    chunk program (pinned to exactly 1 for any mix of prompt lengths), vs
    one per distinct prompt length on the dense path (whose module-level
    jit cache may already hold some of them from an earlier engine in the
    same process — the count is this engine's shape demand, not a process
    compile count)."""

    decode_steps: int = 0
    prefills: int = 0
    prefill_chunks: int = 0
    prefill_traces: int = 0
    decode_traces: int = 0
    blocks_total: int = 0       # allocatable blocks (0: dense layout)
    blocks_in_use: int = 0
    blocks_peak: int = 0
    _block_sum: int = 0
    _block_samples: int = 0

    def observe_blocks(self, in_use: int) -> None:
        self.blocks_in_use = in_use
        self.blocks_peak = max(self.blocks_peak, in_use)
        self._block_sum += in_use
        self._block_samples += 1

    @property
    def blocks_mean(self) -> float:
        if not self._block_samples:
            return 0.0
        return self._block_sum / self._block_samples

    @property
    def block_utilization(self) -> float:
        """Mean fraction of the pool in use (0 when dense)."""
        if not self.blocks_total:
            return 0.0
        return self.blocks_mean / self.blocks_total


# ---------------------------------------------------------------------------
# jitted programs (module level: one trace cache per (cfg, shapes))
# ---------------------------------------------------------------------------


def _sample_tokens(cfg, logits, key, seeds, temperature: float):
    """Last-position sampling, sliced to the true vocab (pad ids never
    sampled).  Per-row keys fold the host-computed (rid, step) seed into the
    engine key, so draws depend only on the request and its token index —
    never on slot assignment or batch composition (determinism under a
    fixed seed, whatever the schedule)."""
    lg = logits[:, -1, :cfg.vocab].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]

    def one(row, seed):
        g = jax.random.gumbel(jax.random.fold_in(key, seed), row.shape,
                              jnp.float32)
        return jnp.argmax(row / temperature + g, axis=-1).astype(jnp.int32)
    return jax.vmap(one)(lg, seeds)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "s_max", "temperature"))
def _prefill_program(cfg, params, tokens, ctx, key, seeds, *, s_max: int,
                     temperature: float):
    """(1, P) prompt -> (first sampled token (1, 1), DecodeState for B=1)."""
    logits, state = lm.prefill(cfg, params, tokens, ctx, s_max=s_max)
    return _sample_tokens(cfg, logits, key, seeds, temperature), state


@functools.partial(jax.jit, static_argnames=("cfg", "temperature"),
                   donate_argnames=("state",))
def _decode_program(cfg, params, tokens, state, active, key, seeds, *,
                    temperature: float):
    """One token for every slot; inactive slots' positions stay frozen."""
    logits, state = lm.decode_step(cfg, params, tokens, state, active=active)
    return _sample_tokens(cfg, logits, key, seeds, temperature), state


@functools.partial(jax.jit, donate_argnames=("resident",))
def _insert_program(resident: lm.DecodeState, one: lm.DecodeState, slot):
    """Scatter a freshly prefilled B=1 state into resident slot ``slot``.

    Segment-state leaves are layer-stacked with batch at axis 1
    ((n_layers, B, ...)); ``ctx`` and ``pos`` carry batch at axis 0.  The
    resident tree follows ``lm.decode_state_spec``: for enc-dec archs its
    ``ctx`` is None (cross-attn KV lives inside the per-layer states; the
    decode path never reads ``DecodeState.ctx``), so the prefill state's
    encoder output is dropped rather than kept resident.
    """
    seg = jax.tree.map(lambda r, o: r.at[:, slot].set(o[:, 0]),
                       resident.seg_states, one.seg_states)
    pos = resident.pos.at[slot].set(one.pos)
    ctx = (resident.ctx if resident.ctx is None
           else resident.ctx.at[slot].set(one.ctx[0]))
    return lm.DecodeState(pos, seg, ctx)


@dataclasses.dataclass
class _PrefillProgress:
    """Host bookkeeping for one slot's in-flight chunked prefill."""

    session: Session
    padded: np.ndarray          # prompt zero-padded to n_chunks * C
    p_len: int
    n_chunks: int
    next_chunk: int
    ctx: Any                    # encoded (enc-dec) / raw (vlm) ctx, or None
    seeds: Any                  # (1,) device seeds for the prefill sample
    rows: dict                  # this slot's (1, W) block-table rows


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeReport:
    """Outcome of one :meth:`ServeEngine.run`."""

    sessions: dict[int, Session]
    wall: float
    decode_steps: int
    prefills: int
    stats: EngineStats | None = None

    @property
    def generated(self) -> int:
        return sum(len(s.tokens) for s in self.sessions.values())

    @property
    def tok_per_s(self) -> float:
        return self.generated / max(self.wall, 1e-9)

    def tokens(self, rid: int) -> np.ndarray:
        return np.asarray(self.sessions[rid].tokens, np.int32)

    def _quantiles(self, values, qs) -> dict[float, float]:
        vals = [v for v in values if v == v]       # drop NaN (in-flight)
        if not vals:
            return {q: 0.0 for q in qs}
        return {q: float(np.quantile(vals, q)) for q in qs}

    def latency_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        return self._quantiles((s.latency for s in self.sessions.values()), qs)

    def ttft_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        """Submit-to-first-token, including time spent queued."""
        return self._quantiles((s.ttft for s in self.sessions.values()), qs)

    def queue_wait_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        """Submit-to-admission: the scheduling share of TTFT, separated so
        prefill cost and queueing backpressure are distinguishable."""
        return self._quantiles(
            (s.queue_wait for s in self.sessions.values()), qs)


class ServeEngine:
    """Continuous-batching serve engine over one resident decode state.

    Args:
      cfg: ArchConfig. ``quant="xnor"`` archs serve from packed weights
        unless ``pack=False``.
      params: float param tree (as from ``lm.init_params`` / ``ckpt``);
        packed at construction when applicable — the float copies of
        binarized linears are not retained by the engine.
      slots: resident batch width (concurrent requests).
      s_max: per-slot cache capacity; every request needs
        ``len(prompt) + max_new_tokens - 1 <= s_max``.
      eos_id: token id that terminates a request early (None: budget only).
      temperature: 0 = greedy (deterministic); > 0 = gumbel sampling with
        schedule-independent per-(request, step) keys.
      seed: engine sampling seed.
      pack: keep binarizable linears packed-resident (xnor archs only).
    """

    def __init__(self, cfg, params, *, slots: int, s_max: int,
                 eos_id: int | None = None, temperature: float = 0.0,
                 seed: int = 0, pack: bool = True, paged: bool = True,
                 block_size: int = 0, prefill_chunk: int = 0,
                 n_blocks: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.s_max = s_max
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self.params = lm.pack_params(cfg, params) if pack else params
        self.pool = SlotPool(slots)
        self.sessions: dict[int, Session] = {}
        self._key = jax.random.PRNGKey(seed)
        self.paged = bool(paged)
        self.stats = EngineStats()
        if self.paged:
            self.block_size = block_size or cfg.block_size
            self.prefill_chunk = prefill_chunk or cfg.prefill_chunk
            self._widths = lm.paged_table_widths(cfg, s_max, self.block_size,
                                                 self.prefill_chunk)
            per_slot_worst = sum(self._widths.values())
            if n_blocks <= 0:
                # default: enough for every slot at full table width (the
                # paged layout is then never *smaller* than dense; callers
                # shrink n_blocks to oversubscribe slots at equal memory)
                n_blocks = 1 + slots * max(per_slot_worst, 1)
            self.n_blocks = n_blocks
            self.blocks = BlockPool(n_blocks) if self._widths else None
            self.stats.blocks_total = n_blocks - 1 if self.blocks else 0
            # host-owned block tables, mirrored to device on change
            self._tables = {c: np.zeros((slots, w), np.int32)
                            for c, w in self._widths.items()}
            self._dev_tables = None
            self._state = lm.paged_decode_state_spec(
                cfg, slots, s_max, n_blocks=n_blocks,
                block_size=self.block_size, abstract=False)
            self._build_paged_programs()
        else:
            # the single source of truth for the resident layout is
            # lm.decode_state_spec (the same tree the dry-run lowers)
            self._state = lm.decode_state_spec(cfg, slots, s_max,
                                               abstract=False,
                                               per_slot_pos=True)
            self._dense_prefill_lens: set[int] = set()
        # host-side mirrors of the device batch (tiny, moved every step)
        self._tokens = np.zeros((slots, 1), np.int32)
        self._active = np.zeros((slots,), bool)
        # slots mid-chunked-prefill: slot -> _PrefillProgress (paged only;
        # dense prefill is a single exact-length program, nothing to slice)
        self._prefilling: dict[int, _PrefillProgress] = {}

    def _build_paged_programs(self):
        """Per-engine jits so trace counts are observable: the python side
        effect on ``stats`` runs at trace time only, so ``prefill_traces``
        counts compilations — the chunked-prefill contract pins it to 1."""
        cfg, temperature = self.cfg, self.temperature

        def chunk_fn(params, tokens, state, slot, n_valid, tables, ctx,
                     fresh, key, seeds):
            self.stats.prefill_traces += 1
            logits, state = lm.prefill_chunk_step(cfg, params, tokens, state,
                                                  slot, n_valid, tables, ctx,
                                                  fresh=fresh)
            return (_sample_tokens(cfg, logits, key, seeds, temperature),
                    state)

        def decode_fn(params, tokens, state, tables, active, key, seeds):
            self.stats.decode_traces += 1
            logits, state = lm.paged_decode_step(cfg, params, tokens, state,
                                                 tables, active=active)
            return (_sample_tokens(cfg, logits, key, seeds, temperature),
                    state)

        self._chunk_program = jax.jit(chunk_fn, donate_argnums=(2,))
        self._paged_decode_program = jax.jit(decode_fn, donate_argnums=(2,))
        self._encode_program = None
        if cfg.is_encdec():
            self._encode_program = jax.jit(
                lambda params, frames: lm.encode(cfg, params, frames))

    def _blocks_per_class(self, prompt_len: int,
                          max_new_tokens: int) -> dict[str, int]:
        """Worst-case block reservation per table class for one request:
        positions 0..P+G-2 are cached, window classes cap at their ring
        width.  Single source for both the admission gate and the actual
        allocation — they must never drift apart."""
        nb = -(-(prompt_len + max_new_tokens - 1) // self.block_size)
        return {c: min(nb, w) for c, w in self._widths.items()}

    def _blocks_needed(self, prompt_len: int, max_new_tokens: int) -> int:
        return sum(self._blocks_per_class(prompt_len,
                                          max_new_tokens).values())

    # -- request lifecycle ---------------------------------------------------

    def submit(self, request: Request) -> Session:
        if request.rid in self.sessions:
            raise ValueError(f"duplicate request id {request.rid}")
        need = request.prompt.shape[0] + request.max_new_tokens - 1
        if need > self.s_max:
            raise ValueError(
                f"request {request.rid} needs {need} cache positions, "
                f"engine capacity is s_max={self.s_max}")
        if self.paged and self.blocks is not None:
            nb = self._blocks_needed(request.prompt.shape[0],
                                     request.max_new_tokens)
            if nb > self.blocks.capacity:
                raise ValueError(
                    f"request {request.rid} needs {nb} blocks, pool "
                    f"capacity is {self.blocks.capacity} "
                    f"(n_blocks={self.n_blocks} incl. trash block)")
        session = Session(request, t_submit=time.monotonic())
        self.sessions[request.rid] = session
        self.pool.submit(session)
        return session

    def _seed_for(self, rid: int, step: int) -> int:
        return (rid * 1_000_003 + step) % (2**31 - 1)

    def _finish(self, session: Session, reason: str) -> None:
        session.finish_reason = reason
        session.t_done = time.monotonic()
        if session.slot is not None and session.slot in self.pool.active:
            slot = session.slot
            self.pool.evict(slot)
            self._active[slot] = False
            self._tokens[slot] = 0   # dead slots feed a constant token id
                                     # (keeps MoE capacity competition quiet)
            if self.paged:
                # eviction returns every block the request held; the zeroed
                # table row routes the dead slot's frozen re-writes to the
                # trash block so reallocated blocks are never corrupted
                if self.blocks is not None:
                    self.blocks.free(session.request.rid)
                for t in self._tables.values():
                    t[slot, :] = 0
                self._dev_tables = None

    def _ctx_for(self, req: Request):
        if req.ctx is not None:
            ctx = jnp.asarray(np.asarray(req.ctx)[None])
            if self.paged and self.cfg.is_encdec():
                # encode once at admission; chunks consume the frames
                ctx = self._encode_program(self.params, ctx)
            return ctx
        if self.cfg.n_ctx_tokens:
            raise ValueError(
                f"arch {self.cfg.name} needs per-request ctx; request "
                f"{req.rid} has none")
        return None

    def _post_prefill(self, session: Session, slot: int, tok) -> bool:
        """Record the prefill-sampled token; returns True when the request
        survives into the decode batch."""
        t = int(np.asarray(tok)[0, 0])
        session.tokens.append(t)
        session.t_first = time.monotonic()
        if self.eos_id is not None and t == self.eos_id:
            self._finish(session, "eos")
            return False
        if session.request.max_new_tokens == 1:
            self._finish(session, "length")
            return False
        self._tokens[slot, 0] = t
        self._active[slot] = True
        return True

    def _admissible_paged(self) -> bool:
        head = self.pool.peek()
        if head is None or not self.pool.free_slots:
            return False
        if self.blocks is None:
            return True
        # OOM backpressure: the FIFO head waits (no skipping — determinism
        # and no starvation) until eviction returns enough blocks
        return self.blocks.available >= self._blocks_needed(
            head.request.prompt.shape[0], head.request.max_new_tokens)

    def _slot_table_rows(self, slot: int) -> dict:
        return {c: jnp.asarray(t[slot:slot + 1])
                for c, t in self._tables.items()}

    def _admit_paged(self) -> None:
        """Admission under the block-paged layout: reserve the request's
        worst-case blocks and queue its chunked prefill.  The chunks
        themselves are dispatched by :meth:`_prefill_step` — ONE per engine
        step per admitting slot — so a long prompt interleaves with the
        decode batch in bounded ``prefill_chunk``-sized slices instead of
        blocking it head-of-line."""
        while self._admissible_paged():
            session, slot = self.pool.admit()
            req = session.request
            session.t_admit = time.monotonic()
            p_len = req.prompt.shape[0]
            if self.blocks is not None:
                for cls_name, need in self._blocks_per_class(
                        p_len, req.max_new_tokens).items():
                    ids = self.blocks.alloc(req.rid, need)
                    row = self._tables[cls_name][slot]
                    row[:] = 0
                    row[:len(ids)] = ids
                self._dev_tables = None
                self.stats.observe_blocks(self.blocks.in_use)
            c = self.prefill_chunk
            n_chunks = -(-p_len // c)
            padded = np.zeros((n_chunks * c,), np.int32)
            padded[:p_len] = req.prompt
            self._prefilling[slot] = _PrefillProgress(
                session=session, padded=padded, p_len=p_len,
                n_chunks=n_chunks, next_chunk=0, ctx=self._ctx_for(req),
                seeds=jnp.asarray([self._seed_for(req.rid, 0)], jnp.int32),
                rows=self._slot_table_rows(slot))
            self.stats.prefills += 1

    def _prefill_step(self) -> None:
        """Advance every in-flight chunked prefill by exactly one chunk;
        a prompt that finishes joins the decode batch this same step."""
        for slot in sorted(self._prefilling):
            prog = self._prefilling[slot]
            c = self.prefill_chunk
            j = prog.next_chunk
            piece = jnp.asarray(prog.padded[None, j * c:(j + 1) * c])
            n_valid = min(c, prog.p_len - j * c)
            tok, self._state = self._chunk_program(
                self.params, piece, self._state, jnp.int32(slot),
                jnp.int32(n_valid), prog.rows, prog.ctx,
                jnp.asarray(j == 0), self._key, prog.seeds)
            self.stats.prefill_chunks += 1
            prog.next_chunk += 1
            if prog.next_chunk == prog.n_chunks:
                del self._prefilling[slot]
                self._post_prefill(prog.session, slot, tok)

    def _admit(self) -> None:
        """Fill every free slot from the queue (prefill + scatter insert)."""
        if self.paged:
            return self._admit_paged()
        while self.pool.admissible():
            session, slot = self.pool.admit()
            req = session.request
            session.t_admit = time.monotonic()
            tokens = jnp.asarray(req.prompt[None, :], jnp.int32)
            ctx = self._ctx_for(req)
            seeds = jnp.asarray([self._seed_for(req.rid, 0)], jnp.int32)
            self._dense_prefill_lens.add(req.prompt.shape[0])
            self.stats.prefill_traces = len(self._dense_prefill_lens)
            tok, one = _prefill_program(
                self.cfg, self.params, tokens, ctx, self._key, seeds,
                s_max=self.s_max, temperature=self.temperature)
            self.stats.prefills += 1
            if self._post_prefill(session, slot, tok):
                self._state = _insert_program(self._state, one,
                                              jnp.int32(slot))

    def _device_tables(self) -> dict:
        if self._dev_tables is None:
            self._dev_tables = {c: jnp.asarray(t)
                                for c, t in self._tables.items()}
        return self._dev_tables

    def _decode_once(self) -> None:
        """One batched decode step; append/evict per active slot (slots
        still mid-prefill ride along inertly and are skipped here)."""
        active_sessions = {s: sess for s, sess in self.pool.active.items()
                           if s not in self._prefilling}
        seeds = np.zeros((self.slots,), np.int32)
        for slot, sess in active_sessions.items():
            seeds[slot] = self._seed_for(sess.request.rid, len(sess.tokens))
        if self.paged:
            toks, self._state = self._paged_decode_program(
                self.params, jnp.asarray(self._tokens), self._state,
                self._device_tables(), jnp.asarray(self._active), self._key,
                jnp.asarray(seeds))
            if self.blocks is not None:
                self.stats.observe_blocks(self.blocks.in_use)
        else:
            toks, self._state = _decode_program(
                self.cfg, self.params, jnp.asarray(self._tokens), self._state,
                jnp.asarray(self._active), self._key, jnp.asarray(seeds),
                temperature=self.temperature)
        self.stats.decode_steps += 1
        toks = np.asarray(toks)                     # the per-step sync point
        for slot, sess in active_sessions.items():
            t = int(toks[slot, 0])
            sess.tokens.append(t)
            self._tokens[slot, 0] = t
            if self.eos_id is not None and t == self.eos_id:
                self._finish(sess, "eos")
            elif len(sess.tokens) >= sess.request.max_new_tokens:
                self._finish(sess, "length")

    def step(self) -> bool:
        """Admit, advance in-flight prefills by one chunk each, then decode
        once; returns False when fully drained."""
        self._admit()
        if self._prefilling:
            self._prefill_step()
        if any(s not in self._prefilling for s in self.pool.active):
            self._decode_once()
        return not self.pool.idle()

    def run(self) -> ServeReport:
        """Drain queue + slots; returns the per-request report."""
        t0 = time.monotonic()
        while self.step():
            pass
        return ServeReport(sessions=dict(self.sessions),
                           wall=time.monotonic() - t0,
                           decode_steps=self.stats.decode_steps,
                           prefills=self.stats.prefills,
                           stats=self.stats)
