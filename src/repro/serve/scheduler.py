"""Continuous-batching decode engine (DESIGN.md §13).

The paper's application regime — binary filters resident in the CiM array,
XNOR-popcount as the serve-time inner loop — needs a *request-level* engine
on top of the token-level serve path.  This module provides it:

* a FIFO request queue and a fixed pool of batch **slots** over one resident
  :class:`repro.models.lm.DecodeState` (per-slot position vector);
* **admission**: a freed slot is immediately refilled — the new request is
  prefilled (exact prompt length, batch 1) and its per-layer state scattered
  into the resident batch, interleaved with decode;
* **eviction** on EOS or max-token budget: the slot is marked free, its
  device state left in place (dead rows are inert: position frozen via the
  active mask, overwritten by the next admission);
* **one jitted decode program** for the whole run: position vector, active
  mask, sampling seeds are device *data*, never trace constants, so slots
  joining/leaving never retrace.  Prefill traces once per distinct prompt
  length (exact lengths — right-padding would corrupt recurrent-arch state).

With ``pack=True`` (default) and a ``quant="xnor"`` arch the resident
params are the packed form (:func:`repro.models.lm.pack_params`): binary
filter planes + beta, float weights absent — packed-weight residency.

Scheduling bookkeeping (:class:`SlotPool`) is pure host logic, separated
from the jitted programs so it is unit-testable without a model.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm
from repro.serve.session import Request, Session


class SlotPool:
    """Slot bookkeeping: FIFO admission into the lowest free slot.

    Pure host-side state machine (no jax) — determinism of the whole engine
    reduces to this class being deterministic, which the unit tests pin.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._free = list(range(n_slots))        # kept sorted ascending
        self._queue: collections.deque[Session] = collections.deque()
        self._active: dict[int, Session] = {}

    # -- queue side ----------------------------------------------------------

    def submit(self, session: Session) -> None:
        self._queue.append(session)

    @property
    def queued(self) -> int:
        return len(self._queue)

    # -- slot side -----------------------------------------------------------

    @property
    def free_slots(self) -> list[int]:
        return list(self._free)

    @property
    def active(self) -> dict[int, Session]:
        return dict(self._active)

    def admissible(self) -> bool:
        return bool(self._queue) and bool(self._free)

    def admit(self) -> tuple[Session, int]:
        """Pop the oldest queued session into the lowest free slot."""
        if not self._queue:
            raise RuntimeError("admit() with an empty queue")
        if not self._free:
            raise RuntimeError("admit() with no free slot")
        session = self._queue.popleft()
        slot = self._free.pop(0)
        session.slot = slot
        self._active[slot] = session
        return session, slot

    def evict(self, slot: int) -> Session:
        """Free a slot; its session leaves the active set."""
        if slot not in self._active:
            raise KeyError(f"slot {slot} is not active")
        session = self._active.pop(slot)
        self._free.append(slot)
        self._free.sort()
        return session

    def idle(self) -> bool:
        return not self._queue and not self._active


# ---------------------------------------------------------------------------
# jitted programs (module level: one trace cache per (cfg, shapes))
# ---------------------------------------------------------------------------


def _sample_tokens(cfg, logits, key, seeds, temperature: float):
    """Last-position sampling, sliced to the true vocab (pad ids never
    sampled).  Per-row keys fold the host-computed (rid, step) seed into the
    engine key, so draws depend only on the request and its token index —
    never on slot assignment or batch composition (determinism under a
    fixed seed, whatever the schedule)."""
    lg = logits[:, -1, :cfg.vocab].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]

    def one(row, seed):
        g = jax.random.gumbel(jax.random.fold_in(key, seed), row.shape,
                              jnp.float32)
        return jnp.argmax(row / temperature + g, axis=-1).astype(jnp.int32)
    return jax.vmap(one)(lg, seeds)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "s_max", "temperature"))
def _prefill_program(cfg, params, tokens, ctx, key, seeds, *, s_max: int,
                     temperature: float):
    """(1, P) prompt -> (first sampled token (1, 1), DecodeState for B=1)."""
    logits, state = lm.prefill(cfg, params, tokens, ctx, s_max=s_max)
    return _sample_tokens(cfg, logits, key, seeds, temperature), state


@functools.partial(jax.jit, static_argnames=("cfg", "temperature"),
                   donate_argnames=("state",))
def _decode_program(cfg, params, tokens, state, active, key, seeds, *,
                    temperature: float):
    """One token for every slot; inactive slots' positions stay frozen."""
    logits, state = lm.decode_step(cfg, params, tokens, state, active=active)
    return _sample_tokens(cfg, logits, key, seeds, temperature), state


@functools.partial(jax.jit, donate_argnames=("resident",))
def _insert_program(resident: lm.DecodeState, one: lm.DecodeState, slot):
    """Scatter a freshly prefilled B=1 state into resident slot ``slot``.

    Segment-state leaves are layer-stacked with batch at axis 1
    ((n_layers, B, ...)); ``ctx`` and ``pos`` carry batch at axis 0.  The
    resident tree follows ``lm.decode_state_spec``: for enc-dec archs its
    ``ctx`` is None (cross-attn KV lives inside the per-layer states; the
    decode path never reads ``DecodeState.ctx``), so the prefill state's
    encoder output is dropped rather than kept resident.
    """
    seg = jax.tree.map(lambda r, o: r.at[:, slot].set(o[:, 0]),
                       resident.seg_states, one.seg_states)
    pos = resident.pos.at[slot].set(one.pos)
    ctx = (resident.ctx if resident.ctx is None
           else resident.ctx.at[slot].set(one.ctx[0]))
    return lm.DecodeState(pos, seg, ctx)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeReport:
    """Outcome of one :meth:`ServeEngine.run`."""

    sessions: dict[int, Session]
    wall: float
    decode_steps: int
    prefills: int

    @property
    def generated(self) -> int:
        return sum(len(s.tokens) for s in self.sessions.values())

    @property
    def tok_per_s(self) -> float:
        return self.generated / max(self.wall, 1e-9)

    def tokens(self, rid: int) -> np.ndarray:
        return np.asarray(self.sessions[rid].tokens, np.int32)

    def latency_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        lats = sorted(s.latency for s in self.sessions.values())
        if not lats:
            return {q: 0.0 for q in qs}
        return {q: float(np.quantile(lats, q)) for q in qs}


class ServeEngine:
    """Continuous-batching serve engine over one resident decode state.

    Args:
      cfg: ArchConfig. ``quant="xnor"`` archs serve from packed weights
        unless ``pack=False``.
      params: float param tree (as from ``lm.init_params`` / ``ckpt``);
        packed at construction when applicable — the float copies of
        binarized linears are not retained by the engine.
      slots: resident batch width (concurrent requests).
      s_max: per-slot cache capacity; every request needs
        ``len(prompt) + max_new_tokens - 1 <= s_max``.
      eos_id: token id that terminates a request early (None: budget only).
      temperature: 0 = greedy (deterministic); > 0 = gumbel sampling with
        schedule-independent per-(request, step) keys.
      seed: engine sampling seed.
      pack: keep binarizable linears packed-resident (xnor archs only).
    """

    def __init__(self, cfg, params, *, slots: int, s_max: int,
                 eos_id: int | None = None, temperature: float = 0.0,
                 seed: int = 0, pack: bool = True):
        self.cfg = cfg
        self.slots = slots
        self.s_max = s_max
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self.params = lm.pack_params(cfg, params) if pack else params
        self.pool = SlotPool(slots)
        self.sessions: dict[int, Session] = {}
        self._key = jax.random.PRNGKey(seed)
        # the single source of truth for the resident layout is
        # lm.decode_state_spec (the same tree the dry-run lowers)
        self._state = lm.decode_state_spec(cfg, slots, s_max, abstract=False,
                                           per_slot_pos=True)
        # host-side mirrors of the device batch (tiny, moved every step)
        self._tokens = np.zeros((slots, 1), np.int32)
        self._active = np.zeros((slots,), bool)
        self._decode_steps = 0
        self._prefills = 0

    # -- request lifecycle ---------------------------------------------------

    def submit(self, request: Request) -> Session:
        if request.rid in self.sessions:
            raise ValueError(f"duplicate request id {request.rid}")
        need = request.prompt.shape[0] + request.max_new_tokens - 1
        if need > self.s_max:
            raise ValueError(
                f"request {request.rid} needs {need} cache positions, "
                f"engine capacity is s_max={self.s_max}")
        session = Session(request, t_submit=time.monotonic())
        self.sessions[request.rid] = session
        self.pool.submit(session)
        return session

    def _seed_for(self, rid: int, step: int) -> int:
        return (rid * 1_000_003 + step) % (2**31 - 1)

    def _finish(self, session: Session, reason: str) -> None:
        session.finish_reason = reason
        session.t_done = time.monotonic()
        if session.slot is not None and session.slot in self.pool.active:
            slot = session.slot
            self.pool.evict(slot)
            self._active[slot] = False
            self._tokens[slot] = 0   # dead slots feed a constant token id
                                     # (keeps MoE capacity competition quiet)

    def _admit(self) -> None:
        """Fill every free slot from the queue (prefill + scatter insert)."""
        while self.pool.admissible():
            session, slot = self.pool.admit()
            req = session.request
            session.t_admit = time.monotonic()
            tokens = jnp.asarray(req.prompt[None, :], jnp.int32)
            ctx = None
            if req.ctx is not None:
                ctx = jnp.asarray(np.asarray(req.ctx)[None])
            elif self.cfg.n_ctx_tokens:
                raise ValueError(
                    f"arch {self.cfg.name} needs per-request ctx; request "
                    f"{req.rid} has none")
            seeds = jnp.asarray([self._seed_for(req.rid, 0)], jnp.int32)
            tok, one = _prefill_program(
                self.cfg, self.params, tokens, ctx, self._key, seeds,
                s_max=self.s_max, temperature=self.temperature)
            self._prefills += 1
            t = int(np.asarray(tok)[0, 0])
            session.tokens.append(t)
            session.t_first = time.monotonic()
            if (self.eos_id is not None and t == self.eos_id):
                self._finish(session, "eos")
                continue
            if req.max_new_tokens == 1:
                self._finish(session, "length")
                continue
            self._state = _insert_program(self._state, one, jnp.int32(slot))
            self._tokens[slot, 0] = t
            self._active[slot] = True

    def _decode_once(self) -> None:
        """One batched decode step; append/evict per active slot."""
        active_sessions = self.pool.active          # slot -> session
        seeds = np.zeros((self.slots,), np.int32)
        for slot, sess in active_sessions.items():
            seeds[slot] = self._seed_for(sess.request.rid, len(sess.tokens))
        toks, self._state = _decode_program(
            self.cfg, self.params, jnp.asarray(self._tokens), self._state,
            jnp.asarray(self._active), self._key, jnp.asarray(seeds),
            temperature=self.temperature)
        self._decode_steps += 1
        toks = np.asarray(toks)                     # the per-step sync point
        for slot, sess in active_sessions.items():
            t = int(toks[slot, 0])
            sess.tokens.append(t)
            self._tokens[slot, 0] = t
            if self.eos_id is not None and t == self.eos_id:
                self._finish(sess, "eos")
            elif len(sess.tokens) >= sess.request.max_new_tokens:
                self._finish(sess, "length")

    def step(self) -> bool:
        """Admit then decode once; returns False when fully drained."""
        self._admit()
        if self.pool.active:
            self._decode_once()
        return not self.pool.idle()

    def run(self) -> ServeReport:
        """Drain queue + slots; returns the per-request report."""
        t0 = time.monotonic()
        while self.step():
            pass
        return ServeReport(sessions=dict(self.sessions),
                           wall=time.monotonic() - t0,
                           decode_steps=self._decode_steps,
                           prefills=self._prefills)
