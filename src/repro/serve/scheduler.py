"""Continuous-batching decode engine (DESIGN.md §13–§14).

The paper's application regime — binary filters resident in the CiM array,
XNOR-popcount as the serve-time inner loop — needs a *request-level* engine
on top of the token-level serve path.  This module provides it:

* a FIFO request queue and a fixed pool of batch **slots** over one resident
  :class:`repro.models.lm.DecodeState` (per-slot position vector);
* a **block-paged KV cache** (default, DESIGN.md §14): attention state
  lives in a shared block pool addressed through host-owned per-slot block
  tables (:class:`BlockPool` allocates; tables are device *data*), so cache
  memory is proportional to tokens actually held, not ``slots x s_max``;
  ``paged=False`` keeps the slot-dense layout — the two are
  token-identical (MoE excepted, see §14);
* **admission**: a freed slot is immediately refilled.  Paged: the
  request's worst-case blocks are reserved (OOM backpressure holds the
  FIFO head otherwise) and the prompt is consumed by **chunked prefill** —
  fixed ``prefill_chunk``-sized pieces through ONE jitted program, so
  prefill compiles once for any prompt-length mix and long prompts
  interleave with decode in bounded slices.  Dense: exact-length batch-1
  prefill scattered into the slot (one trace per distinct length);
* **eviction** on EOS or max-token budget: the slot is marked free and its
  blocks return to the pool; dead rows are inert (position frozen via the
  active mask, table rows zeroed so frozen re-writes land in the reserved
  trash block);
* **one jitted decode program** for the whole run: position vector, active
  mask, block tables, sampling seeds are device *data*, never trace
  constants, so slots joining/leaving and blocks moving never retrace.

With ``pack=True`` (default) and a ``quant="xnor"`` arch the resident
params are the packed form (:func:`repro.models.lm.pack_params`): binary
filter planes + beta, float weights absent — packed-weight residency (runs
on both cache layouts).

Scheduling bookkeeping (:class:`SlotPool`, :class:`BlockPool`) is pure
host logic, separated from the jitted programs so it is unit-testable
without a model; :class:`EngineStats` counts steps, traces, and block-pool
occupancy (peak/mean blocks in use) for the benchmarks.
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
import functools
import hashlib
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm
from repro.serve.session import Request, Session


class SlotPool:
    """Slot bookkeeping: FIFO admission into the lowest free slot.

    Pure host-side state machine (no jax) — determinism of the whole engine
    reduces to this class being deterministic, which the unit tests pin.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._free = list(range(n_slots))        # kept sorted ascending
        self._queue: collections.deque[Session] = collections.deque()
        self._active: dict[int, Session] = {}

    # -- queue side ----------------------------------------------------------

    def submit(self, session: Session) -> None:
        self._queue.append(session)

    @property
    def queued(self) -> int:
        return len(self._queue)

    def peek(self) -> Session | None:
        """The session the next admit() would pop (FIFO head), or None."""
        return self._queue[0] if self._queue else None

    # -- slot side -----------------------------------------------------------

    @property
    def free_slots(self) -> list[int]:
        return list(self._free)

    @property
    def active(self) -> dict[int, Session]:
        return dict(self._active)

    def admissible(self) -> bool:
        return bool(self._queue) and bool(self._free)

    def admit(self) -> tuple[Session, int]:
        """Pop the oldest queued session into the lowest free slot."""
        if not self._queue:
            raise RuntimeError("admit() with an empty queue")
        if not self._free:
            raise RuntimeError("admit() with no free slot")
        session = self._queue.popleft()
        slot = self._free.pop(0)
        session.slot = slot
        self._active[slot] = session
        return session, slot

    def evict(self, slot: int) -> Session:
        """Free a slot; its session leaves the active set."""
        if slot not in self._active:
            raise KeyError(f"slot {slot} is not active")
        session = self._active.pop(slot)
        self._free.append(slot)
        self._free.sort()
        return session

    def idle(self) -> bool:
        return not self._queue and not self._active


class BlockPool:
    """Host allocator for the shared paged-KV block pool (DESIGN.md §14/§15).

    Physical block 0 is the reserved *trash* block — dead-slot and padding
    writes are routed there and never read — so ids 1..n_blocks-1 are
    allocatable.  Allocation is lowest-id-first and per-request (free by
    request id reclaims everything the request held), which keeps the whole
    engine deterministic for a fixed trace.  Pure host logic, like
    :class:`SlotPool`, so it is unit-testable without a model.

    Prefix sharing (§15) adds per-block refcounts: a block may be *held*
    by several requests at once (:meth:`share` maps an existing block into
    another request read-only; a block is writable only while exactly one
    request holds it and it is not cached) and may be marked *cached*
    (registered in a :class:`PrefixIndex`).  A cached block whose refcount
    drops to zero is not freed but parked in an *idle* tier — content kept
    resident, revived by a later :meth:`share`, reclaimed least-recently-
    idle-first by :meth:`evict_idle` under pool pressure.  Uncached blocks
    go straight back to the free list, exactly the pre-§15 behavior.  LRU
    order uses a logical clock, never wall time, so eviction (and with it
    the whole engine) stays deterministic for a fixed trace.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError(
                f"need at least 2 blocks (block 0 is the reserved trash "
                f"block), got {n_blocks}")
        self.n_blocks = n_blocks
        self._free = list(range(1, n_blocks))    # kept sorted ascending
        self._held: dict[int, list[int]] = {}    # rid -> block ids
        self._ref: dict[int, int] = {}           # bid -> holders (>= 1)
        self._cached: set[int] = set()           # registered in a PrefixIndex
        self._idle: dict[int, int] = {}          # cached, ref 0: bid -> stamp
        self._clock = 0                          # deterministic LRU time

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the trash block)."""
        return self.n_blocks - 1

    @property
    def available(self) -> int:
        """Immediately allocatable (free list only — idle cached blocks
        need :meth:`evict_idle` first)."""
        return len(self._free)

    @property
    def idle(self) -> int:
        """Cached blocks with no holder (evictable, content resident)."""
        return len(self._idle)

    @property
    def reclaimable(self) -> int:
        """free + idle: the upper bound an admission gate may count on.
        Idle blocks a plan itself will :meth:`share` must be excluded by
        the caller — revival precedes the fresh allocation, so they
        cannot also be evicted to cover it."""
        return len(self._free) + len(self._idle)

    @property
    def in_use(self) -> int:
        """Blocks held by at least one request (idle cached blocks are
        resident but not in use)."""
        return self.capacity - len(self._free) - len(self._idle)

    @property
    def free_blocks(self) -> list[int]:
        return list(self._free)

    @property
    def idle_blocks(self) -> list[int]:
        """Idle cached blocks, eviction (LRU) order."""
        return sorted(self._idle, key=self._idle.__getitem__)

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def cached(self, bid: int) -> bool:
        return bid in self._cached

    def is_idle(self, bid: int) -> bool:
        """True when ``bid`` sits in the idle tier (cached, no holder) —
        evictable now, but not after a :meth:`share` revives it."""
        return bid in self._idle

    def alloc(self, rid: int, n: int) -> list[int]:
        """n lowest free block ids, charged to request ``rid``."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: request {rid} needs {n} blocks, "
                f"{len(self._free)} free (admission must gate on available, "
                f"evicting idle cached blocks first)")
        ids = self._free[:n]
        del self._free[:n]
        self._held.setdefault(rid, []).extend(ids)
        for bid in ids:
            self._ref[bid] = 1
        return ids

    def share(self, rid: int, ids: list[int]) -> None:
        """Map existing blocks into ``rid`` read-only (refcount + 1 each).

        Sharing an idle cached block revives it: it leaves the eviction
        tier with its contents intact.  Sharing a free block (or the trash
        block, or a block ``rid`` already holds) is a caller bug."""
        held = self._held.setdefault(rid, [])
        for bid in ids:
            if bid <= 0 or bid >= self.n_blocks:
                raise ValueError(f"share({bid}): not an allocatable block id")
            if bid in held:
                raise RuntimeError(
                    f"share({bid}): request {rid} already holds it")
            if bid in self._idle:
                del self._idle[bid]
                self._ref[bid] = 1
            elif self._ref.get(bid, 0) > 0:
                self._ref[bid] += 1
            else:
                raise RuntimeError(f"share({bid}): block is free")
            held.append(bid)

    def _release(self, bid: int) -> None:
        r = self._ref[bid] - 1
        if r > 0:
            self._ref[bid] = r
            return
        del self._ref[bid]
        if bid in self._cached:
            self._clock += 1
            self._idle[bid] = self._clock
        else:
            bisect.insort(self._free, bid)

    def free(self, rid: int) -> int:
        """Drop every hold ``rid`` has; returns how many.  Blocks whose
        refcount hits zero return to the free list, except cached ones,
        which park in the idle tier."""
        ids = self._held.pop(rid, [])
        for bid in ids:
            self._release(bid)
        return len(ids)

    def drop(self, rid: int, bid: int) -> None:
        """Release ``rid``'s hold on one block — the copy-on-write path:
        after duplicating a shared divergence block into a private one the
        request lets go of the original."""
        held = self._held.get(rid)
        if held is None or bid not in held:
            raise KeyError(f"drop({bid}): not held by request {rid}")
        held.remove(bid)
        if not held:
            del self._held[rid]
        self._release(bid)

    def set_cached(self, bid: int) -> None:
        """Mark a held block as index-registered: its last release parks
        it in the idle tier instead of freeing it."""
        if self._ref.get(bid, 0) < 1:
            raise RuntimeError(f"set_cached({bid}): block is not held")
        self._cached.add(bid)

    def evict_idle(self, n: int) -> list[int]:
        """Reclaim the ``n`` least-recently-idled cached blocks back to
        the free list; the caller must drop their index entries.  Held
        (refcount > 0) blocks are never evicted."""
        if n > len(self._idle):
            raise RuntimeError(
                f"evict_idle({n}): only {len(self._idle)} blocks idle")
        victims = sorted(self._idle, key=self._idle.__getitem__)[:n]
        for bid in victims:
            del self._idle[bid]
            self._cached.discard(bid)
            bisect.insort(self._free, bid)
        return victims

    def held(self, rid: int) -> list[int]:
        return list(self._held.get(rid, []))


class PrefixIndex:
    """Content-addressed index over cached prefix blocks (DESIGN.md §15):
    hash-of-block-contents -> physical block id, for *full* blocks only
    (partial blocks are still being written, so their contents are not
    stable).  Keys are chain hashes — a block's key folds its parent's
    key, so key equality implies the whole prefix up to and including the
    block matched (the same prefix-digest idea as ``CimEngine``'s streamed
    digest path, but blake2b rather than the engine's linear XOR fold: an
    index key must survive adversarial collisions, a parity check need
    not).  Correctness never rests on the hash either way: every entry
    stores its actual tokens and lookup verifies them word-exactly, so a
    collision degrades to a cache miss, never to wrong reuse — the same
    hash-then-word-compare discipline DigestCache uses (§12).

    For ctx archs (vlm / enc-dec) the chain root folds a digest of the
    request's modality context, so equal token prefixes under different
    images / audio never share.  Pure host logic; the engine drives
    registration and eviction, and :class:`BlockPool` owns residency."""

    ROOT = b"\x00" * 16

    def __init__(self, block_size: int):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.block_size = block_size
        # key -> (bid, tokens); parent key -> child keys; bid -> (key, parent)
        self._entries: dict[bytes, tuple[int, np.ndarray]] = {}
        self._children: dict[bytes, list[bytes]] = {}
        self._by_block: dict[int, tuple[bytes, bytes]] = {}
        # bumped on every mutation: lookup results are valid (and may be
        # cached by callers) exactly while this stays unchanged
        self.generation = 0

    def __len__(self) -> int:
        return len(self._by_block)

    @staticmethod
    def root_key(ctx=None) -> bytes:
        if ctx is None:
            return PrefixIndex.ROOT
        a = np.ascontiguousarray(np.asarray(ctx))
        return hashlib.blake2b(repr((a.shape, a.dtype.str)).encode()
                               + a.tobytes(), digest_size=16).digest()

    def chain(self, tokens, ctx=None) -> list[tuple[bytes, bytes, np.ndarray]]:
        """(key, parent_key, block_tokens) per full block of ``tokens``."""
        bs = self.block_size
        toks = np.asarray(tokens, np.int32)
        out, parent = [], self.root_key(ctx)
        for i in range(len(toks) // bs):
            blk = toks[i * bs:(i + 1) * bs]
            key = hashlib.blake2b(parent + blk.tobytes(),
                                  digest_size=16).digest()
            out.append((key, parent, blk))
            parent = key
        return out

    def register(self, key: bytes, parent: bytes, bid: int,
                 tokens: np.ndarray) -> bool:
        """Idempotent, keep-first: when two requests with identical
        prompts prefill concurrently both try to register, and the first
        stays canonical (the second's block simply frees unregistered).
        Returns True when ``bid`` newly entered the index."""
        if key in self._entries or bid in self._by_block:
            return False
        self._entries[key] = (bid, np.array(tokens, np.int32))
        self._children.setdefault(parent, []).append(key)
        self._by_block[bid] = (key, parent)
        self.generation += 1
        return True

    def drop_block(self, bid: int) -> None:
        """Remove the entry backed by ``bid`` (pool eviction).  Entries
        that extended it stay registered: lookup can only reach a child
        through its matched parent — which now misses — so orphaned
        descendants are unreachable until a re-registration of the same
        prefix content restores the chain, and meanwhile they age out of
        the idle LRU like any other cold block."""
        key, parent = self._by_block.pop(bid)
        del self._entries[key]
        sibs = self._children[parent]
        sibs.remove(key)
        if not sibs:
            del self._children[parent]
        self.generation += 1

    def lookup(self, prompt, ctx=None):
        """Longest registered chain of full blocks, plus the best partial
        continuation.

        Returns ``(block_ids, n_full, child)``: the matched full blocks'
        ids, how many, and ``(bid, d)`` for the registered block extending
        the chain with the longest common token prefix (``d`` tokens,
        possibly 0; ties break toward the earliest-registered child) — or
        None when no block extends the chain.  Tokens are compared exactly
        at every step; a hash collision is a miss, never a wrong block."""
        bs = self.block_size
        toks = np.asarray(prompt, np.int32)
        ids: list[int] = []
        parent = self.root_key(ctx)
        for key, _, blk in self.chain(toks, ctx):
            ent = self._entries.get(key)
            if ent is None or not np.array_equal(ent[1], blk):
                break
            ids.append(ent[0])
            parent = key
        n_full = len(ids)
        child = None
        rest = toks[n_full * bs:]
        if len(rest):
            best = -1
            for ck in self._children.get(parent, []):
                bid, ctoks = self._entries[ck]
                m = min(len(rest), len(ctoks))
                neq = ctoks[:m] != rest[:m]
                d = int(np.argmax(neq)) if neq.any() else m
                if d > best:
                    best, child = d, (bid, d)
        return ids, n_full, child


@dataclasses.dataclass
class EngineStats:
    """Engine-side counters, including block-pool occupancy (peak / mean
    blocks in use) so benchmarks can report memory utilization alongside
    tok/s.  ``prefill_traces`` counts the distinct prefill programs this
    engine demanded: actual compilations of the paged engine's per-engine
    chunk program (pinned to exactly 1 for any mix of prompt lengths), vs
    one per distinct prompt length on the dense path (whose module-level
    jit cache may already hold some of them from an earlier engine in the
    same process — the count is this engine's shape demand, not a process
    compile count)."""

    decode_steps: int = 0
    prefills: int = 0
    prefill_chunks: int = 0
    prefill_traces: int = 0
    decode_traces: int = 0
    blocks_total: int = 0       # allocatable blocks (0: dense layout)
    blocks_in_use: int = 0
    blocks_peak: int = 0
    # prefix caching (DESIGN.md §15; all zero when disabled / dense)
    cow_copies: int = 0             # divergence-block copy-on-write copies
    prefix_hits: int = 0            # admissions that mapped >= 1 shared block
    prefix_shared_blocks: int = 0   # total blocks mapped read-only
    prefix_tokens: int = 0          # prompt tokens skipped via the cache
    prompt_tokens: int = 0          # prompt tokens admitted (paged path)
    fresh_blocks: int = 0           # blocks newly allocated at admission
    prefix_evictions: int = 0       # cached blocks reclaimed under pressure
    prefix_cached_blocks: int = 0   # current index size (registered blocks)
    _block_sum: int = 0
    _block_samples: int = 0

    def observe_blocks(self, in_use: int) -> None:
        self.blocks_in_use = in_use
        self.blocks_peak = max(self.blocks_peak, in_use)
        self._block_sum += in_use
        self._block_samples += 1

    @property
    def blocks_mean(self) -> float:
        if not self._block_samples:
            return 0.0
        return self._block_sum / self._block_samples

    @property
    def block_utilization(self) -> float:
        """Mean fraction of the pool in use (0 when dense)."""
        if not self.blocks_total:
            return 0.0
        return self.blocks_mean / self.blocks_total

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from the prefix
        cache (skipped at prefill)."""
        if not self.prompt_tokens:
            return 0.0
        return self.prefix_tokens / self.prompt_tokens

    @property
    def blocks_per_request(self) -> float:
        """Mean *fresh* blocks allocated per admitted request — sharing
        drives this down; the serve-throughput smoke gate pins the drop."""
        if not self.prefills:
            return 0.0
        return self.fresh_blocks / self.prefills


# ---------------------------------------------------------------------------
# jitted programs (module level: one trace cache per (cfg, shapes))
# ---------------------------------------------------------------------------


def _sample_tokens(cfg, logits, key, seeds, temperature: float):
    """Last-position sampling, sliced to the true vocab (pad ids never
    sampled).  Per-row keys fold the host-computed (rid, step) seed into the
    engine key, so draws depend only on the request and its token index —
    never on slot assignment or batch composition (determinism under a
    fixed seed, whatever the schedule)."""
    lg = logits[:, -1, :cfg.vocab].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]

    def one(row, seed):
        g = jax.random.gumbel(jax.random.fold_in(key, seed), row.shape,
                              jnp.float32)
        return jnp.argmax(row / temperature + g, axis=-1).astype(jnp.int32)
    return jax.vmap(one)(lg, seeds)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "s_max", "temperature"))
def _prefill_program(cfg, params, tokens, ctx, key, seeds, *, s_max: int,
                     temperature: float):
    """(1, P) prompt -> (first sampled token (1, 1), DecodeState for B=1)."""
    logits, state = lm.prefill(cfg, params, tokens, ctx, s_max=s_max)
    return _sample_tokens(cfg, logits, key, seeds, temperature), state


@functools.partial(jax.jit, static_argnames=("cfg", "temperature"),
                   donate_argnames=("state",))
def _decode_program(cfg, params, tokens, state, active, key, seeds, *,
                    temperature: float):
    """One token for every slot; inactive slots' positions stay frozen."""
    logits, state = lm.decode_step(cfg, params, tokens, state, active=active)
    return _sample_tokens(cfg, logits, key, seeds, temperature), state


@functools.partial(jax.jit, donate_argnames=("resident",))
def _insert_program(resident: lm.DecodeState, one: lm.DecodeState, slot):
    """Scatter a freshly prefilled B=1 state into resident slot ``slot``.

    Segment-state leaves are layer-stacked with batch at axis 1
    ((n_layers, B, ...)); ``ctx`` and ``pos`` carry batch at axis 0.  The
    resident tree follows ``lm.decode_state_spec``: for enc-dec archs its
    ``ctx`` is None (cross-attn KV lives inside the per-layer states; the
    decode path never reads ``DecodeState.ctx``), so the prefill state's
    encoder output is dropped rather than kept resident.
    """
    seg = jax.tree.map(lambda r, o: r.at[:, slot].set(o[:, 0]),
                       resident.seg_states, one.seg_states)
    pos = resident.pos.at[slot].set(one.pos)
    ctx = (resident.ctx if resident.ctx is None
           else resident.ctx.at[slot].set(one.ctx[0]))
    return lm.DecodeState(pos, seg, ctx)


@dataclasses.dataclass
class _PrefillProgress:
    """Host bookkeeping for one slot's in-flight chunked prefill."""

    session: Session
    padded: np.ndarray          # prompt suffix zero-padded to n_chunks * C
    p_len: int                  # suffix length = prompt length - skip
    n_chunks: int
    next_chunk: int
    ctx: Any                    # encoded (enc-dec) / raw (vlm) ctx, or None
    seeds: Any                  # (1,) device seeds for the prefill sample
    rows: dict                  # this slot's (1, W) block-table rows
    skip: int = 0               # positions served from shared prefix blocks
    chain: list = dataclasses.field(default_factory=list)
                                # full prompt's (key, parent, tokens) chain
    registered: int = 0         # prompt blocks registered so far


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeReport:
    """Outcome of one :meth:`ServeEngine.run`."""

    sessions: dict[int, Session]
    wall: float
    decode_steps: int
    prefills: int
    stats: EngineStats | None = None

    @property
    def generated(self) -> int:
        return sum(len(s.tokens) for s in self.sessions.values())

    @property
    def tok_per_s(self) -> float:
        return self.generated / max(self.wall, 1e-9)

    def tokens(self, rid: int) -> np.ndarray:
        return np.asarray(self.sessions[rid].tokens, np.int32)

    def _quantiles(self, values, qs) -> dict[float, float]:
        vals = [v for v in values if v == v]       # drop NaN (in-flight)
        if not vals:
            return {q: 0.0 for q in qs}
        return {q: float(np.quantile(vals, q)) for q in qs}

    def latency_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        return self._quantiles((s.latency for s in self.sessions.values()), qs)

    def ttft_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        """Submit-to-first-token, including time spent queued."""
        return self._quantiles((s.ttft for s in self.sessions.values()), qs)

    def ttft_step_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        """First-token engine-step index — TTFT in schedule depth.  On a
        dispatch-bound smoke model wall TTFT is dominated by per-step sync
        overhead; the step count is the deterministic quantity wall time
        tracks once prefill compute actually dominates."""
        return self._quantiles(
            (float("nan") if s.step_first is None else float(s.step_first)
             for s in self.sessions.values()), qs)

    def queue_wait_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        """Submit-to-admission: the scheduling share of TTFT, separated so
        prefill cost and queueing backpressure are distinguishable."""
        return self._quantiles(
            (s.queue_wait for s in self.sessions.values()), qs)


class ServeEngine:
    """Continuous-batching serve engine over one resident decode state.

    Args:
      cfg: ArchConfig. ``quant="xnor"`` archs serve from packed weights
        unless ``pack=False``.
      params: float param tree (as from ``lm.init_params`` / ``ckpt``);
        packed at construction when applicable — the float copies of
        binarized linears are not retained by the engine.
      slots: resident batch width (concurrent requests).
      s_max: per-slot cache capacity; every request needs
        ``len(prompt) + max_new_tokens - 1 <= s_max``.
      eos_id: token id that terminates a request early (None: budget only).
      temperature: 0 = greedy (deterministic); > 0 = gumbel sampling with
        schedule-independent per-(request, step) keys.
      seed: engine sampling seed.
      pack: keep binarizable linears packed-resident (xnor archs only).
      prefix_cache: content-addressed prefix sharing over the paged pool
        (DESIGN.md §15; paged engines only).  Auto-disabled for archs whose
        state cannot be rebuilt from cached blocks (recurrent carries,
        local window rings) — ``engine.prefix_caching`` reports the
        effective setting.
    """

    def __init__(self, cfg, params, *, slots: int, s_max: int,
                 eos_id: int | None = None, temperature: float = 0.0,
                 seed: int = 0, pack: bool = True, paged: bool = True,
                 block_size: int = 0, prefill_chunk: int = 0,
                 n_blocks: int = 0, prefix_cache: bool = True):
        self.cfg = cfg
        self.slots = slots
        self.s_max = s_max
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self.params = lm.pack_params(cfg, params) if pack else params
        self.pool = SlotPool(slots)
        self.sessions: dict[int, Session] = {}
        self._key = jax.random.PRNGKey(seed)
        self.paged = bool(paged)
        self.stats = EngineStats()
        self._step_idx = 0                 # engine steps since construction
        if self.paged:
            self.block_size = block_size or cfg.block_size
            self.prefill_chunk = prefill_chunk or cfg.prefill_chunk
            self._widths = lm.paged_table_widths(cfg, s_max, self.block_size,
                                                 self.prefill_chunk)
            per_slot_worst = sum(self._widths.values())
            if n_blocks <= 0:
                # default: enough for every slot at full table width (the
                # paged layout is then never *smaller* than dense; callers
                # shrink n_blocks to oversubscribe slots at equal memory)
                n_blocks = 1 + slots * max(per_slot_worst, 1)
            self.n_blocks = n_blocks
            self.blocks = BlockPool(n_blocks) if self._widths else None
            self.stats.blocks_total = n_blocks - 1 if self.blocks else 0
            # prefix caching (DESIGN.md §15): only for archs whose whole
            # sequential state is reconstructible from the paged pools —
            # prefix_cache_eligible is fail-closed over each kind's
            # declared prefix_shareable contract flag (recurrent carries
            # and local window *rings* don't declare it).  The table class
            # shared prefixes register under comes from the same contracts.
            self._share_cls = lm.prefix_table_class(cfg)
            self._prefix = (PrefixIndex(self.block_size)
                            if prefix_cache and self.blocks is not None
                            and self._share_cls is not None
                            and lm.prefix_cache_eligible(cfg) else None)
            # host-owned block tables, mirrored to device on change
            self._tables = {c: np.zeros((slots, w), np.int32)
                            for c, w in self._widths.items()}
            self._dev_tables = None
            self._state = lm.paged_decode_state_spec(
                cfg, slots, s_max, n_blocks=n_blocks,
                block_size=self.block_size, abstract=False)
            self._build_paged_programs()
        else:
            # the single source of truth for the resident layout is
            # lm.decode_state_spec (the same tree the dry-run lowers)
            self._state = lm.decode_state_spec(cfg, slots, s_max,
                                               abstract=False,
                                               per_slot_pos=True)
            self._dense_prefill_lens: set[int] = set()
            self._prefix = None
            self._share_cls = None
        # host-side mirrors of the device batch (tiny, moved every step)
        self._tokens = np.zeros((slots, 1), np.int32)
        self._active = np.zeros((slots,), bool)
        # slots mid-chunked-prefill: slot -> _PrefillProgress (paged only;
        # dense prefill is a single exact-length program, nothing to slice)
        self._prefilling: dict[int, _PrefillProgress] = {}
        # memoized FIFO-head prefix plan: ((rid, index generation), plan)
        self._plan_cache: tuple[tuple[int, int], tuple] | None = None

    def _build_paged_programs(self):
        """Per-engine jits so trace counts are observable: the python side
        effect on ``stats`` runs at trace time only, so ``prefill_traces``
        counts compilations — the chunked-prefill contract pins it to 1."""
        cfg, temperature = self.cfg, self.temperature

        def chunk_fn(params, tokens, state, slot, n_valid, tables, ctx,
                     fresh, start, key, seeds):
            self.stats.prefill_traces += 1
            logits, state = lm.prefill_chunk_step(cfg, params, tokens, state,
                                                  slot, n_valid, tables, ctx,
                                                  fresh=fresh, start=start)
            return (_sample_tokens(cfg, logits, key, seeds, temperature),
                    state)

        def decode_fn(params, tokens, state, tables, active, key, seeds):
            self.stats.decode_traces += 1
            logits, state = lm.paged_decode_step(cfg, params, tokens, state,
                                                 tables, active=active)
            return (_sample_tokens(cfg, logits, key, seeds, temperature),
                    state)

        self._chunk_program = jax.jit(chunk_fn, donate_argnums=(2,))
        self._paged_decode_program = jax.jit(decode_fn, donate_argnums=(2,))
        # copy-on-write block duplication: src/dst are device scalars, so
        # one program covers every (donor, recipient) pair without retracing
        self._cow_program = jax.jit(
            lambda state, src, dst: lm.paged_copy_block(cfg, state, src, dst),
            donate_argnums=(0,))
        self._encode_program = None
        if cfg.is_encdec():
            self._encode_program = jax.jit(
                lambda params, frames: lm.encode(cfg, params, frames))

    def _blocks_per_class(self, prompt_len: int,
                          max_new_tokens: int) -> dict[str, int]:
        """Worst-case block reservation per table class for one request:
        positions 0..P+G-2 are cached, window classes cap at their ring
        width.  Single source for both the admission gate and the actual
        allocation — they must never drift apart."""
        nb = -(-(prompt_len + max_new_tokens - 1) // self.block_size)
        return {c: min(nb, w) for c, w in self._widths.items()}

    def _blocks_needed(self, prompt_len: int, max_new_tokens: int) -> int:
        return sum(self._blocks_per_class(prompt_len,
                                          max_new_tokens).values())

    # -- request lifecycle ---------------------------------------------------

    def submit(self, request: Request) -> Session:
        if request.rid in self.sessions:
            raise ValueError(f"duplicate request id {request.rid}")
        need = request.prompt.shape[0] + request.max_new_tokens - 1
        if need > self.s_max:
            raise ValueError(
                f"request {request.rid} needs {need} cache positions, "
                f"engine capacity is s_max={self.s_max}")
        if self.paged and self.blocks is not None:
            nb = self._blocks_needed(request.prompt.shape[0],
                                     request.max_new_tokens)
            if nb > self.blocks.capacity:
                raise ValueError(
                    f"request {request.rid} needs {nb} blocks, pool "
                    f"capacity is {self.blocks.capacity} "
                    f"(n_blocks={self.n_blocks} incl. trash block)")
        session = Session(request, t_submit=time.monotonic())
        self.sessions[request.rid] = session
        self.pool.submit(session)
        return session

    def _seed_for(self, rid: int, step: int) -> int:
        return (rid * 1_000_003 + step) % (2**31 - 1)

    def _finish(self, session: Session, reason: str) -> None:
        session.finish_reason = reason
        session.t_done = time.monotonic()
        if session.slot is not None and session.slot in self.pool.active:
            slot = session.slot
            self.pool.evict(slot)
            self._active[slot] = False
            self._tokens[slot] = 0   # dead slots feed a constant token id
                                     # (keeps MoE capacity competition quiet)
            if self.paged:
                # eviction returns every block the request held; the zeroed
                # table row routes the dead slot's frozen re-writes to the
                # trash block so reallocated blocks are never corrupted.
                # Cached blocks (registered below / during prefill) park in
                # the pool's idle tier instead of freeing.
                if self.blocks is not None:
                    if self._prefix is not None:
                        self._register_finished(session, slot)
                    self.blocks.free(session.request.rid)
                for t in self._tables.values():
                    t[slot, :] = 0
                self._dev_tables = None

    def _register_finished(self, session: Session, slot: int) -> None:
        """Register the request's full written blocks on release — prompt
        *and* generated region: positions 0..P+G-2 are written (the last
        sampled token never is), so every full block's contents are final
        and a later prompt extending this one past its prompt shares the
        decode region too."""
        req = session.request
        written = req.prompt.shape[0] + len(session.tokens) - 1
        seq = req.prompt
        if len(session.tokens) > 1:
            seq = np.concatenate(
                [seq, np.asarray(session.tokens[:-1], np.int32)])
        row = self._tables[self._share_cls][slot]
        chain = self._prefix.chain(seq[:written], req.ctx)
        for i, (key, parent, toks) in enumerate(chain):
            bid = int(row[i])
            if self._prefix.register(key, parent, bid, toks):
                self.blocks.set_cached(bid)
        self.stats.prefix_cached_blocks = len(self._prefix)

    def _ctx_for(self, req: Request):
        if req.ctx is not None:
            ctx = jnp.asarray(np.asarray(req.ctx)[None])
            if self.paged and self.cfg.is_encdec():
                # encode once at admission; chunks consume the frames
                ctx = self._encode_program(self.params, ctx)
            return ctx
        if self.cfg.n_ctx_tokens:
            raise ValueError(
                f"arch {self.cfg.name} needs per-request ctx; request "
                f"{req.rid} has none")
        return None

    def _post_prefill(self, session: Session, slot: int, tok) -> bool:
        """Record the prefill-sampled token; returns True when the request
        survives into the decode batch."""
        t = int(np.asarray(tok)[0, 0])
        session.tokens.append(t)
        session.t_first = time.monotonic()
        session.step_first = self._step_idx
        if self.eos_id is not None and t == self.eos_id:
            self._finish(session, "eos")
            return False
        if session.request.max_new_tokens == 1:
            self._finish(session, "length")
            return False
        self._tokens[slot, 0] = t
        self._active[slot] = True
        return True

    @property
    def prefix_caching(self) -> bool:
        """Whether prefix sharing is effectively on for this engine."""
        return self._prefix is not None

    def _prefix_plan(self, req: Request) -> tuple[list[int], int, int | None]:
        """``(shared, skip, cow_src)`` for one request: which cached blocks
        it can map read-only, how many prompt positions that skips, and the
        shared block its first write would land in (the copy-on-write
        source), if any.  Pure lookup — residency changes at admission.

        The divergence block (the registered block extending the matched
        chain, matching ``d >= 0`` further tokens) is mapped whenever at
        least one full block matched or ``d > 0`` — the uniform rule that
        makes "exactly one COW per divergence" hold at block boundaries
        too; a request that matches nothing takes the wholly-fresh path.
        ``skip`` is capped at P-1: the prefill always recomputes at least
        the last prompt position, because it must emit that logit row —
        which also means a full-prompt hit COWs the block holding position
        P-1 rather than writing a donor's block."""
        p_len = req.prompt.shape[0]
        if self._prefix is None:
            return [], 0, None
        ids, n_full, child = self._prefix.lookup(req.prompt, req.ctx)
        shared = list(ids)
        skip = n_full * self.block_size
        if child is not None and (n_full > 0 or child[1] > 0):
            shared.append(child[0])
            skip += child[1]
        skip = min(skip, p_len - 1)
        if skip <= 0:
            return [], 0, None
        w0 = skip // self.block_size
        cow = shared[w0] if w0 < len(shared) else None
        return shared, skip, cow

    def _fresh_needed(self, req: Request,
                      plan: tuple[list[int], int, int | None]) -> dict:
        """Fresh-block need per table class given a prefix plan: shared
        blocks cost nothing, the COW target costs one extra."""
        shared, _, cow = plan
        per = self._blocks_per_class(req.prompt.shape[0], req.max_new_tokens)
        if shared:
            per = dict(per)
            per[self._share_cls] -= len(shared) - (1 if cow is not None else 0)
        return per

    def _alloc_blocks(self, rid: int, n: int) -> list[int]:
        """Alloc with eviction: when the free list runs short, reclaim the
        LRU idle cached blocks and drop their index entries (the admission
        gate already checked free + idle covers the need)."""
        short = n - self.blocks.available
        if short > 0:
            for bid in self.blocks.evict_idle(short):
                self._prefix.drop_block(bid)
                self.stats.prefix_evictions += 1
            self.stats.prefix_cached_blocks = len(self._prefix)
        return self.blocks.alloc(rid, n)

    def _head_plan(self, req: Request) -> tuple[list[int], int, int | None]:
        """The FIFO head's prefix plan, memoized on (rid, index
        generation): a head blocked on blocks or slots is re-polled every
        engine step, and the plan — an O(P) chain hash plus child scans —
        only changes when the index does (revival/idling of blocks moves
        residency tiers, never index contents)."""
        if self._prefix is None:
            return self._prefix_plan(req)
        tag = (req.rid, self._prefix.generation)
        if self._plan_cache is None or self._plan_cache[0] != tag:
            self._plan_cache = (tag, self._prefix_plan(req))
        return self._plan_cache[1]

    def _admissible_paged(self) -> tuple | None:
        """The FIFO head's prefix plan when it can be admitted, else None.
        OOM backpressure gates on *fresh* blocks needed (shared blocks are
        free) against free + evictable-idle — the head waits, no skipping
        (determinism and no starvation).

        Idle blocks the plan itself shares don't count as evictable: admit
        revives them (refcount 1) before allocating, so they can't also
        cover the fresh need.  When that deficit is the only thing blocking
        the head and nothing is in flight — no active request will ever
        free another block, so waiting would deadlock — the head degrades
        to a wholly-fresh plan, which :meth:`submit`'s capacity check
        guarantees fits once the idle tier is evicted."""
        head = self.pool.peek()
        if head is None or not self.pool.free_slots:
            return None
        plan = self._head_plan(head.request)
        if self.blocks is None:
            return plan
        need = sum(self._fresh_needed(head.request, plan).values())
        revived = sum(1 for b in plan[0] if self.blocks.is_idle(b))
        if need <= self.blocks.reclaimable - revived:
            return plan
        if plan[0] and not self.pool.active:
            fresh = ([], 0, None)
            n = sum(self._fresh_needed(head.request, fresh).values())
            if n <= self.blocks.reclaimable:
                return fresh
        return None

    def _slot_table_rows(self, slot: int) -> dict:
        return {c: jnp.asarray(t[slot:slot + 1])
                for c, t in self._tables.items()}

    def _admit_paged(self) -> None:
        """Admission under the block-paged layout: map the request's shared
        prefix blocks read-only, reserve fresh blocks for the remainder
        (evicting idle cached blocks LRU-first under pressure), COW the
        divergence block if the first write would land in shared cache, and
        queue the chunked prefill of the unshared suffix.  The chunks
        themselves are dispatched by :meth:`_prefill_step` — ONE per engine
        step per admitting slot — so a long prompt interleaves with the
        decode batch in bounded ``prefill_chunk``-sized slices instead of
        blocking it head-of-line."""
        while (plan := self._admissible_paged()) is not None:
            session, slot = self.pool.admit()
            req = session.request
            session.t_admit = time.monotonic()
            p_len = req.prompt.shape[0]
            shared, skip, cow_src = plan
            if self.blocks is not None:
                if shared:
                    self.blocks.share(req.rid, shared)
                fresh = {cls_name: self._alloc_blocks(req.rid, n)
                         for cls_name, n in
                         self._fresh_needed(req, plan).items()}
                for cls_name, ids in fresh.items():
                    row = self._tables[cls_name][slot]
                    row[:] = 0
                    if cls_name == self._share_cls and shared:
                        row[:len(shared)] = shared
                        tail = ids
                        if cow_src is not None:
                            # repoint the first-write block at a private
                            # copy; the device copy below runs before any
                            # subsequently dispatched program can write it
                            row[skip // self.block_size] = ids[0]
                            tail = ids[1:]
                        row[len(shared):len(shared) + len(tail)] = tail
                    else:
                        row[:len(ids)] = ids
                self._dev_tables = None
                if cow_src is not None:
                    self._state = self._cow_program(
                        self._state, jnp.int32(cow_src),
                        jnp.int32(fresh[self._share_cls][0]))
                    self.blocks.drop(req.rid, cow_src)
                    self.stats.cow_copies += 1
                self.stats.fresh_blocks += sum(len(v) for v in fresh.values())
                self.stats.observe_blocks(self.blocks.in_use)
            self.stats.prompt_tokens += p_len
            if shared:
                self.stats.prefix_hits += 1
                self.stats.prefix_shared_blocks += len(shared)
                self.stats.prefix_tokens += skip
            c = self.prefill_chunk
            n_suffix = p_len - skip
            n_chunks = -(-n_suffix // c)
            padded = np.zeros((n_chunks * c,), np.int32)
            padded[:n_suffix] = req.prompt[skip:]
            chain = ([] if self._prefix is None
                     else self._prefix.chain(req.prompt, req.ctx))
            self._prefilling[slot] = _PrefillProgress(
                session=session, padded=padded, p_len=n_suffix,
                n_chunks=n_chunks, next_chunk=0, ctx=self._ctx_for(req),
                seeds=jnp.asarray([self._seed_for(req.rid, 0)], jnp.int32),
                rows=self._slot_table_rows(slot), skip=skip, chain=chain)
            self.stats.prefills += 1

    def _register_upto(self, prog: _PrefillProgress, slot: int,
                       n_done: int) -> None:
        """Register the prompt's first ``n_done`` full blocks (those wholly
        covered by dispatched chunks) in the prefix index.  Device programs
        execute in dispatch order, so by the time any later-admitted
        sharer's gather runs, the content the key promises is in place —
        this is what lets a request share with a *still-prefilling* donor
        (the mid-prefill divergence case).  Already-registered keys (the
        blocks this request itself shares) no-op via keep-first."""
        row = self._tables[self._share_cls][slot]
        n = min(n_done, len(prog.chain))
        while prog.registered < n:
            key, parent, toks = prog.chain[prog.registered]
            bid = int(row[prog.registered])
            if self._prefix.register(key, parent, bid, toks):
                self.blocks.set_cached(bid)
            prog.registered += 1
        self.stats.prefix_cached_blocks = len(self._prefix)

    def _prefill_step(self) -> None:
        """Advance every in-flight chunked prefill by exactly one chunk;
        a prompt that finishes joins the decode batch this same step."""
        for slot in sorted(self._prefilling):
            prog = self._prefilling[slot]
            c = self.prefill_chunk
            j = prog.next_chunk
            piece = jnp.asarray(prog.padded[None, j * c:(j + 1) * c])
            n_valid = min(c, prog.p_len - j * c)
            tok, self._state = self._chunk_program(
                self.params, piece, self._state, jnp.int32(slot),
                jnp.int32(n_valid), prog.rows, prog.ctx,
                jnp.asarray(j == 0), jnp.int32(prog.skip), self._key,
                prog.seeds)
            self.stats.prefill_chunks += 1
            prog.next_chunk += 1
            if self._prefix is not None:
                done = prog.skip + min((j + 1) * c, prog.p_len)
                self._register_upto(prog, slot, done // self.block_size)
            if prog.next_chunk == prog.n_chunks:
                del self._prefilling[slot]
                self._post_prefill(prog.session, slot, tok)

    def _admit(self) -> None:
        """Fill every free slot from the queue (prefill + scatter insert)."""
        if self.paged:
            return self._admit_paged()
        while self.pool.admissible():
            session, slot = self.pool.admit()
            req = session.request
            session.t_admit = time.monotonic()
            tokens = jnp.asarray(req.prompt[None, :], jnp.int32)
            ctx = self._ctx_for(req)
            seeds = jnp.asarray([self._seed_for(req.rid, 0)], jnp.int32)
            self._dense_prefill_lens.add(req.prompt.shape[0])
            self.stats.prefill_traces = len(self._dense_prefill_lens)
            tok, one = _prefill_program(
                self.cfg, self.params, tokens, ctx, self._key, seeds,
                s_max=self.s_max, temperature=self.temperature)
            self.stats.prefills += 1
            if self._post_prefill(session, slot, tok):
                self._state = _insert_program(self._state, one,
                                              jnp.int32(slot))

    def _device_tables(self) -> dict:
        if self._dev_tables is None:
            self._dev_tables = {c: jnp.asarray(t)
                                for c, t in self._tables.items()}
        return self._dev_tables

    def _decode_once(self) -> None:
        """One batched decode step; append/evict per active slot (slots
        still mid-prefill ride along inertly and are skipped here)."""
        active_sessions = {s: sess for s, sess in self.pool.active.items()
                           if s not in self._prefilling}
        seeds = np.zeros((self.slots,), np.int32)
        for slot, sess in active_sessions.items():
            seeds[slot] = self._seed_for(sess.request.rid, len(sess.tokens))
        if self.paged:
            toks, self._state = self._paged_decode_program(
                self.params, jnp.asarray(self._tokens), self._state,
                self._device_tables(), jnp.asarray(self._active), self._key,
                jnp.asarray(seeds))
            if self.blocks is not None:
                self.stats.observe_blocks(self.blocks.in_use)
        else:
            toks, self._state = _decode_program(
                self.cfg, self.params, jnp.asarray(self._tokens), self._state,
                jnp.asarray(self._active), self._key, jnp.asarray(seeds),
                temperature=self.temperature)
        self.stats.decode_steps += 1
        toks = np.asarray(toks)                     # the per-step sync point
        for slot, sess in active_sessions.items():
            t = int(toks[slot, 0])
            sess.tokens.append(t)
            self._tokens[slot, 0] = t
            if self.eos_id is not None and t == self.eos_id:
                self._finish(sess, "eos")
            elif len(sess.tokens) >= sess.request.max_new_tokens:
                self._finish(sess, "length")

    def step(self) -> bool:
        """Admit, advance in-flight prefills by one chunk each, then decode
        once; returns False when fully drained."""
        self._step_idx += 1
        self._admit()
        if self._prefilling:
            self._prefill_step()
        if any(s not in self._prefilling for s in self.pool.active):
            self._decode_once()
        return not self.pool.idle()

    def run(self) -> ServeReport:
        """Drain queue + slots; returns the per-request report."""
        t0 = time.monotonic()
        while self.step():
            pass
        return ServeReport(sessions=dict(self.sessions),
                           wall=time.monotonic() - t0,
                           decode_steps=self.stats.decode_steps,
                           prefills=self.stats.prefills,
                           stats=self.stats)
