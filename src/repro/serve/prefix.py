"""Content-addressed prefix index over cached KV blocks (DESIGN.md §15).

Split out of the engine so a replica-ready process can hold one index per
engine (replicas never share an index — each replica's pool owns its own
residency) and so the chain-hash logic is unit-testable without a model.
"""

from __future__ import annotations

import hashlib

import numpy as np


class PrefixIndex:
    """Content-addressed index over cached prefix blocks (DESIGN.md §15):
    hash-of-block-contents -> physical block id, for *full* blocks only
    (partial blocks are still being written, so their contents are not
    stable).  Keys are chain hashes — a block's key folds its parent's
    key, so key equality implies the whole prefix up to and including the
    block matched (the same prefix-digest idea as ``CimEngine``'s streamed
    digest path, but blake2b rather than the engine's linear XOR fold: an
    index key must survive adversarial collisions, a parity check need
    not).  Correctness never rests on the hash either way: every entry
    stores its actual tokens and lookup verifies them word-exactly, so a
    collision degrades to a cache miss, never to wrong reuse — the same
    hash-then-word-compare discipline DigestCache uses (§12).

    For ctx archs (vlm / enc-dec) the chain root folds a digest of the
    request's modality context, so equal token prefixes under different
    images / audio never share.  Pure host logic; the engine drives
    registration and eviction, and :class:`repro.serve.pools.BlockPool`
    owns residency."""

    ROOT = b"\x00" * 16

    def __init__(self, block_size: int):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.block_size = block_size
        # key -> (bid, tokens); parent key -> child keys; bid -> (key, parent)
        self._entries: dict[bytes, tuple[int, np.ndarray]] = {}
        self._children: dict[bytes, list[bytes]] = {}
        self._by_block: dict[int, tuple[bytes, bytes]] = {}
        # bumped on every mutation: lookup results are valid (and may be
        # cached by callers) exactly while this stays unchanged
        self.generation = 0

    def __len__(self) -> int:
        return len(self._by_block)

    @staticmethod
    def root_key(ctx=None) -> bytes:
        if ctx is None:
            return PrefixIndex.ROOT
        a = np.ascontiguousarray(np.asarray(ctx))
        return hashlib.blake2b(repr((a.shape, a.dtype.str)).encode()
                               + a.tobytes(), digest_size=16).digest()

    def chain(self, tokens, ctx=None) -> list[tuple[bytes, bytes, np.ndarray]]:
        """(key, parent_key, block_tokens) per full block of ``tokens``."""
        bs = self.block_size
        toks = np.asarray(tokens, np.int32)
        out, parent = [], self.root_key(ctx)
        for i in range(len(toks) // bs):
            blk = toks[i * bs:(i + 1) * bs]
            key = hashlib.blake2b(parent + blk.tobytes(),
                                  digest_size=16).digest()
            out.append((key, parent, blk))
            parent = key
        return out

    def register(self, key: bytes, parent: bytes, bid: int,
                 tokens: np.ndarray) -> bool:
        """Idempotent, keep-first: when two requests with identical
        prompts prefill concurrently both try to register, and the first
        stays canonical (the second's block simply frees unregistered).
        Returns True when ``bid`` newly entered the index."""
        if key in self._entries or bid in self._by_block:
            return False
        self._entries[key] = (bid, np.array(tokens, np.int32))
        self._children.setdefault(parent, []).append(key)
        self._by_block[bid] = (key, parent)
        self.generation += 1
        return True

    def drop_block(self, bid: int) -> None:
        """Remove the entry backed by ``bid`` (pool eviction).  Entries
        that extended it stay registered: lookup can only reach a child
        through its matched parent — which now misses — so orphaned
        descendants are unreachable until a re-registration of the same
        prefix content restores the chain, and meanwhile they age out of
        the idle LRU like any other cold block."""
        key, parent = self._by_block.pop(bid)
        del self._entries[key]
        sibs = self._children[parent]
        sibs.remove(key)
        if not sibs:
            del self._children[parent]
        self.generation += 1

    def lookup(self, prompt, ctx=None):
        """Longest registered chain of full blocks, plus the best partial
        continuation.

        Returns ``(block_ids, n_full, child)``: the matched full blocks'
        ids, how many, and ``(bid, d)`` for the registered block extending
        the chain with the longest common token prefix (``d`` tokens,
        possibly 0; ties break toward the earliest-registered child) — or
        None when no block extends the chain.  Tokens are compared exactly
        at every step; a hash collision is a miss, never a wrong block."""
        bs = self.block_size
        toks = np.asarray(prompt, np.int32)
        ids: list[int] = []
        parent = self.root_key(ctx)
        for key, _, blk in self.chain(toks, ctx):
            ent = self._entries.get(key)
            if ent is None or not np.array_equal(ent[1], blk):
                break
            ids.append(ent[0])
            parent = key
        n_full = len(ids)
        child = None
        rest = toks[n_full * bs:]
        if len(rest):
            best = -1
            for ck in self._children.get(parent, []):
                bid, ctoks = self._entries[ck]
                m = min(len(rest), len(ctoks))
                neq = ctoks[:m] != rest[:m]
                d = int(np.argmax(neq)) if neq.any() else m
                if d > best:
                    best, child = d, (bid, d)
        return ids, n_full, child
