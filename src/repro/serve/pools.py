"""Host-side residency allocators for the serve tier (DESIGN.md §13–§15).

:class:`SlotPool` owns batch-slot bookkeeping (FIFO admission into the
lowest free slot); :class:`BlockPool` owns the shared paged-KV block pool
(refcounts, copy-on-write holds, the idle cached tier and its LRU
eviction).  Both are pure host state machines — no jax — so the
determinism of the whole engine reduces to these classes being
deterministic, which the unit tests pin, and so one process can hold many
of them (one per engine replica) without touching device state.
"""

from __future__ import annotations

import bisect
import collections

from repro.serve.session import Session


class SlotPool:
    """Slot bookkeeping: FIFO admission into the lowest free slot.

    Pure host-side state machine (no jax) — determinism of the whole engine
    reduces to this class being deterministic, which the unit tests pin.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._free = list(range(n_slots))        # kept sorted ascending
        self._queue: collections.deque[Session] = collections.deque()
        self._active: dict[int, Session] = {}

    # -- queue side ----------------------------------------------------------

    def submit(self, session: Session) -> None:
        self._queue.append(session)

    @property
    def queued(self) -> int:
        return len(self._queue)

    def peek(self) -> Session | None:
        """The session the next admit() would pop (FIFO head), or None."""
        return self._queue[0] if self._queue else None

    def drain_queue(self) -> list[Session]:
        """Remove and return every queued (not yet admitted) session — the
        router's kill-drill path re-submits these to surviving replicas."""
        out = list(self._queue)
        self._queue.clear()
        return out

    # -- slot side -----------------------------------------------------------

    @property
    def free_slots(self) -> list[int]:
        return list(self._free)

    @property
    def active(self) -> dict[int, Session]:
        return dict(self._active)

    def admissible(self) -> bool:
        return bool(self._queue) and bool(self._free)

    def admit(self) -> tuple[Session, int]:
        """Pop the oldest queued session into the lowest free slot."""
        if not self._queue:
            raise RuntimeError("admit() with an empty queue")
        if not self._free:
            raise RuntimeError("admit() with no free slot")
        session = self._queue.popleft()
        slot = self._free.pop(0)
        session.slot = slot
        self._active[slot] = session
        return session, slot

    def place(self, session: Session, slot: int) -> None:
        """Seat a session directly into a specific free slot, bypassing the
        queue — the migration import path, which must land the session in
        the slot its device state was scattered into."""
        if slot not in self._free:
            raise RuntimeError(f"place({slot}): slot is not free")
        self._free.remove(slot)
        session.slot = slot
        self._active[slot] = session

    def evict(self, slot: int) -> Session:
        """Free a slot; its session leaves the active set."""
        if slot not in self._active:
            raise KeyError(f"slot {slot} is not active")
        session = self._active.pop(slot)
        self._free.append(slot)
        self._free.sort()
        return session

    def idle(self) -> bool:
        return not self._queue and not self._active


class BlockPool:
    """Host allocator for the shared paged-KV block pool (DESIGN.md §14/§15).

    Physical block 0 is the reserved *trash* block — dead-slot and padding
    writes are routed there and never read — so ids 1..n_blocks-1 are
    allocatable.  Allocation is lowest-id-first and per-request (free by
    request id reclaims everything the request held), which keeps the whole
    engine deterministic for a fixed trace.  Pure host logic, like
    :class:`SlotPool`, so it is unit-testable without a model.

    Prefix sharing (§15) adds per-block refcounts: a block may be *held*
    by several requests at once (:meth:`share` maps an existing block into
    another request read-only; a block is writable only while exactly one
    request holds it and it is not cached) and may be marked *cached*
    (registered in a :class:`repro.serve.prefix.PrefixIndex`).  A cached
    block whose refcount drops to zero is not freed but parked in an *idle*
    tier — content kept resident, revived by a later :meth:`share`,
    reclaimed least-recently-idle-first by :meth:`evict_idle` under pool
    pressure.  Uncached blocks go straight back to the free list, exactly
    the pre-§15 behavior.  LRU order uses a logical clock, never wall time,
    so eviction (and with it the whole engine) stays deterministic for a
    fixed trace.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError(
                f"need at least 2 blocks (block 0 is the reserved trash "
                f"block), got {n_blocks}")
        self.n_blocks = n_blocks
        self._free = list(range(1, n_blocks))    # kept sorted ascending
        self._held: dict[int, list[int]] = {}    # rid -> block ids
        self._ref: dict[int, int] = {}           # bid -> holders (>= 1)
        self._cached: set[int] = set()           # registered in a PrefixIndex
        self._idle: dict[int, int] = {}          # cached, ref 0: bid -> stamp
        self._clock = 0                          # deterministic LRU time

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the trash block)."""
        return self.n_blocks - 1

    @property
    def available(self) -> int:
        """Immediately allocatable (free list only — idle cached blocks
        need :meth:`evict_idle` first)."""
        return len(self._free)

    @property
    def idle(self) -> int:
        """Cached blocks with no holder (evictable, content resident)."""
        return len(self._idle)

    @property
    def reclaimable(self) -> int:
        """free + idle: the upper bound an admission gate may count on.
        Idle blocks a plan itself will :meth:`share` must be excluded by
        the caller — revival precedes the fresh allocation, so they
        cannot also be evicted to cover it."""
        return len(self._free) + len(self._idle)

    @property
    def in_use(self) -> int:
        """Blocks held by at least one request (idle cached blocks are
        resident but not in use)."""
        return self.capacity - len(self._free) - len(self._idle)

    @property
    def free_blocks(self) -> list[int]:
        return list(self._free)

    @property
    def idle_blocks(self) -> list[int]:
        """Idle cached blocks, eviction (LRU) order."""
        return sorted(self._idle, key=self._idle.__getitem__)

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def cached(self, bid: int) -> bool:
        return bid in self._cached

    def is_idle(self, bid: int) -> bool:
        """True when ``bid`` sits in the idle tier (cached, no holder) —
        evictable now, but not after a :meth:`share` revives it."""
        return bid in self._idle

    def idle_stamp(self, bid: int) -> int | None:
        """The logical-clock stamp of ``bid``'s *current* stay in the idle
        tier (None if not idle).  Strictly increasing across stays — the
        integrity scrubber keys its content baselines on (bid, stamp), so a
        block that was revived, rewritten by a new holder and re-idled is
        re-baselined instead of flagged as corrupt."""
        return self._idle.get(bid)

    def alloc(self, rid: int, n: int) -> list[int]:
        """n lowest free block ids, charged to request ``rid``."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: request {rid} needs {n} blocks, "
                f"{len(self._free)} free (admission must gate on available, "
                f"evicting idle cached blocks first)")
        ids = self._free[:n]
        del self._free[:n]
        self._held.setdefault(rid, []).extend(ids)
        for bid in ids:
            self._ref[bid] = 1
        return ids

    def share(self, rid: int, ids: list[int]) -> None:
        """Map existing blocks into ``rid`` read-only (refcount + 1 each).

        Sharing an idle cached block revives it: it leaves the eviction
        tier with its contents intact.  Sharing a free block (or the trash
        block, or a block ``rid`` already holds) is a caller bug."""
        held = self._held.setdefault(rid, [])
        for bid in ids:
            if bid <= 0 or bid >= self.n_blocks:
                raise ValueError(f"share({bid}): not an allocatable block id")
            if bid in held:
                raise RuntimeError(
                    f"share({bid}): request {rid} already holds it")
            if bid in self._idle:
                del self._idle[bid]
                self._ref[bid] = 1
            elif self._ref.get(bid, 0) > 0:
                self._ref[bid] += 1
            else:
                raise RuntimeError(f"share({bid}): block is free")
            held.append(bid)

    def _release(self, bid: int) -> None:
        r = self._ref[bid] - 1
        if r > 0:
            self._ref[bid] = r
            return
        del self._ref[bid]
        if bid in self._cached:
            self._clock += 1
            self._idle[bid] = self._clock
        else:
            bisect.insort(self._free, bid)

    def free(self, rid: int) -> int:
        """Drop every hold ``rid`` has; returns how many.  Blocks whose
        refcount hits zero return to the free list, except cached ones,
        which park in the idle tier."""
        ids = self._held.pop(rid, [])
        for bid in ids:
            self._release(bid)
        return len(ids)

    def drop(self, rid: int, bid: int) -> None:
        """Release ``rid``'s hold on one block — the copy-on-write path:
        after duplicating a shared divergence block into a private one the
        request lets go of the original."""
        held = self._held.get(rid)
        if held is None or bid not in held:
            raise KeyError(f"drop({bid}): not held by request {rid}")
        held.remove(bid)
        if not held:
            del self._held[rid]
        self._release(bid)

    def set_cached(self, bid: int) -> None:
        """Mark a held block as index-registered: its last release parks
        it in the idle tier instead of freeing it."""
        if self._ref.get(bid, 0) < 1:
            raise RuntimeError(f"set_cached({bid}): block is not held")
        self._cached.add(bid)

    def evict_idle(self, n: int) -> list[int]:
        """Reclaim the ``n`` least-recently-idled cached blocks back to
        the free list; the caller must drop their index entries.  Held
        (refcount > 0) blocks are never evicted."""
        if n > len(self._idle):
            raise RuntimeError(
                f"evict_idle({n}): only {len(self._idle)} blocks idle")
        victims = sorted(self._idle, key=self._idle.__getitem__)[:n]
        for bid in victims:
            del self._idle[bid]
            self._cached.discard(bid)
            bisect.insort(self._free, bid)
        return victims

    def held(self, rid: int) -> list[int]:
        return list(self._held.get(rid, []))
