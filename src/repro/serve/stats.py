"""Serve-side counters and run reports (DESIGN.md §13–§15, §17).

:class:`EngineStats` is the per-engine counter block (steps, traces,
block-pool occupancy, prefix-cache and integrity-scrub outcomes);
:class:`ServeReport` is the per-run outcome of :meth:`ServeEngine.run`.
Both are plain host data so the router can aggregate them across replicas.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.session import Session


@dataclasses.dataclass
class EngineStats:
    """Engine-side counters, including block-pool occupancy (peak / mean
    blocks in use) so benchmarks can report memory utilization alongside
    tok/s.  ``prefill_traces`` counts the distinct prefill programs this
    engine demanded: actual compilations of the paged engine's per-engine
    chunk program (pinned to exactly 1 for any mix of prompt lengths), vs
    one per distinct prompt length on the dense path (whose module-level
    jit cache may already hold some of them from an earlier engine in the
    same process — the count is this engine's shape demand, not a process
    compile count)."""

    decode_steps: int = 0
    prefills: int = 0
    prefill_chunks: int = 0
    prefill_traces: int = 0
    decode_traces: int = 0
    decode_dispatches: int = 0  # jaxpr dispatch count of the decode step
                                # (recorded by ServeEngine.decode_roofline;
                                # 0 until an audit runs)
    blocks_total: int = 0       # allocatable blocks (0: dense layout)
    blocks_in_use: int = 0
    blocks_peak: int = 0
    # prefix caching (DESIGN.md §15; all zero when disabled / dense)
    cow_copies: int = 0             # divergence-block copy-on-write copies
    prefix_hits: int = 0            # admissions that mapped >= 1 shared block
    prefix_shared_blocks: int = 0   # total blocks mapped read-only
    prefix_tokens: int = 0          # prompt tokens skipped via the cache
    prompt_tokens: int = 0          # prompt tokens admitted (paged path)
    fresh_blocks: int = 0           # blocks newly allocated at admission
    prefix_evictions: int = 0       # cached blocks reclaimed under pressure
    prefix_cached_blocks: int = 0   # current index size (registered blocks)
    # session migration (DESIGN.md §17; zero outside the replicated tier)
    migrations_out: int = 0         # sessions exported off this engine
    migrations_in: int = 0          # sessions imported into this engine
    # integrity scrubbing (§17): DigestCache passes over resident packed
    # weights and idle cached KV blocks, and mismatches found
    scrub_passes: int = 0
    scrub_weight_leaves: int = 0    # param leaves verified, cumulative
    scrub_idle_blocks: int = 0      # idle cached blocks verified, cumulative
    scrub_corruptions: int = 0      # digest mismatches vs recorded baseline
    _block_sum: int = 0
    _block_samples: int = 0

    def observe_blocks(self, in_use: int) -> None:
        self.blocks_in_use = in_use
        self.blocks_peak = max(self.blocks_peak, in_use)
        self._block_sum += in_use
        self._block_samples += 1

    @property
    def blocks_mean(self) -> float:
        if not self._block_samples:
            return 0.0
        return self._block_sum / self._block_samples

    @property
    def block_utilization(self) -> float:
        """Mean fraction of the pool in use (0 when dense)."""
        if not self.blocks_total:
            return 0.0
        return self.blocks_mean / self.blocks_total

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from the prefix
        cache (skipped at prefill)."""
        if not self.prompt_tokens:
            return 0.0
        return self.prefix_tokens / self.prompt_tokens

    @property
    def blocks_per_request(self) -> float:
        """Mean *fresh* blocks allocated per admitted request — sharing
        drives this down; the serve-throughput smoke gate pins the drop."""
        if not self.prefills:
            return 0.0
        return self.fresh_blocks / self.prefills


@dataclasses.dataclass
class ServeReport:
    """Outcome of one :meth:`ServeEngine.run`."""

    sessions: dict[int, Session]
    wall: float
    decode_steps: int
    prefills: int
    stats: EngineStats | None = None

    @property
    def generated(self) -> int:
        return sum(len(s.tokens) for s in self.sessions.values())

    @property
    def tok_per_s(self) -> float:
        return self.generated / max(self.wall, 1e-9)

    def tokens(self, rid: int) -> np.ndarray:
        return np.asarray(self.sessions[rid].tokens, np.int32)

    def _quantiles(self, values, qs) -> dict[float, float]:
        vals = [v for v in values if v == v]       # drop NaN (in-flight)
        if not vals:
            # mirror the Session.latency/ttft contract: nothing finished
            # means the statistic does not exist yet — NaN, never a fake 0
            # that would read as "instant" to a dashboard or a gate
            return {q: float("nan") for q in qs}
        return {q: float(np.quantile(vals, q)) for q in qs}

    def latency_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        return self._quantiles((s.latency for s in self.sessions.values()), qs)

    def ttft_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        """Submit-to-first-token, including time spent queued."""
        return self._quantiles((s.ttft for s in self.sessions.values()), qs)

    def ttft_step_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        """First-token engine-step index — TTFT in schedule depth.  On a
        dispatch-bound smoke model wall TTFT is dominated by per-step sync
        overhead; the step count is the deterministic quantity wall time
        tracks once prefill compute actually dominates."""
        return self._quantiles(
            (float("nan") if s.step_first is None else float(s.step_first)
             for s in self.sessions.values()), qs)

    def queue_wait_quantiles(self, qs=(0.5, 0.95)) -> dict[float, float]:
        """Submit-to-admission: the scheduling share of TTFT, separated so
        prefill cost and queueing backpressure are distinguishable."""
        return self._quantiles(
            (s.queue_wait for s in self.sessions.values()), qs)
