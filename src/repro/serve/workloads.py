"""Serving workloads beyond LM chat, through the unchanged ServeEngine.

The engine core never changes for a new workload — that is the point of
the block-contract registry (DESIGN.md §16).  A workload is a thin driver
that maps its domain requests onto :class:`repro.serve.session.Request`
objects and interprets the emitted tokens:

:class:`TranscriptionService`
    Streaming audio transcription on an enc-dec arch (whisper-tiny): each
    :class:`TranscriptStream` window becomes one session whose ctx is the
    window's frames (encoded once at admission) and whose prompt carries
    the tail of the transcript so far — incremental decoding.  Windows of
    one stream are sequential; windows of different streams interleave in
    the slot pool.  Sampling rides the engine's (rid, step) seed-folding,
    so transcripts are schedule-independent: any slot count yields the
    same tokens.

:class:`ClassifierService`
    The paper's XNOR-CNN image classification (Fig. 6) as a batched
    service: one-shot sessions (one QUERY_TOKEN prompt, image patches as
    ctx, ``max_new_tokens=1``), greedy sampling — the emitted token IS the
    class id.  With ``pack=True`` the resident weights are the packed
    XNOR bit-planes, so every classification runs the paper's in-memory
    popcount GEMM; packed and float-sign paths are bit-exact.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from repro.serve.engine import ServeEngine
from repro.serve.session import Request, TranscriptStream


class TranscriptionService:
    """Streaming transcription driver over one enc-dec serve engine.

    ``carry`` trailing transcript tokens condition each next window (the
    incremental-decode contract); ``tokens_per_window`` is each window's
    generation budget (eos is disabled so budgets — and with them prompt
    shapes — are schedule-independent).  One engine is built per
    :meth:`transcribe` call: window rids are derived from stream ids, so a
    fresh call gets a fresh rid space.
    """

    _RID_STRIDE = 1 << 20              # rid = sid * stride + window index

    def __init__(self, cfg, params, *, slots: int = 4, s_max: int = 32,
                 tokens_per_window: int = 4, carry: int = 8,
                 temperature: float = 0.8, seed: int = 0, bos_id: int = 1,
                 **engine_kw: Any):
        if not cfg.is_encdec():
            raise ValueError(f"transcription needs an enc-dec arch, "
                             f"got {cfg.name}")
        if 1 + carry + tokens_per_window - 1 > s_max:
            raise ValueError(f"carry={carry} + budget={tokens_per_window} "
                             f"does not fit s_max={s_max}")
        self.cfg = cfg
        self.params = params
        self.tokens_per_window = tokens_per_window
        self.carry = carry
        self.bos_id = bos_id
        self._engine_kw = dict(slots=slots, s_max=s_max, eos_id=None,
                               temperature=temperature, seed=seed,
                               **engine_kw)
        self.stats = None              # EngineStats of the last transcribe()

    def _prompt(self, transcript: list[int]) -> np.ndarray:
        return np.asarray([self.bos_id] + transcript[-self.carry:], np.int32)

    def transcribe(self, streams: list[TranscriptStream]) -> dict[int, list[int]]:
        """Drain every stream; returns {sid: transcript token list}.

        The loop submits each stream's next window as soon as its previous
        one finishes, then advances the engine one step — so transcription
        is genuinely incremental (a window's prompt does not exist until
        its predecessor's tokens do) while the engine keeps every slot as
        busy as the dependency chains allow.
        """
        engine = ServeEngine(self.cfg, self.params, **self._engine_kw)
        streams = sorted(streams, key=lambda s: s.sid)
        if len({s.sid for s in streams}) != len(streams):
            raise ValueError("duplicate stream ids")
        transcripts: dict[int, list[int]] = {s.sid: [] for s in streams}
        nxt = {s.sid: 0 for s in streams}
        busy: set[int] = set()         # sids with a window in flight
        inflight: dict[int, int] = {}  # rid -> sid

        def submit_ready():
            for s in streams:
                if s.sid in busy or nxt[s.sid] >= len(s.windows):
                    continue
                w = nxt[s.sid]
                rid = s.sid * self._RID_STRIDE + w
                engine.submit(Request(
                    rid=rid, prompt=self._prompt(transcripts[s.sid]),
                    max_new_tokens=self.tokens_per_window,
                    ctx=np.asarray(s.windows[w], np.float32)))
                inflight[rid] = s.sid
                busy.add(s.sid)
                nxt[s.sid] = w + 1

        submit_ready()
        while inflight:
            engine.step()
            done = [rid for rid in inflight if engine.sessions[rid].done]
            for rid in done:
                sid = inflight.pop(rid)
                busy.discard(sid)
                transcripts[sid].extend(engine.sessions[rid].tokens)
            if done:
                submit_ready()
        self.stats = engine.stats
        return transcripts


class ClassifierService:
    """Batched XNOR-CNN classification behind the serve admission/slot
    machinery.  One persistent engine: requests are one-shot (finished at
    the prefill sample), so slots turn over every step and a batch of
    images drains in ~ceil(n/slots) engine steps."""

    def __init__(self, cfg=None, params=None, *, slots: int = 4,
                 s_max: int = 8, pack: bool = True, seed: int = 0,
                 train_steps: int = 150, **engine_kw: Any):
        from repro import configs
        from repro.models import bcnn
        self._bcnn = bcnn
        self.cfg = cfg if cfg is not None else configs.get("xnor-cnn")
        self.train_acc = None
        if params is None:
            params, self.train_acc = bcnn.train_classifier(
                self.cfg, steps=train_steps, seed=seed)
        self.params = params
        self.engine = ServeEngine(self.cfg, self.params, slots=slots,
                                  s_max=s_max, eos_id=None, temperature=0.0,
                                  pack=pack, seed=seed, **engine_kw)
        self._next_rid = 0

    def classify(self, images) -> np.ndarray:
        """(N, H, W) images -> (N,) predicted class ids (greedy argmax
        tokens; deterministic — temperature is pinned to 0)."""
        ctx = self._bcnn.image_ctx(self.cfg, images)
        prompt = np.asarray([self._bcnn.QUERY_TOKEN], np.int32)
        rid0 = self._next_rid
        for i in range(ctx.shape[0]):
            self.engine.submit(Request(rid=rid0 + i, prompt=prompt,
                                       max_new_tokens=1, ctx=ctx[i]))
        self._next_rid += ctx.shape[0]
        while self.engine.step():
            pass
        return np.asarray(
            [self.engine.sessions[rid0 + i].tokens[0]
             for i in range(ctx.shape[0])], np.int32)

    @property
    def stats(self):
        return self.engine.stats
