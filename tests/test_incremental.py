"""Incremental verification (DESIGN.md §12): the ChunkedDigest fold
invariant, the engine's chunk-level digest export, and the DigestCache's
O(dirty-chunks) dispatch contract — asserted via EngineStats cycle counts,
the acceptance criterion of the subsystem — on both engine classes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import verify
from repro.core.engine import BankGeometry, CimEngine, ShardedCimEngine
from repro.core.incremental import ChunkedDigest, DigestCache
from repro.kernels import ops
from repro.launch import mesh as mesh_mod

RNG = np.random.default_rng(0)

CHUNK = 256  # words per chunk (multiple of DIGEST_WIDTH)


def _engine(kind: str) -> CimEngine:
    if kind == "sharded":
        return ShardedCimEngine(mesh_mod.make_engine_mesh(), impl="ref")
    return CimEngine(impl="ref")


def _words(n: int) -> jnp.ndarray:
    return jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))


def _flip_chunk(buf: jnp.ndarray, i: int, chunk: int = CHUNK) -> jnp.ndarray:
    """New buffer differing from ``buf`` in exactly chunk i (one bit)."""
    pos = min(i * chunk, buf.shape[0] - 1)
    return buf.at[pos].set(buf[pos] ^ jnp.uint32(1))


# ---------------------------------------------------------------------------
# ChunkedDigest: the fold invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,chunk,width", [(5000, 512, 128), (1, 256, 128),
                                           (4096, 4096, 128), (777, 384, 96),
                                           (100001, 1024, 128)])
def test_chunked_digest_fold_equals_one_shot(n, chunk, width):
    eng = CimEngine(impl="ref")
    buf = _words(n)
    cd = ChunkedDigest.compute(buf, eng, chunk_words=chunk, digest_width=width)
    assert cd.chunks.shape == (max(1, -(-n // chunk)), width)
    assert cd.nwords == n
    assert np.array_equal(cd.digest(),
                          np.asarray(ops.digest(buf, width, impl="ref")))


def test_chunked_digest_rows_match_slice_digests():
    eng = CimEngine(impl="ref")
    buf = _words(1000)
    cd = ChunkedDigest.compute(buf, eng, chunk_words=CHUNK)
    for i in range(cd.n_chunks):
        want = ops.digest(buf[i * CHUNK:(i + 1) * CHUNK], impl="ref")
        assert np.array_equal(cd.chunks[i], np.asarray(want)), i


def test_chunked_digest_diff_localizes_corruption():
    eng = CimEngine(impl="ref")
    buf = _words(4 * CHUNK)
    cd0 = ChunkedDigest.compute(buf, eng, chunk_words=CHUNK)
    cd1 = ChunkedDigest.compute(_flip_chunk(buf, 2), eng, chunk_words=CHUNK)
    assert np.array_equal(cd0.diff(cd1), [2])
    with pytest.raises(ValueError, match="chunk layouts"):
        cd0.diff(ChunkedDigest.compute(buf, eng, chunk_words=2 * CHUNK))


@pytest.mark.parametrize("kind", ["single", "sharded"])
def test_digest_chunks_engine_export(kind):
    """The engine-level export used by ChunkedDigest.compute: per-row equals
    the per-slice digest, on both engine classes."""
    eng = _engine(kind)
    buf = _words(3 * CHUNK + 17)
    rows = np.asarray(eng.digest_chunks(buf, CHUNK))
    assert rows.shape == (4, verify.DIGEST_WIDTH)
    single = CimEngine(impl="ref")
    for i in range(4):
        want = single.digest(buf[i * CHUNK:(i + 1) * CHUNK])
        assert np.array_equal(rows[i], np.asarray(want)), i


# ---------------------------------------------------------------------------
# DigestCache: digests bit-identical to the full scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["single", "sharded"])
def test_cache_digests_match_tree_digest(kind):
    tree = {"w": jnp.asarray(RNG.standard_normal((64, 33)), jnp.float32),
            "u": _words(1000),
            "inner": {"b": jnp.asarray(RNG.standard_normal(129),
                                       jnp.float32)}}
    cache = DigestCache(engine=_engine(kind), chunk_words=CHUNK)
    got = verify.tree_digest(tree, cache=cache)
    want = verify.tree_digest(tree, impl="ref")
    for k in ("w", "u"):
        assert np.array_equal(np.asarray(got[k]), np.asarray(want[k])), k
    assert np.array_equal(np.asarray(got["inner"]["b"]),
                          np.asarray(want["inner"]["b"]))
    assert cache.last.new_leaves == 3 and len(cache) == 3


# ---------------------------------------------------------------------------
# the dispatch contract: O(dirty-chunks) engine cycles (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["single", "sharded"])
def test_clean_reverify_dispatches_nothing(kind):
    eng = _engine(kind)
    cache = DigestCache(engine=eng, chunk_words=CHUNK)
    tree = {"a": _words(10 * CHUNK), "b": _words(3 * CHUNK + 5)}
    d0 = cache.digests(tree)
    snap = eng.stats.snapshot()
    d1 = cache.digests(tree)            # same leaf objects: identity hits
    assert eng.stats.cycles == snap.cycles
    assert eng.stats.calls == snap.calls
    assert cache.last.dirty_chunks == 0
    assert cache.last.clean_leaves == 2
    for k in tree:
        assert np.array_equal(d0[k], d1[k])


@pytest.mark.parametrize("kind", ["single", "sharded"])
@pytest.mark.parametrize("dirty", [[0], [3], [0, 7, 9], [2, 3, 4]])
def test_dirty_chunks_dispatch_exactly_those_chunks(kind, dirty):
    """k dirty chunks -> exactly k digest dispatches of one chunk each,
    cycle-counted as k * cycles_for(chunk bits) — not O(tree)."""
    eng = _engine(kind)
    cache = DigestCache(engine=eng, chunk_words=CHUNK)
    n_chunks = 10
    tree = {"a": _words(n_chunks * CHUNK)}
    cache.digests(tree)

    buf = tree["a"]
    for i in dirty:
        buf = _flip_chunk(buf, i)
    snap = eng.stats.snapshot()
    got = cache.digests({"a": buf})

    k = len(dirty)
    assert cache.last.dirty_chunks == k
    assert cache.last.chunks == n_chunks
    per_chunk = eng.cycles_for(CHUNK * 32)
    assert eng.stats.cycles - snap.cycles == k * per_chunk
    assert eng.stats.by_op["digest"][2] - snap.by_op["digest"][2] == k
    # and the incrementally-updated digest is still the true digest
    assert np.array_equal(got["a"],
                          np.asarray(ops.digest(buf, impl="ref")))


def test_dirty_chunk_count_property():
    """Property sweep: for random buffers/dirty sets, the cache re-digests
    exactly the dirty chunks and stays bit-identical to a fresh scan."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        n_chunks = int(rng.integers(2, 12))
        tail = int(rng.integers(1, CHUNK))
        n = (n_chunks - 1) * CHUNK + tail
        eng = CimEngine(impl="ref")
        cache = DigestCache(engine=eng, chunk_words=CHUNK)
        buf = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
        cache.digests({"x": buf})

        k = int(rng.integers(0, n_chunks + 1))
        dirty = sorted(rng.choice(n_chunks, size=k, replace=False).tolist())
        new = buf
        for i in dirty:
            pos = int(rng.integers(i * CHUNK, min((i + 1) * CHUNK, n)))
            new = new.at[pos].set(new[pos] ^ jnp.uint32(1))
        snap = eng.stats.snapshot()
        got = cache.digests({"x": new})
        assert cache.last.dirty_chunks == k, (seed, dirty)
        want = sum(eng.cycles_for(32 * (min((i + 1) * CHUNK, n) - i * CHUNK))
                   for i in dirty)
        assert eng.stats.cycles - snap.cycles == want, (seed, dirty)
        assert np.array_equal(got["x"], np.asarray(ops.digest(new,
                                                              impl="ref")))


def test_shape_change_triggers_full_recompute():
    eng = CimEngine(impl="ref")
    cache = DigestCache(engine=eng, chunk_words=CHUNK)
    cache.digests({"x": _words(4 * CHUNK)})
    buf2 = _words(6 * CHUNK)
    got = cache.digests({"x": buf2})
    assert cache.last.new_leaves == 1
    assert cache.last.dirty_chunks == 6
    assert np.array_equal(got["x"], np.asarray(ops.digest(buf2, impl="ref")))


def test_cache_handles_float_leaves_and_scalars():
    eng = CimEngine(impl="ref")
    cache = DigestCache(engine=eng, chunk_words=CHUNK)
    tree = {"w": jnp.asarray(RNG.standard_normal((65, 31)), jnp.float32),
            "s": jnp.uint32(7)}
    got = cache.digests(tree)
    want = verify.tree_digest(tree, impl="ref")
    assert np.array_equal(got["w"], np.asarray(want["w"]))
    assert np.array_equal(got["s"], np.asarray(want["s"]))
    # perturb one element: exactly that chunk re-digests
    w2 = tree["w"].at[64, 30].set(0.0)
    snap = eng.stats.snapshot()
    got2 = cache.digests({"w": w2, "s": tree["s"]})
    assert cache.last.dirty_chunks == 1
    assert eng.stats.calls - snap.calls == 1
    assert np.array_equal(got2["w"],
                          np.asarray(verify.tree_digest({"w": w2},
                                                        impl="ref")["w"]))


def test_inplace_numpy_mutation_is_detected():
    """numpy leaves must never take the identity fast path: an in-place
    update under the same object identity is still found by the word-compare
    tier — including through a read-only view whose writable base mutates
    (writability flags prove nothing).  jax arrays are immutable and keep
    the fast path."""
    eng = CimEngine(impl="ref")
    cache = DigestCache(engine=eng, chunk_words=CHUNK)
    w = np.arange(2 * CHUNK, dtype=np.uint32)
    cache.digests({"w": w})
    w[0] ^= 1                              # same object, new bytes
    got = cache.digests({"w": w})
    assert cache.last.clean_leaves == 0
    assert cache.last.dirty_chunks == 1
    assert np.array_equal(
        got["w"], np.asarray(ops.digest(jnp.asarray(w), impl="ref")))

    base = np.arange(2 * CHUNK, dtype=np.uint32)
    frozen = base.view()
    frozen.flags.writeable = False         # read-only view, writable base
    cache.digests({"v": frozen})
    base[CHUNK] ^= 1                       # mutate THROUGH the base
    got = cache.digests({"v": frozen})
    assert cache.last.dirty_chunks == 1
    assert np.array_equal(got["v"], verify.np_digest(np.asarray(frozen)))


def test_cache_is_byte_true_for_64bit_numpy_leaves():
    """float64/int64 numpy leaves must digest their true bytes — jnp.asarray
    would silently downcast them with x64 off and the cache's digests would
    disagree with the checkpoint manifest's np_digest."""
    eng = CimEngine(impl="ref")
    cache = DigestCache(engine=eng, chunk_words=CHUNK)
    tree = {"d": np.arange(300, dtype=np.float64) * 0.5,
            "i": np.arange(100, dtype=np.int64)}
    got = cache.digests(tree)
    uncached = verify.tree_digest(tree, impl="ref")
    for k in tree:
        assert np.array_equal(got[k], verify.np_digest(tree[k])), k
        # and the UNCACHED engine scan agrees (as_words host byte view)
        assert np.array_equal(got[k], np.asarray(uncached[k])), k
    # in-place 64-bit update: found, and still byte-true
    tree["d"][7] = -1.0
    got = cache.digests(tree)
    assert cache.last.dirty_chunks == 1
    assert np.array_equal(got["d"], verify.np_digest(tree["d"]))


def test_cache_does_not_pin_host_leaves():
    """_Entry must not retain numpy leaf objects (identity never trusts
    them): memory cost stays at the documented one snapshot copy."""
    cache = DigestCache(engine=CimEngine(impl="ref"), chunk_words=CHUNK)
    w = np.arange(CHUNK, dtype=np.uint32)
    j = _words(CHUNK)
    cache.digests({"w": w, "j": j})
    assert cache._entries["w"].leaf is None
    assert cache._entries["j"].leaf is j
    # per-leaf change evidence: exact counts per pass
    w[3] ^= 1
    cache.digests({"w": w, "j": j})
    assert cache.last_leaf_dirty == {"w": 1}


def test_cache_bookkeeping():
    cache = DigestCache(engine=CimEngine(impl="ref"), chunk_words=CHUNK)
    cache.digests({"x": _words(2 * CHUNK)})
    cd = cache.chunk_digests("x")
    assert cd is not None and cd.n_chunks == 2
    assert cache.chunk_digests("y") is None
    cache.drop("x")
    assert len(cache) == 0
    cache.digests({"x": _words(CHUNK)})
    cache.clear()
    assert len(cache) == 0


# ---------------------------------------------------------------------------
# the scrub workload: verify_trees with per-tree caches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["single", "sharded"])
def test_incremental_scrub_detects_backup_divergence(kind):
    eng = _engine(kind)
    src = {"a": _words(8 * CHUNK), "b": _words(3 * CHUNK)}
    bak = {k: jnp.array(v) for k, v in src.items()}   # the backup copy
    ca, cb = (DigestCache(engine=eng, chunk_words=CHUNK) for _ in range(2))
    ok, _ = verify.verify_trees(src, bak, cache_a=ca, cache_b=cb)
    assert bool(ok)
    snap = eng.stats.snapshot()
    ok, _ = verify.verify_trees(src, bak, cache_a=ca, cache_b=cb)
    assert bool(ok) and eng.stats.cycles == snap.cycles   # clean re-scrub

    src2 = {"a": _flip_chunk(src["a"], 5), "b": src["b"]}  # source moved on
    snap = eng.stats.snapshot()
    ok, leaf_ok = verify.verify_trees(src2, bak, cache_a=ca, cache_b=cb)
    assert not bool(ok)
    assert not bool(leaf_ok["a"]) and bool(leaf_ok["b"])
    assert eng.stats.cycles - snap.cycles == eng.cycles_for(CHUNK * 32)


def test_cache_conflicts_are_refused():
    """A shared cache across verify_trees' two trees, or a tree_digest
    engine= that isn't the cache's, silently defeats the incremental
    contract — both must raise."""
    eng = CimEngine(impl="ref")
    cache = DigestCache(engine=eng, chunk_words=CHUNK)
    tree = {"x": _words(CHUNK)}
    with pytest.raises(ValueError, match="distinct"):
        verify.verify_trees(tree, tree, cache_a=cache, cache_b=cache)
    with pytest.raises(ValueError, match="conflict"):
        verify.tree_digest(tree, engine=CimEngine(impl="ref"), cache=cache)
    with pytest.raises(ValueError, match="chunk_words"):
        verify.tree_digest(tree, chunk_words=2 * CHUNK, cache=cache)
    with pytest.raises(ValueError, match="impl"):
        verify.tree_digest(tree, "interpret", cache=cache)
    with pytest.raises(ValueError, match="digest_width"):
        verify.tree_digest(tree, cache=DigestCache(
            engine=eng, chunk_words=CHUNK, digest_width=96))
    # the cache's own engine (or none) is fine
    verify.tree_digest(tree, engine=eng, cache=cache)
    verify.tree_digest(tree, cache=cache)


def test_cache_geometry_scales_dispatch():
    """More banks -> fewer cycles for the same dirty chunk: the incremental
    path inherits the bank-scaling model."""
    chunk = 1 << 16                    # big enough that ceil() divides evenly
    buf = _words(4 * chunk)
    new = _flip_chunk(buf, 3, chunk)
    cyc = []
    for banks in (1, 8):
        eng = CimEngine(BankGeometry(banks=banks), impl="ref")
        cache = DigestCache(engine=eng, chunk_words=chunk)
        cache.digests({"x": buf})
        snap = eng.stats.snapshot()
        cache.digests({"x": new})
        cyc.append(eng.stats.cycles - snap.cycles)
    assert cyc[0] == 8 * cyc[1]
