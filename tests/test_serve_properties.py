"""Hypothesis property tests for the serve-layer block bookkeeping.

Random interleavings of alloc / share / COW-drop / free / cache / evict
against :class:`BlockPool` must preserve the DESIGN.md §15 invariants:

* conservation — free + idle + held partition the allocatable pool;
* refcount(b) == number of requests holding b (no double-free: a block
  re-enters the free list exactly once, when its last holder releases);
* a *writable* block (refcount 1, uncached) has exactly one owner — which
  is refcount 1 by definition, so sharing can never yield two writers;
* the trash block 0 is never allocated, shared, cached, idled, or freed;
* allocation stays lowest-id-first and eviction least-recently-idle-first
  (the determinism the whole engine inherits).

Mirrors the tests/test_kernels_properties.py pattern: the importorskip
guard keeps bare environments green (requirements-dev.txt pins hypothesis
for CI)."""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve import BlockPool  # noqa: E402


def _check_invariants(pool: BlockPool, holders: dict, cached: set) -> None:
    """Cross-check the pool against an independently maintained model."""
    free = pool.free_blocks
    idle = set(pool.idle_blocks)
    held = {b for ids in holders.values() for b in ids}
    # trash block 0 never surfaces anywhere
    assert 0 not in free and 0 not in idle and 0 not in held
    # free / idle / held partition the allocatable pool exactly
    assert not (set(free) & idle) and not (set(free) & held)
    assert not (idle & held)
    assert len(free) + len(idle) + len(held) == pool.capacity
    assert pool.available == len(free)
    assert pool.idle == len(idle)
    assert pool.in_use == len(held)
    assert pool.reclaimable == len(free) + len(idle)
    # free list sorted and duplicate-free (free count conserved)
    assert free == sorted(set(free))
    # refcounts equal the model's holder counts; idle blocks are cached
    for bid in range(1, pool.n_blocks):
        assert pool.refcount(bid) == \
            sum(bid in ids for ids in holders.values())
        assert pool.is_idle(bid) == (bid in idle)
    for bid in idle:
        assert pool.cached(bid)
    for bid in cached & held:
        assert pool.cached(bid)


@given(st.integers(3, 20), st.data())
@settings(max_examples=50, deadline=None)
def test_block_pool_random_interleavings_preserve_invariants(n_blocks, data):
    pool = BlockPool(n_blocks)
    holders: dict[int, list[int]] = {}       # rid -> blocks it holds
    cached: set[int] = set()
    next_rid = 0
    for step in range(data.draw(st.integers(1, 30), label="n_ops")):
        shareable = sorted(
            set(b for ids in holders.values() for b in ids)
            | set(pool.idle_blocks))
        ops = ["alloc", "free_unknown"]
        if holders:
            ops += ["free", "drop", "cache"]
        if shareable:
            ops.append("share")
        if pool.idle:
            ops.append("evict")
        op = data.draw(st.sampled_from(ops), label=f"op{step}")

        if op == "alloc":
            n = data.draw(st.integers(0, pool.available), label="n")
            expect = pool.free_blocks[:n]    # lowest-id-first, always
            rid = next_rid
            next_rid += 1
            got = pool.alloc(rid, n)
            assert got == expect
            if got:
                holders.setdefault(rid, []).extend(got)
        elif op == "share":
            rid = data.draw(
                st.sampled_from(sorted(holders) + [next_rid]), label="rid")
            mine = set(holders.get(rid, []))
            pickable = [b for b in shareable if b not in mine]
            if pickable:
                take = data.draw(
                    st.sets(st.sampled_from(pickable), min_size=1),
                    label="blocks")
                if rid == next_rid:
                    next_rid += 1
                pool.share(rid, sorted(take))
                holders.setdefault(rid, []).extend(sorted(take))
        elif op == "free":
            rid = data.draw(st.sampled_from(sorted(holders)), label="rid")
            assert pool.free(rid) == len(holders.pop(rid))
            assert pool.free(rid) == 0       # no double-free: second is a no-op
        elif op == "free_unknown":
            assert pool.free(10_000 + step) == 0
        elif op == "drop":
            rid = data.draw(st.sampled_from(sorted(holders)), label="rid")
            bid = data.draw(st.sampled_from(holders[rid]), label="bid")
            pool.drop(rid, bid)
            holders[rid].remove(bid)
            if not holders[rid]:
                del holders[rid]
        elif op == "cache":
            rid = data.draw(st.sampled_from(sorted(holders)), label="rid")
            bid = data.draw(st.sampled_from(holders[rid]), label="bid")
            pool.set_cached(bid)
            cached.add(bid)
        elif op == "evict":
            k = data.draw(st.integers(1, pool.idle), label="k")
            expect = pool.idle_blocks[:k]    # least-recently-idle-first
            got = pool.evict_idle(k)
            assert got == expect
            for bid in got:
                assert not pool.cached(bid)
                cached.discard(bid)

        _check_invariants(pool, holders, cached)

    # drain: releasing every holder leaves zero blocks in use and every
    # block accounted for (free or parked idle awaiting eviction)
    for rid in list(holders):
        pool.free(rid)
        holders.pop(rid)
    _check_invariants(pool, holders, cached)
    assert pool.in_use == 0
    assert pool.available + pool.idle == pool.capacity
