"""Distributed machinery on a small in-process device grid (subprocess so
the 1-device assumption of the rest of the suite is preserved)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, devices: int = 8, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_small_mesh_train_step_shards_and_runs():
    """Real multi-device execution: sharded train step on a 2x2x2 mesh
    matches the single-device loss."""
    r = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.configs as configs
        from repro.launch import mesh as mesh_mod
        from repro.models import lm
        from repro.train import train_step as train_mod
        from repro.distributed import sharding
        from repro.distributed.ctx import activation_rules

        cfg = configs.get("qwen2-7b").smoke(n_kv_heads=2)
        mesh = mesh_mod.make_smoke_mesh(8)  # (pod, data, model) = (2, 2, 2)
        state = train_mod.init_state(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens,
                 "labels": jnp.concatenate([tokens[:, 1:],
                          -jnp.ones((8, 1), jnp.int32)], 1)}

        rules = dict(sharding.DEFAULT_RULES)
        sspec = train_mod.state_pspecs(cfg, rules)
        bspec = sharding.data_specs(mesh, 8)
        act = {"batch": sharding.batch_axes(mesh, 8), "tp": "model",
               "ep": "model"}
        with mesh, activation_rules(act):
            f = jax.jit(lambda s, b, i: train_mod.train_step(cfg, s, b, i),
                        in_shardings=(sharding.tree_named(mesh, sspec),
                                      sharding.tree_named(mesh, bspec),
                                      NamedSharding(mesh, P())),
                        )
            new_state, metrics = f(state, batch, jnp.asarray(0, jnp.int32))
            sharded_loss = float(metrics["loss"])

        # single-logical-device reference
        st2, m2 = jax.jit(lambda s, b, i: train_mod.train_step(cfg, s, b, i))(
            state, batch, jnp.asarray(0, jnp.int32))
        ref_loss = float(m2["loss"])
        assert abs(sharded_loss - ref_loss) < 5e-2, (sharded_loss, ref_loss)
        print("OK", sharded_loss, ref_loss)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_onebit_pod_compression_lowers_with_allgather():
    """The 1-bit majority-vote exchange must (a) move only uint32 planes
    across the pod axis (u32 all-gather in the HLO) and (b) reconstruct the
    majority sign exactly.  (Tested on the collective directly: the
    full-model composition under manual-pod shard_map trips an XLA:CPU
    PartitionGather crash on toy meshes — the 512-device dry-run exercises
    the full path.)"""
    r = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np, re
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import mesh as mesh_mod
        from repro.distributed import sharding
        from repro.train.train_step import _onebit_pod_allreduce

        mesh = mesh_mod.make_smoke_mesh(8)   # (pod, data, model) = (2,2,2)
        grads = jnp.linspace(-1.0, 1.0, 2 * 64).reshape(2, 64)

        # fully manual: the isolated collective only uses "pod", and partial
        # manual subgroups crash the old XLA:CPU SPMD partitioner.
        sharded = sharding.shard_map(
            _onebit_pod_allreduce, mesh,
            in_specs=P("pod", None), out_specs=P("pod", None),
            manual_axes=set(mesh.axis_names))
        with mesh:
            compiled = jax.jit(sharded).lower(grads).compile()
        txt = compiled.as_text()
        assert re.search(r"u32[\\[][0-9,]*[\\]].*all-gather", txt), \\
            "expected uint32 plane all-gathers inter-pod"
        out = compiled(grads)
        # output is +-(mean of per-pod L1 scales): exactly two magnitudes
        vals = np.unique(np.round(np.abs(np.asarray(out, np.float32)), 6))
        assert out.shape == (2, 64) and len(vals) <= 2
        print("OK")
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_sharded_engine_property_sweep_8way():
    """Acceptance property (DESIGN.md §11): on a real 8-way host-device
    mesh, sharded digest/xor/stream_cipher are bit-identical to the
    single-device engine across randomized sizes, digest widths, and
    counters, and the sharded cycle model is exactly devices x faster."""
    r = _run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.engine import BankGeometry, CimEngine, ShardedCimEngine
        from repro.launch.mesh import make_engine_mesh

        mesh = make_engine_mesh(8)
        eng = ShardedCimEngine(mesh, impl="ref")
        ref = CimEngine(impl="ref")
        assert eng.geometry.devices == 8
        rng = np.random.default_rng(0)
        for case in range(20):
            n = int(rng.integers(1, 200_000))
            width = int(rng.choice([32, 96, 128, 256]))
            ctr = int(rng.integers(0, 2**32))
            a = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
            b = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
            key = jnp.asarray(rng.integers(0, 2**32, 2, dtype=np.uint32))
            assert np.array_equal(np.asarray(eng.xor(a, b)),
                                  np.asarray(ref.xor(a, b))), case
            assert np.array_equal(np.asarray(eng.digest(a, width)),
                                  np.asarray(ref.digest(a, width))), case
            enc = eng.stream_cipher(a, key, counter=ctr)
            assert np.array_equal(
                np.asarray(enc),
                np.asarray(ref.stream_cipher(a, key, counter=ctr))), case
            assert np.array_equal(
                np.asarray(eng.stream_cipher(enc, key, counter=ctr)),
                np.asarray(a)), case
        assert ref.cycles_for(1 << 22) == 8 * eng.cycles_for(1 << 22)
        print("OK")
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_dryrun_cell_end_to_end_small():
    """The dryrun driver itself (512 virtual devices) on the cheapest cell."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = os.path.join(ROOT, "experiments", "dryrun_test")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-tiny",
         "--shape", "decode_32k", "--mesh", "multi", "--out", out,
         "--tag", "unittest"],
        capture_output=True, text=True, env=env, timeout=900, cwd=ROOT)
    assert r.returncode == 0, r.stderr[-2000:]
    path = os.path.join(out, "whisper-tiny_decode_32k_multi_unittest.json")
    res = json.load(open(path))
    assert res["status"] == "ok"
    assert res["n_devices"] == 512
    assert res["memory_analysis"]["peak_bytes"] < 16e9  # fits v5e HBM
