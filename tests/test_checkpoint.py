"""Checkpoint integrity: XOR-parity write/read verification (paper Fig. 1(a)),
XOR encryption round-trip (Fig. 1(b)), corruption detection, restart
orchestration, straggler policy."""

import os

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import encrypt, verify
from repro.distributed import fault

RNG = np.random.default_rng(0)


@pytest.fixture
def tree():
    return {"w": RNG.standard_normal((32, 16)).astype(np.float32),
            "inner": {"b": RNG.standard_normal(7).astype(np.float16),
                      "steps": np.arange(5, dtype=np.int32)}}


def _like(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


@pytest.mark.parametrize("root_key", [None, "hunter2"])
def test_save_restore_roundtrip(tmp_path, tree, root_key):
    ckpt.save(str(tmp_path), 7, tree, root_key=root_key)
    out, step = ckpt.restore(str(tmp_path), None, _like(tree),
                             root_key=root_key)
    assert step == 7
    assert np.array_equal(out["w"], tree["w"])
    assert np.array_equal(out["inner"]["b"], tree["inner"]["b"])
    assert np.array_equal(out["inner"]["steps"], tree["inner"]["steps"])


def test_encrypted_payload_is_scrambled(tmp_path, tree):
    ckpt.save(str(tmp_path), 1, tree, root_key="k", verify_write=False)
    data = np.load(str(tmp_path / "ckpt_00000001.npz"))
    # stored bytes must NOT equal plaintext
    stored = data["w"]
    assert stored.dtype == np.uint8
    assert not np.array_equal(stored.view(np.float32).reshape(32, 16),
                              tree["w"])


def test_parity_detects_tampered_leaf(tmp_path, tree):
    """Tamper inside a valid container: our parity check (not the zip CRC)
    must catch it."""
    ckpt.save(str(tmp_path), 3, tree)
    path = str(tmp_path / "ckpt_00000003.npz")
    data = dict(np.load(path))
    tampered = data["w"].copy()
    tampered.view(np.uint32)[5] ^= 1 << 12        # one flipped bit
    data["w"] = tampered
    with open(path, "wb") as f:
        np.savez(f, **data)
    ok, bad = ckpt.check(str(tmp_path), 3)
    assert not ok and bad == ["w"]
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 3, _like(tree))


def test_runner_falls_back_on_corruption(tmp_path, tree):
    r = fault.Runner(str(tmp_path), save_every=1)
    ckpt.save(str(tmp_path), 1, tree)
    tree2 = jax.tree.map(lambda a: a + 1 if a.dtype.kind == "f" else a, tree)
    ckpt.save(str(tmp_path), 2, tree2)
    # corrupt step 2 in-place (valid zip, bad parity)
    path = str(tmp_path / "ckpt_00000002.npz")
    data = dict(np.load(path))
    data["w"].view(np.uint32)[0] ^= 1
    with open(path, "wb") as f:
        np.savez(f, **data)
    state, step = r.resume_or_init(_like(tree), lambda: tree)
    assert step == 1                      # fell back past the corrupt ckpt
    assert np.array_equal(state["w"], tree["w"])


def test_runner_gc_keeps_last(tmp_path, tree):
    r = fault.Runner(str(tmp_path), save_every=1, keep_last=2)
    for s in (1, 2, 3, 4):
        r.maybe_save(s, tree)
    assert r._steps() == [3, 4]


def test_straggler_policy_three_strikes():
    pol = fault.StragglerPolicy(straggler_factor=2.0, max_strikes=3)
    for i in range(10):
        assert pol.observe(i, 1.0) == "ok"
    assert pol.observe(10, 5.0) == "straggler"
    assert pol.observe(11, 5.0) == "straggler"
    assert pol.observe(12, 5.0) == "reshard"
    assert pol.strikes == 0               # reset after reshard


def test_encrypted_checkpoint_requires_root_key(tmp_path, tree):
    """Missing key on an encrypted checkpoint must be a clear ValueError,
    not an AttributeError from inside derive_key."""
    ckpt.save(str(tmp_path), 2, tree, root_key="hunter2")
    with pytest.raises(ValueError, match="root_key"):
        ckpt.check(str(tmp_path), 2)
    with pytest.raises(ValueError, match="root_key"):
        ckpt.restore(str(tmp_path), 2, _like(tree))
    # unencrypted checkpoints keep working without a key
    ckpt.save(str(tmp_path / "plain"), 2, tree)
    ok, bad = ckpt.check(str(tmp_path / "plain"), 2)
    assert ok and not bad


@pytest.mark.parametrize("root_key", [None, "hunter2"])
def test_bfloat16_leaf_roundtrip(tmp_path, root_key):
    """bfloat16 leaves: npz stores them as void records (_coerce path);
    composed with encrypt/decrypt they must still round-trip bit-exactly."""
    import ml_dtypes
    bf16 = np.dtype(ml_dtypes.bfloat16)
    tree = {"w": RNG.standard_normal((16, 8)).astype(bf16),
            "odd": RNG.standard_normal(33).astype(bf16),  # odd byte tail
            "f": RNG.standard_normal(5).astype(np.float32)}
    ckpt.save(str(tmp_path), 4, tree, root_key=root_key)
    ok, bad = ckpt.check(str(tmp_path), 4, root_key=root_key)
    assert ok, bad
    out, step = ckpt.restore(str(tmp_path), None, _like(tree),
                             root_key=root_key)
    assert step == 4
    for k in tree:
        assert out[k].dtype == tree[k].dtype, k
        assert np.array_equal(out[k].view(np.uint8), tree[k].view(np.uint8)), k


@pytest.mark.parametrize("root_key", [None, "hunter2"])
def test_device_side_ckpt_path_is_bit_identical_to_host(tmp_path, tree,
                                                        root_key):
    """save/check/restore with engine= (device digests + device cipher)
    must produce byte-identical manifests and cross-restore with the host
    path in both directions."""
    from repro.core.engine import CimEngine
    eng = CimEngine(impl="ref")
    m_dev = ckpt.save(str(tmp_path / "dev"), 5, tree, root_key=root_key,
                      engine=eng)
    m_host = ckpt.save(str(tmp_path / "host"), 5, tree, root_key=root_key)
    assert m_dev == m_host
    assert eng.stats.calls > 0            # digests/cipher ran on the engine
    # device-written -> host-read, host-written -> device-read
    out, _ = ckpt.restore(str(tmp_path / "dev"), 5, _like(tree),
                          root_key=root_key)
    assert np.array_equal(out["w"], tree["w"])
    out2, _ = ckpt.restore(str(tmp_path / "host"), 5, _like(tree),
                           root_key=root_key, engine=eng)
    assert np.array_equal(out2["inner"]["b"], tree["inner"]["b"])
    ok, bad = ckpt.check(str(tmp_path / "host"), 5, root_key=root_key,
                         engine=eng)
    assert ok, bad


# ---------------------------------------------------------------------------
# delta checkpoints (DESIGN.md §12): base+delta chains restore byte-identical
# to an equivalent full checkpoint, encrypted and plain, host and engine paths
# ---------------------------------------------------------------------------

def _engine(kind):
    if kind == "none":
        return None
    from repro.core.engine import CimEngine, ShardedCimEngine
    from repro.launch.mesh import make_engine_mesh
    if kind == "sharded":
        return ShardedCimEngine(make_engine_mesh(), impl="ref")
    return CimEngine(impl="ref")


def _step_trees(tree):
    """Three tree versions: base, one leaf changed, another leaf changed."""
    t2 = dict(tree, w=tree["w"] + 1)
    t3 = dict(t2, inner={"b": t2["inner"]["b"] * 2,
                         "steps": t2["inner"]["steps"]})
    return tree, t2, t3


@pytest.mark.parametrize("root_key", [None, "hunter2"])
@pytest.mark.parametrize("kind", ["none", "single", "sharded"])
def test_delta_chain_restore_matches_full(tmp_path, tree, root_key, kind):
    """Restoring base+delta+delta == restoring an equivalent full checkpoint,
    byte for byte — the acceptance criterion of the delta subsystem."""
    eng = _engine(kind)
    t1, t2, t3 = _step_trees(tree)
    ckpt.save(str(tmp_path / "chain"), 1, t1, root_key=root_key, engine=eng)
    ckpt.save_delta(str(tmp_path / "chain"), 2, t2, root_key=root_key,
                    engine=eng)
    ckpt.save_delta(str(tmp_path / "chain"), 3, t3, root_key=root_key,
                    engine=eng)
    ckpt.save(str(tmp_path / "full"), 3, t3, root_key=root_key)

    out_c, step = ckpt.restore(str(tmp_path / "chain"), None, _like(tree),
                               root_key=root_key, engine=eng)
    out_f, _ = ckpt.restore(str(tmp_path / "full"), 3, _like(tree),
                            root_key=root_key)
    assert step == 3
    for key in ("w",):
        assert out_c[key].tobytes() == out_f[key].tobytes()
    for key in ("b", "steps"):
        assert out_c["inner"][key].tobytes() == out_f["inner"][key].tobytes()
    ok, bad = ckpt.check(str(tmp_path / "chain"), 3, root_key=root_key,
                         engine=eng)
    assert ok, bad


def test_delta_npz_stores_only_moved_leaves(tmp_path, tree):
    t1, t2, t3 = _step_trees(tree)
    ckpt.save(str(tmp_path), 1, t1)
    m2 = ckpt.save_delta(str(tmp_path), 2, t2)
    assert set(np.load(str(tmp_path / "ckpt_00000002.npz")).files) == {"w"}
    assert m2["base_step"] == 1
    assert m2["leaves"]["w"]["stored_in"] == 2
    assert m2["leaves"]["inner/b"]["stored_in"] == 1
    m3 = ckpt.save_delta(str(tmp_path), 3, t3)      # chains onto the delta
    assert set(np.load(str(tmp_path / "ckpt_00000003.npz")).files) == \
        {"inner__b"}
    assert m3["base_step"] == 2
    assert m3["leaves"]["w"]["stored_in"] == 2       # one-hop resolution
    assert m3["leaves"]["inner/steps"]["stored_in"] == 1


def test_delta_write_verify_rechecks_only_written_leaves(tmp_path, tree):
    """Corrupt a base-stored leaf on disk between base and delta: the delta's
    write-verify (only-written leaves) must still pass, while a full chain
    check flags the corruption."""
    t1, t2, _ = _step_trees(tree)
    ckpt.save(str(tmp_path), 1, t1)
    path = str(tmp_path / "ckpt_00000001.npz")
    data = dict(np.load(path))
    tampered = data["inner__b"].copy()
    tampered.view(np.uint16)[0] ^= 1
    data["inner__b"] = tampered
    with open(path, "wb") as f:
        np.savez(f, **data)
    ckpt.save_delta(str(tmp_path), 2, t2)            # verify_write=True: OK
    ok, bad = ckpt.check(str(tmp_path), 2)           # full chain check: not OK
    assert not ok and bad == ["inner/b"]
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 2, _like(tree))


def test_delta_pad_keying_is_reuse_free(tmp_path, tree):
    """A leaf re-written at a later delta step with the SAME plaintext must
    produce different ciphertext (pad keyed by the write step)."""
    t1 = tree
    t2 = dict(tree, w=tree["w"] + 1)
    t3 = dict(tree, w=t1["w"])                       # w back to its t1 value
    ckpt.save(str(tmp_path), 1, t1, root_key="k")
    ckpt.save_delta(str(tmp_path), 2, t2, root_key="k")
    m3 = ckpt.save_delta(str(tmp_path), 3, t3, root_key="k")
    assert m3["leaves"]["w"]["stored_in"] == 3       # digest moved vs step 2
    c1 = np.load(str(tmp_path / "ckpt_00000001.npz"))["w"]
    c3 = np.load(str(tmp_path / "ckpt_00000003.npz"))["w"]
    assert not np.array_equal(c1, c3)                # fresh pad, same bytes in
    out, _ = ckpt.restore(str(tmp_path), 3, _like(tree), root_key="k")
    assert np.array_equal(out["w"], t1["w"])


def test_delta_with_digest_cache_matches_cacheless(tmp_path, tree):
    """save_delta(cache=) must write the same manifest/payload as the
    cacheless scan while dispatching only dirty chunks."""
    from repro.core.engine import CimEngine
    from repro.core.incremental import DigestCache
    eng = CimEngine(impl="ref")
    cache = DigestCache(engine=eng, chunk_words=128)
    t1, t2, _ = _step_trees(tree)
    ckpt.save(str(tmp_path / "a"), 1, t1, root_key="k", engine=eng)
    cache.digests(t1)                                # prime on the base tree
    m_cached = ckpt.save_delta(str(tmp_path / "a"), 2, t2, root_key="k",
                               engine=eng, cache=cache)
    assert cache.last.dirty_chunks == 4              # only w's chunks, 512/128
    ckpt.save(str(tmp_path / "b"), 1, t1, root_key="k")
    m_plain = ckpt.save_delta(str(tmp_path / "b"), 2, t2, root_key="k")
    assert m_cached == m_plain
    out, _ = ckpt.restore(str(tmp_path / "a"), 2, _like(tree), root_key="k")
    assert np.array_equal(out["w"], t2["w"])
    with pytest.raises(ValueError, match="conflict"):   # foreign engine=
        ckpt.save_delta(str(tmp_path / "a"), 3, t2, root_key="k",
                        engine=CimEngine(impl="ref"), cache=cache)
    with pytest.raises(ValueError, match="digest_width"):  # manifest width
        ckpt.save_delta(str(tmp_path / "a"), 3, t2, root_key="k",
                        cache=DigestCache(engine=eng, digest_width=96))


def test_delta_with_cache_handles_float64_leaves(tmp_path):
    """save_delta(cache=) on a float64 leaf must stay restorable: the cache
    digest must cover the true 8-byte words, not an x64-off downcast."""
    from repro.core.engine import CimEngine
    from repro.core.incremental import DigestCache
    eng = CimEngine(impl="ref")
    cache = DigestCache(engine=eng, chunk_words=128)
    t1 = {"d": np.arange(64, dtype=np.float64),
          "f": np.ones(8, dtype=np.float32)}
    ckpt.save(str(tmp_path), 1, t1)
    cache.digests(t1)
    t2 = {"d": t1["d"] + 1.0, "f": t1["f"]}
    ckpt.save_delta(str(tmp_path), 2, t2, cache=cache)   # verify_write=True
    out, _ = ckpt.restore(str(tmp_path), 2, _like(t2))
    assert out["d"].dtype == np.float64
    assert out["d"].tobytes() == t2["d"].tobytes()


def test_delta_with_cache_stores_parity_colliding_changes(tmp_path):
    """Swapping two 512-byte-aligned blocks cancels in the columnwise XOR
    parity, so the digest can't see it — the cache's exact word-compare
    must force the store anyway (cacheless scans are documented to miss
    this)."""
    from repro.core.engine import CimEngine
    from repro.core.incremental import DigestCache
    w = np.arange(512, dtype=np.float32)
    w2 = w.copy()
    w2[0:128], w2[128:256] = w[128:256].copy(), w[0:128].copy()
    assert np.array_equal(verify.np_digest(w), verify.np_digest(w2))  # collides
    t1, t2 = {"w": w}, {"w": w2}
    cache = DigestCache(engine=CimEngine(impl="ref"), chunk_words=128)
    ckpt.save(str(tmp_path), 1, t1)
    cache.digests(t1)
    m = ckpt.save_delta(str(tmp_path), 2, t2, cache=cache)
    assert m["leaves"]["w"]["stored_in"] == 2        # stored despite collision
    out, _ = ckpt.restore(str(tmp_path), 2, _like(t2), verify_read=False)
    assert out["w"].tobytes() == w2.tobytes()

    # the README flow: a scrub pass syncs the cache BEFORE save_delta, whose
    # internal pass then sees everything clean — the evidence must persist
    # across passes (observed_since_save) until a save consumes it
    w3 = w2.copy()
    w3[0:128], w3[256:384] = w2[256:384].copy(), w2[0:128].copy()  # collides
    assert np.array_equal(verify.np_digest(w2), verify.np_digest(w3))
    t3 = {"w": w3}
    verify.tree_digest(t3, cache=cache)              # observing scrub pass
    m = ckpt.save_delta(str(tmp_path), 3, t3, cache=cache)
    assert m["leaves"]["w"]["stored_in"] == 3
    out, _ = ckpt.restore(str(tmp_path), 3, _like(t3), verify_read=False)
    assert out["w"].tobytes() == w3.tobytes()
    # evidence was consumed by the successful save: an unchanged re-delta
    # goes back to storing nothing
    m = ckpt.save_delta(str(tmp_path), 4, t3, cache=cache)
    assert m["leaves"]["w"]["stored_in"] == 3

    # an UNPRIMED cache has no comparison history: it cannot attest any
    # leaf clean, so a colliding change is still stored (conservative full
    # write instead of silently trusting the collidable digest)
    fresh = DigestCache(engine=CimEngine(impl="ref"), chunk_words=128)
    w4 = w3.copy()
    w4[0:128], w4[128:256] = w3[128:256].copy(), w3[0:128].copy()
    assert np.array_equal(verify.np_digest(w3), verify.np_digest(w4))
    m = ckpt.save_delta(str(tmp_path), 5, {"w": w4}, cache=fresh)
    assert m["leaves"]["w"]["stored_in"] == 5
    out, _ = ckpt.restore(str(tmp_path), 5, _like(t3), verify_read=False)
    assert out["w"].tobytes() == w4.tobytes()


def test_delta_requires_base_and_uniform_encryption(tmp_path, tree):
    with pytest.raises(FileNotFoundError, match="base"):
        ckpt.save_delta(str(tmp_path), 2, tree)
    ckpt.save(str(tmp_path), 1, tree)                # plain base
    with pytest.raises(ValueError, match="encrypt"):
        ckpt.save_delta(str(tmp_path), 2, tree, root_key="k")
    ckpt.save(str(tmp_path), 3, tree, root_key="k")  # encrypted base
    with pytest.raises(ValueError, match="encrypt"):
        ckpt.save_delta(str(tmp_path), 4, tree)


def test_delta_refuses_to_clobber_its_base(tmp_path, tree):
    """step <= base_step would os.replace the npz the chain still points at
    (silent data loss) — must be a clear error, not a corrupted chain."""
    ckpt.save(str(tmp_path), 5, tree)
    with pytest.raises(ValueError, match="greater than its base"):
        ckpt.save_delta(str(tmp_path), 5, tree)      # default base = latest
    with pytest.raises(ValueError, match="greater than its base"):
        ckpt.save_delta(str(tmp_path), 4, tree, base_step=5)


def test_delta_restores_dtype_reinterpretation_with_identical_bytes(tmp_path,
                                                                    tree):
    """Same bytes, new dtype: the byte digest doesn't move, but the leaf must
    still be re-stored or plain restore would value-cast the base's floats."""
    t2 = dict(tree, w=tree["w"].view(np.int32))      # bitwise identical
    ckpt.save(str(tmp_path), 1, tree)
    m = ckpt.save_delta(str(tmp_path), 2, t2)
    assert m["leaves"]["w"]["stored_in"] == 2
    out, _ = ckpt.restore(str(tmp_path), 2, _like(t2))
    assert out["w"].dtype == np.int32
    assert out["w"].tobytes() == tree["w"].tobytes()


def test_failed_write_verify_unpublishes_the_step(tmp_path, tree,
                                                  monkeypatch):
    """A delta whose write-verify fails must not stay on disk: a published
    bad step would become the next delta's default base and its manifest
    records the intended digests, hiding the corruption forever."""
    ckpt.save(str(tmp_path), 1, tree)
    real = ckpt._write_payload

    def corrupting(path, flat, stage):
        digs = real(path, flat, stage)
        data = dict(np.load(path))
        bad = data["w"].copy()
        bad.view(np.uint32)[0] ^= 1
        data["w"] = bad
        with open(path, "wb") as f:
            np.savez(f, **data)
        return digs

    monkeypatch.setattr(ckpt, "_write_payload", corrupting)
    t2 = dict(tree, w=tree["w"] + 1)
    with pytest.raises(IOError, match="unpublished"):
        ckpt.save_delta(str(tmp_path), 2, t2)
    monkeypatch.undo()
    assert ckpt.latest_step(str(tmp_path)) == 1       # bad step is gone
    assert not os.path.exists(str(tmp_path / "manifest_00000002.msgpack"))
    ckpt.save_delta(str(tmp_path), 2, t2)             # chain still healthy
    out, _ = ckpt.restore(str(tmp_path), 2, _like(tree))
    assert np.array_equal(out["w"], t2["w"])


def test_writers_refuse_to_clobber_a_chained_base(tmp_path, tree):
    """Overwriting a step a newer delta's stored_in points at would destroy
    the chain's only copy of its clean leaves — both writers must refuse."""
    t1, t2, t3 = _step_trees(tree)
    ckpt.save(str(tmp_path), 1, t1)
    ckpt.save_delta(str(tmp_path), 2, t2)             # w stored_in=2
    ckpt.save_delta(str(tmp_path), 3, t3)             # still references 1, 2
    with pytest.raises(ValueError, match="chain"):
        ckpt.save(str(tmp_path), 1, t1)               # full save over base
    with pytest.raises(ValueError, match="chain"):
        ckpt.save_delta(str(tmp_path), 2, t2, base_step=1)  # delta over base
    # the chain head itself is referenced by nothing: overwriting is fine
    ckpt.save(str(tmp_path), 3, t3)
    out, _ = ckpt.restore(str(tmp_path), 3, _like(tree))
    assert np.array_equal(out["w"], t3["w"])


def test_orphan_npz_without_manifest_is_not_a_published_step(tmp_path, tree):
    """Crash window: an npz whose manifest never landed (killed during
    write-verify) must be invisible to latest_step — restore(None) and the
    next delta's default base use the last intact step instead of wedging."""
    ckpt.save(str(tmp_path), 1, tree)
    with open(str(tmp_path / "ckpt_00000002.npz"), "wb") as f:
        f.write(b"partial")                           # orphan, no manifest
    assert ckpt.latest_step(str(tmp_path)) == 1
    out, step = ckpt.restore(str(tmp_path), None, _like(tree))
    assert step == 1 and np.array_equal(out["w"], tree["w"])
    t2 = dict(tree, w=tree["w"] + 1)
    ckpt.save_delta(str(tmp_path), 3, t2)             # base defaults to 1
    out, _ = ckpt.restore(str(tmp_path), 3, _like(tree))
    assert np.array_equal(out["w"], t2["w"])


def test_delta_pruned_base_is_a_clear_error(tmp_path, tree):
    t1, t2, _ = _step_trees(tree)
    ckpt.save(str(tmp_path), 1, t1)
    ckpt.save_delta(str(tmp_path), 2, t2)
    os.remove(str(tmp_path / "ckpt_00000001.npz"))
    with pytest.raises(FileNotFoundError, match="stored in step 1"):
        ckpt.restore(str(tmp_path), 2, _like(tree))


def test_np_digest_matches_device_digest():
    x = RNG.standard_normal((257,)).astype(np.float32)
    import jax.numpy as jnp
    from repro.kernels import ops
    d_np = verify.np_digest(x)
    d_dev = np.asarray(ops.digest(jnp.asarray(x), impl="ref"))
    assert np.array_equal(d_np, d_dev)


def test_encrypt_np_involution_and_key_sensitivity():
    x = RNG.standard_normal((100,)).astype(np.float32)
    enc = encrypt.encrypt_np(x, "key", "path/a")
    dec = encrypt.decrypt_np(enc, "key", "path/a", np.float32, (100,))
    assert np.array_equal(dec, x)
    other = encrypt.decrypt_np(enc, "key", "path/b", np.float32, (100,))
    assert not np.array_equal(other, x)
