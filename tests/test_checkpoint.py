"""Checkpoint integrity: XOR-parity write/read verification (paper Fig. 1(a)),
XOR encryption round-trip (Fig. 1(b)), corruption detection, restart
orchestration, straggler policy."""

import os

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import encrypt, verify
from repro.distributed import fault

RNG = np.random.default_rng(0)


@pytest.fixture
def tree():
    return {"w": RNG.standard_normal((32, 16)).astype(np.float32),
            "inner": {"b": RNG.standard_normal(7).astype(np.float16),
                      "steps": np.arange(5, dtype=np.int32)}}


def _like(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


@pytest.mark.parametrize("root_key", [None, "hunter2"])
def test_save_restore_roundtrip(tmp_path, tree, root_key):
    ckpt.save(str(tmp_path), 7, tree, root_key=root_key)
    out, step = ckpt.restore(str(tmp_path), None, _like(tree),
                             root_key=root_key)
    assert step == 7
    assert np.array_equal(out["w"], tree["w"])
    assert np.array_equal(out["inner"]["b"], tree["inner"]["b"])
    assert np.array_equal(out["inner"]["steps"], tree["inner"]["steps"])


def test_encrypted_payload_is_scrambled(tmp_path, tree):
    ckpt.save(str(tmp_path), 1, tree, root_key="k", verify_write=False)
    data = np.load(str(tmp_path / "ckpt_00000001.npz"))
    # stored bytes must NOT equal plaintext
    stored = data["w"]
    assert stored.dtype == np.uint8
    assert not np.array_equal(stored.view(np.float32).reshape(32, 16),
                              tree["w"])


def test_parity_detects_tampered_leaf(tmp_path, tree):
    """Tamper inside a valid container: our parity check (not the zip CRC)
    must catch it."""
    ckpt.save(str(tmp_path), 3, tree)
    path = str(tmp_path / "ckpt_00000003.npz")
    data = dict(np.load(path))
    tampered = data["w"].copy()
    tampered.view(np.uint32)[5] ^= 1 << 12        # one flipped bit
    data["w"] = tampered
    with open(path, "wb") as f:
        np.savez(f, **data)
    ok, bad = ckpt.check(str(tmp_path), 3)
    assert not ok and bad == ["w"]
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 3, _like(tree))


def test_runner_falls_back_on_corruption(tmp_path, tree):
    r = fault.Runner(str(tmp_path), save_every=1)
    ckpt.save(str(tmp_path), 1, tree)
    tree2 = jax.tree.map(lambda a: a + 1 if a.dtype.kind == "f" else a, tree)
    ckpt.save(str(tmp_path), 2, tree2)
    # corrupt step 2 in-place (valid zip, bad parity)
    path = str(tmp_path / "ckpt_00000002.npz")
    data = dict(np.load(path))
    data["w"].view(np.uint32)[0] ^= 1
    with open(path, "wb") as f:
        np.savez(f, **data)
    state, step = r.resume_or_init(_like(tree), lambda: tree)
    assert step == 1                      # fell back past the corrupt ckpt
    assert np.array_equal(state["w"], tree["w"])


def test_runner_gc_keeps_last(tmp_path, tree):
    r = fault.Runner(str(tmp_path), save_every=1, keep_last=2)
    for s in (1, 2, 3, 4):
        r.maybe_save(s, tree)
    assert r._steps() == [3, 4]


def test_straggler_policy_three_strikes():
    pol = fault.StragglerPolicy(straggler_factor=2.0, max_strikes=3)
    for i in range(10):
        assert pol.observe(i, 1.0) == "ok"
    assert pol.observe(10, 5.0) == "straggler"
    assert pol.observe(11, 5.0) == "straggler"
    assert pol.observe(12, 5.0) == "reshard"
    assert pol.strikes == 0               # reset after reshard


def test_encrypted_checkpoint_requires_root_key(tmp_path, tree):
    """Missing key on an encrypted checkpoint must be a clear ValueError,
    not an AttributeError from inside derive_key."""
    ckpt.save(str(tmp_path), 2, tree, root_key="hunter2")
    with pytest.raises(ValueError, match="root_key"):
        ckpt.check(str(tmp_path), 2)
    with pytest.raises(ValueError, match="root_key"):
        ckpt.restore(str(tmp_path), 2, _like(tree))
    # unencrypted checkpoints keep working without a key
    ckpt.save(str(tmp_path / "plain"), 2, tree)
    ok, bad = ckpt.check(str(tmp_path / "plain"), 2)
    assert ok and not bad


@pytest.mark.parametrize("root_key", [None, "hunter2"])
def test_bfloat16_leaf_roundtrip(tmp_path, root_key):
    """bfloat16 leaves: npz stores them as void records (_coerce path);
    composed with encrypt/decrypt they must still round-trip bit-exactly."""
    import ml_dtypes
    bf16 = np.dtype(ml_dtypes.bfloat16)
    tree = {"w": RNG.standard_normal((16, 8)).astype(bf16),
            "odd": RNG.standard_normal(33).astype(bf16),  # odd byte tail
            "f": RNG.standard_normal(5).astype(np.float32)}
    ckpt.save(str(tmp_path), 4, tree, root_key=root_key)
    ok, bad = ckpt.check(str(tmp_path), 4, root_key=root_key)
    assert ok, bad
    out, step = ckpt.restore(str(tmp_path), None, _like(tree),
                             root_key=root_key)
    assert step == 4
    for k in tree:
        assert out[k].dtype == tree[k].dtype, k
        assert np.array_equal(out[k].view(np.uint8), tree[k].view(np.uint8)), k


@pytest.mark.parametrize("root_key", [None, "hunter2"])
def test_device_side_ckpt_path_is_bit_identical_to_host(tmp_path, tree,
                                                        root_key):
    """save/check/restore with engine= (device digests + device cipher)
    must produce byte-identical manifests and cross-restore with the host
    path in both directions."""
    from repro.core.engine import CimEngine
    eng = CimEngine(impl="ref")
    m_dev = ckpt.save(str(tmp_path / "dev"), 5, tree, root_key=root_key,
                      engine=eng)
    m_host = ckpt.save(str(tmp_path / "host"), 5, tree, root_key=root_key)
    assert m_dev == m_host
    assert eng.stats.calls > 0            # digests/cipher ran on the engine
    # device-written -> host-read, host-written -> device-read
    out, _ = ckpt.restore(str(tmp_path / "dev"), 5, _like(tree),
                          root_key=root_key)
    assert np.array_equal(out["w"], tree["w"])
    out2, _ = ckpt.restore(str(tmp_path / "host"), 5, _like(tree),
                           root_key=root_key, engine=eng)
    assert np.array_equal(out2["inner"]["b"], tree["inner"]["b"])
    ok, bad = ckpt.check(str(tmp_path / "host"), 5, root_key=root_key,
                         engine=eng)
    assert ok, bad


def test_np_digest_matches_device_digest():
    x = RNG.standard_normal((257,)).astype(np.float32)
    import jax.numpy as jnp
    from repro.kernels import ops
    d_np = verify.np_digest(x)
    d_dev = np.asarray(ops.digest(jnp.asarray(x), impl="ref"))
    assert np.array_equal(d_np, d_dev)


def test_encrypt_np_involution_and_key_sensitivity():
    x = RNG.standard_normal((100,)).astype(np.float32)
    enc = encrypt.encrypt_np(x, "key", "path/a")
    dec = encrypt.decrypt_np(enc, "key", "path/a", np.float32, (100,))
    assert np.array_equal(dec, x)
    other = encrypt.decrypt_np(enc, "key", "path/b", np.float32, (100,))
    assert not np.array_equal(other, x)
