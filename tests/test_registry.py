"""Block-contract registry conformance (DESIGN.md §16).

Every registered kind — present and future — gets the same contract
coverage for free: state_spec abstract/concrete round-trip, the
contract-generated paged split/merge inverse, fwd-vs-decode parity, and
chunk ragged-tail exactness (paged == dense through the real engine).
Registration-time validation and the fail-closed prefix gate (a kind that
doesn't declare ``prefix_shareable`` disables sharing for any arch that
contains it) are pinned here too.

The ``_OVER`` table below gives each kind the config knobs its *model*
needs (ctx tokens, encoder stack, expert counts).  That is test-harness
knowledge — the consumers under test never switch on kind strings.
"""

import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import ArchConfig
from repro.models import blocks, lm, registry
from repro.models.registry import BlockContract
from repro.serve import ServeEngine, synthetic_trace

KINDS = registry.kinds()   # configs import above registers satellite kinds

_OVER = {
    "local": dict(local_window=8),
    "cross": dict(n_ctx_tokens=16, family="vlm"),
    "dec": dict(n_ctx_tokens=16, encoder_layers=2, family="audio"),
    "bindense": dict(n_ctx_tokens=4, vocab=4, quant="xnor", family="vlm"),
    "moe": dict(n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0,
                family="moe"),
}


def _cfg(kind, **extra):
    base = dict(name=f"conformance-{kind}", family="dense", n_layers=2,
                d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                pattern=(kind,), local_window=32, mlstm_chunk=8,
                block_size=8, prefill_chunk=8, dtype=jnp.float32)
    base.update(_OVER.get(kind, {}))
    base.update(extra)
    return ArchConfig(**base)


def _key(kind):
    return jax.random.PRNGKey(zlib.crc32(kind.encode()) % 2**31)


def _model(kind):
    cfg = _cfg(kind)
    params = lm.init_params(cfg, _key(kind))
    return cfg, params


# ---------------------------------------------------------------------------
# per-kind conformance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_state_spec_abstract_concrete_roundtrip(kind):
    """Abstract and concrete specs agree on structure, shape and dtype —
    for both the dense and the contract-generated paged layouts."""
    cls = registry.get(kind)
    cfg = _cfg(kind)
    for mk in (lambda a: cls.state_spec(cfg, 2, 16, a),
               lambda a: cls.paged_state_spec(cfg, 2, 16, 4,
                                              cfg.block_size, a)):
        abs_t, con_t = mk(True), mk(False)
        assert (jax.tree.structure(abs_t) == jax.tree.structure(con_t))
        for la, lc in zip(jax.tree.leaves(abs_t), jax.tree.leaves(con_t)):
            assert la.shape == lc.shape, (kind, la.shape, lc.shape)
            assert la.dtype == lc.dtype, (kind, la.dtype, lc.dtype)


@pytest.mark.parametrize("kind", KINDS)
def test_paged_split_merge_inverse(kind):
    """split's halves track the declared contract flags, and merge(split)
    is the identity on the paged state tree."""
    cls = registry.get(kind)
    c = cls.contract
    cfg = _cfg(kind)
    state = cls.paged_state_spec(cfg, 2, 16, 4, cfg.block_size, False)
    shared, per_slot = cls.paged_split(state)
    assert (shared is not None) == c.paged_kv
    assert (per_slot is not None) == c.per_slot_state
    merged = cls.paged_merge(shared, per_slot)
    assert jax.tree.structure(merged) == jax.tree.structure(state)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind", KINDS)
def test_fwd_decode_parity(kind):
    """Single-kind model: prefill + step-by-step decode reproduces the
    full-sequence forward (the §13 serve-path equivalence, per kind)."""
    if not registry.contract(kind).decodes:
        pytest.skip("encoder-only kind never runs the decode path")
    B, S, s0 = 2, 12, 8
    cfg, params = _model(kind)
    key = _key(kind)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    ctx = None
    if cfg.n_ctx_tokens:
        ctx = jax.random.normal(key, (B, cfg.n_ctx_tokens, cfg.d_model),
                                jnp.float32) * 0.1
    full, _ = lm.forward(cfg, params, tokens, ctx)
    lg, st = lm.prefill(cfg, params, tokens[:, :s0], ctx, s_max=S + 2)
    outs = [lg]
    for t in range(s0, S):
        lg, st = lm.decode_step(cfg, params, tokens[:, t:t + 1], st)
        outs.append(lg)
    dec = np.asarray(jnp.concatenate(outs, 1), np.float32)
    want = np.asarray(full[:, s0 - 1:], np.float32)
    rel = np.abs(dec - want).max() / max(np.abs(want).max(), 1e-6)
    assert rel < 1e-3, (kind, rel)


@pytest.mark.parametrize("kind", KINDS)
def test_chunked_paged_matches_dense(kind):
    """Chunk ragged-tail exactness through the real engine: prompt lengths
    straddling the C=8 chunk size (5, 8, 13) serve token-identically on
    the paged and dense layouts."""
    c = registry.contract(kind)
    if not c.decodes:
        pytest.skip("encoder-only kind never runs the decode path")
    if c.routed_experts:
        pytest.skip("MoE exempt from cross-layout token identity "
                    "(capacity is a function of dispatch group length, "
                    "DESIGN.md §14)")
    cfg, params = _model(kind)
    trace = synthetic_trace(4, cfg.vocab, seed=3, prompt_lens=(5, 8, 13),
                            new_tokens=(3, 5),
                            n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model)
    outs = []
    for paged in (False, True):
        eng = ServeEngine(cfg, params, slots=2, s_max=24, paged=paged)
        for r in trace:
            eng.submit(r)
        report = eng.run()
        outs.append({rid: report.tokens(rid).tolist()
                     for rid in report.sessions})
    assert outs[0] == outs[1], kind


@pytest.mark.parametrize("kind", KINDS)
def test_table_widths_follow_contract(kind):
    """paged_table_widths is generic: exactly the kinds declaring a paged
    pool produce a table class, sized >= 1 block."""
    c = registry.contract(kind)
    cfg = _cfg(kind)
    widths = lm.paged_table_widths(cfg, 32, cfg.block_size,
                                   cfg.prefill_chunk)
    if c.paged_kv:
        assert list(widths) == [c.table_class] and widths[c.table_class] >= 1
    else:
        assert widths == {}


def test_every_arch_kind_is_registered():
    """Every kind any shipped arch names (decoder and encoder stacks)
    resolves to a registered contract."""
    for cfg in configs.ALL.values():
        for kind, _ in cfg.segments() + cfg.encoder_segments():
            assert isinstance(registry.contract(kind), BlockContract)


# ---------------------------------------------------------------------------
# registration-time validation
# ---------------------------------------------------------------------------


def test_contract_validation():
    with pytest.raises(ValueError):       # pool without a table class
        BlockContract("p", paged_kv=True)
    with pytest.raises(ValueError):       # ring without a table class
        BlockContract("w", window=True)
    with pytest.raises(ValueError):       # rings are never stable (§15)
        BlockContract("ws", window=True, table_class="win",
                      prefix_shareable=True)
    with pytest.raises(ValueError):
        BlockContract("")


def test_register_rejects_duplicates_and_malformed():
    with pytest.raises(ValueError):       # "attn" already registered
        registry.register(blocks.AttnBlock)

    class NoContract:
        pass

    with pytest.raises(TypeError):
        registry.register(NoContract)

    class NoSurface:
        contract = BlockContract("hollow")

    with pytest.raises(TypeError):        # lacks defs/fwd/state_spec
        registry.register(NoSurface)
    assert "hollow" not in registry.kinds()


def test_unknown_kind_error_names_registered_kinds():
    with pytest.raises(KeyError, match="attn"):
        registry.get("no-such-kind")


# ---------------------------------------------------------------------------
# fail-closed prefix gate (satellite regression)
# ---------------------------------------------------------------------------


class _OpaqueAttn(blocks.AttnBlock):
    """Physically identical to attn, but its contract says nothing about
    prefix sharing — the gate must fail closed."""
    contract = BlockContract("opaque_attn", paged_kv=True,
                             table_class="full")


def test_prefix_gate_fails_closed_for_undeclared_kind():
    with registry.temporary(_OpaqueAttn):
        cfg = _cfg("opaque_attn")
        assert lm.prefix_cache_eligible(cfg) is False
        assert lm.prefix_table_class(cfg) is None
        # one undeclared kind anywhere in the stack disables the arch,
        # even when every other kind declares shareability
        mixed = _cfg("opaque_attn", pattern=("attn", "opaque_attn"))
        assert lm.prefix_cache_eligible(mixed) is False
        # the engine honors the gate (prefix_cache=True requested) and the
        # kind still serves through the generic machinery
        params = lm.init_params(cfg, _key("opaque_attn"))
        eng = ServeEngine(cfg, params, slots=2, s_max=24, prefix_cache=True)
        assert eng.prefix_caching is False
        trace = synthetic_trace(3, cfg.vocab, seed=1, prompt_lens=(4, 9),
                                new_tokens=(3,))
        for r in trace:
            eng.submit(r)
        report = eng.run()
        assert all(len(s.tokens) > 0 for s in report.sessions.values())
    # the temporary registration is gone afterwards
    with pytest.raises(KeyError):
        registry.get("opaque_attn")


def test_declared_kinds_keep_eligibility():
    """The contract flag reproduces the historical allowlist on the
    shipped archs (no eligibility regressions from the refactor)."""
    want = {"qwen3-4b": True, "whisper-tiny": True,
            "llama-3.2-vision-11b": True, "recurrentgemma-2b": False,
            "xlstm-350m": False, "xnor-cnn": True}
    for name, eligible in want.items():
        assert lm.prefix_cache_eligible(configs.get(name)) is eligible, name
