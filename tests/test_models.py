"""Per-architecture smoke tests (reduced configs): forward shape/NaN, loss +
grad, prefill/decode consistency, XNOR-quant variant, MoE properties."""

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm, moe

ARCHS = sorted(configs.ALL)


def _setup(name, B=2, S=12, **over):
    cfg = configs.ALL[name].smoke(**over)
    # crc32, NOT hash(): str hashes are salted per process (PYTHONHASHSEED),
    # so hash-derived keys redraw params/tokens every pytest run — the i8
    # cache-accuracy threshold then flakes on tail draws.  crc32 is stable.
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % 2**31)
    params = lm.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    ctx = None
    if cfg.n_ctx_tokens:
        ctx = jax.random.normal(key, (B, cfg.n_ctx_tokens, cfg.d_model),
                                jnp.float32) * 0.1
    return cfg, params, tokens, ctx


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    B, S = 2, 12
    cfg, params, tokens, ctx = _setup(name, B, S)
    logits, aux = lm.forward(cfg, params, tokens, ctx)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_grad_finite(name):
    B, S = 2, 12
    cfg, params, tokens, ctx = _setup(name, B, S)
    batch = {"tokens": tokens,
             "labels": jnp.concatenate(
                 [tokens[:, 1:], -jnp.ones((B, 1), jnp.int32)], 1)}
    if ctx is not None:
        batch["ctx"] = ctx
    (loss, metrics), g = jax.value_and_grad(
        lambda p: lm.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                      for x in jax.tree.leaves(g)))
    assert np.isfinite(float(gn)) and float(gn) > 0


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_matches_forward(name):
    """Algorithmic equivalence of the serve path (f32 so recurrent-layer
    bf16 accumulation noise doesn't mask logic bugs; no-drop capacity so
    MoE routing is identical across both paths)."""
    B, S, s0 = 2, 12, 8
    cfg, params, tokens, ctx = _setup(name, B, S, capacity_factor=8.0,
                                      dtype=jnp.float32)
    full_logits, _ = lm.forward(cfg, params, tokens, ctx)
    lg, st = lm.prefill(cfg, params, tokens[:, :s0], ctx, s_max=S + 2)
    outs = [lg]
    for t in range(s0, S):
        lg, st = lm.decode_step(cfg, params, tokens[:, t:t+1], st)
        outs.append(lg)
    dec = np.asarray(jnp.concatenate(outs, 1), np.float32)
    want = np.asarray(full_logits[:, s0 - 1:], np.float32)
    rel = np.abs(dec - want).max() / max(np.abs(want).max(), 1e-6)
    assert rel < 1e-3, rel


@pytest.mark.parametrize("name", ["qwen2-7b", "xlstm-350m",
                                  "moonshot-v1-16b-a3b"])
def test_xnor_quant_variant_trains(name):
    """The paper's technique as a config axis: binary projections still give
    finite loss/grads (STE path)."""
    B, S = 2, 12
    cfg, params, tokens, ctx = _setup(name, B, S, quant="xnor")
    assert cfg.quant == "xnor"
    batch = {"tokens": tokens,
             "labels": jnp.concatenate(
                 [tokens[:, 1:], -jnp.ones((B, 1), jnp.int32)], 1)}
    (loss, _), g = jax.value_and_grad(
        lambda p: lm.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    leaves = [x for x in jax.tree.leaves(g)]
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in leaves)


def test_i8_kv_cache_decode_accuracy():
    """int8 fixed-point decode cache (§Perf iter 7): <2% rel logit error."""
    name = "qwen3-4b"
    cfg, params, tokens, ctx = _setup(name, 2, 12, dtype=jnp.float32)
    cfg = dataclasses.replace(cfg, kv_cache_dtype="i8")
    full_logits, _ = lm.forward(cfg, params, tokens, ctx)
    lg, st = lm.prefill(cfg, params, tokens[:, :8], ctx, s_max=14)
    outs = [lg]
    for t in range(8, 12):
        lg, st = lm.decode_step(cfg, params, tokens[:, t:t+1], st)
        outs.append(lg)
    dec = np.asarray(jnp.concatenate(outs, 1), np.float32)
    want = np.asarray(full_logits[:, 7:], np.float32)
    rel = np.abs(dec - want).max() / np.abs(want).max()
    assert rel < 2e-2, rel
    assert jax.tree.leaves(st.seg_states)[0].dtype == jnp.int8


def test_kv_i8_scale_config_roundtrip():
    """The i8 cache scale is a config axis (cfg.kv_i8_scale), not a module
    constant: a non-default scale must round-trip prefill -> decode (encode
    and decode sides read the same config), stay accurate, and actually
    change the stored fixed-point representation."""
    cfg, params, tokens, ctx = _setup("qwen3-4b", 2, 12, dtype=jnp.float32)
    full_logits, _ = lm.forward(cfg, params, tokens, ctx)

    def decode_tail(c):
        lg, st = lm.prefill(c, params, tokens[:, :8], ctx, s_max=14)
        outs = [lg]
        for t in range(8, 12):
            lg, st = lm.decode_step(c, params, tokens[:, t:t+1], st)
            outs.append(lg)
        return np.asarray(jnp.concatenate(outs, 1), np.float32), st

    want = np.asarray(full_logits[:, 7:], np.float32)
    caches = {}
    # 16 is coarser than the default 32 (double the rounding error, hence
    # the looser bound) but still clip-free; going *finer* than 32 would
    # saturate int8 at these |k| magnitudes
    for scale, bound in ((32.0, 2e-2), (16.0, 4e-2)):
        c = dataclasses.replace(cfg, kv_cache_dtype="i8", kv_i8_scale=scale)
        assert c.kv_i8_scale == scale
        dec, st = decode_tail(c)
        rel = np.abs(dec - want).max() / np.abs(want).max()
        assert rel < bound, (scale, rel)
        caches[scale] = np.asarray(jax.tree.leaves(st.seg_states)[0])
    # a different scale stores different fixed-point words — the field is
    # genuinely wired through both the prefill and decode encoders
    assert caches[32.0].dtype == np.int8
    assert not np.array_equal(caches[32.0], caches[16.0])


def test_chunked_attention_matches_full():
    cfg, params, tokens, ctx = _setup("qwen2-7b", 2, 16)
    full, _ = lm.forward(cfg, params, tokens, ctx, q_chunk=0)
    chunked, _ = lm.forward(cfg, params, tokens, ctx, q_chunk=4)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(chunked, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_local_window_attention_masks_past():
    """RecurrentGemma local layers must not see beyond the window."""
    cfg, params, tokens, _ = _setup("recurrentgemma-2b", 1, 40)
    # perturb a token far outside every window; logits at the end must shift
    # by (much) less than perturbing a token inside the window
    t2 = tokens.at[0, 1].set((tokens[0, 1] + 7) % cfg.vocab)
    t3 = tokens.at[0, 38].set((tokens[0, 38] + 7) % cfg.vocab)
    base, _ = lm.forward(cfg, params, tokens)
    far, _ = lm.forward(cfg, params, t2)
    near, _ = lm.forward(cfg, params, t3)
    d_far = np.abs(np.asarray(base[0, -1] - far[0, -1], np.float32)).max()
    d_near = np.abs(np.asarray(base[0, -1] - near[0, -1], np.float32)).max()
    assert d_near > d_far  # recurrent path may carry some far influence


def test_moe_capacity_and_load_balance():
    cfg = configs.ALL["moonshot-v1-16b-a3b"].smoke()
    key = jax.random.PRNGKey(3)
    d, e = cfg.d_model, cfg.n_experts
    p = {"router": jax.random.normal(key, (d, e)) * 0.02,
         "w1": jax.random.normal(key, (e, d, cfg.d_ff_expert), cfg.dtype) * 0.02,
         "w3": jax.random.normal(key, (e, d, cfg.d_ff_expert), cfg.dtype) * 0.02,
         "w2": jax.random.normal(key, (e, cfg.d_ff_expert, d), cfg.dtype) * 0.02}
    x = jax.random.normal(key, (2, 64, d), cfg.dtype)
    y, aux = moe.moe_ffn(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, == 1 if balanced


def test_moe_respects_capacity_drop_semantics():
    """Force all tokens to one expert: overflow must be dropped (residual
    carries them), output for dropped tokens is exactly zero."""
    cfg = dataclasses.replace(configs.ALL["llama4-scout-17b-a16e"].smoke(),
                              capacity_factor=0.25, top_k=1)
    key = jax.random.PRNGKey(4)
    d, e = cfg.d_model, cfg.n_experts
    router = jnp.zeros((d, e)).at[:, 0].set(100.0)  # everyone -> expert 0
    p = {"router": router,
         "w1": jnp.ones((e, d, cfg.d_ff_expert), cfg.dtype) * 0.01,
         "w3": jnp.ones((e, d, cfg.d_ff_expert), cfg.dtype) * 0.01,
         "w2": jnp.ones((e, cfg.d_ff_expert, d), cfg.dtype) * 0.01}
    x = jax.random.normal(key, (1, 32, d), cfg.dtype) + 1.0
    y, _ = moe.moe_ffn(cfg, p, x)
    ynorm = np.asarray(jnp.sum(jnp.abs(y.astype(jnp.float32)), axis=-1))[0]
    kept = int((ynorm > 1e-3).sum())
    cap = moe.capacity(cfg, 32)
    assert kept == cap, (kept, cap)


@pytest.mark.parametrize("name", ARCHS)
def test_param_specs_cover_params(name):
    cfg = configs.ALL[name]
    defs = lm.param_defs(cfg)
    ab = lm.abstract_params(cfg)
    specs = lm.param_pspecs(cfg, {"fsdp": "data", "tp": "model", "ep": "model"})
    assert jax.tree.structure(ab) == jax.tree.structure(specs)
    for leaf, spec in zip(jax.tree.leaves(ab), jax.tree.leaves(specs)):
        assert len(spec) <= len(leaf.shape)
