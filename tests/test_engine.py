"""Banked engine tests (DESIGN.md §10): banked/vectorized `cim.compute`
against a Python loop of single-array calls, CimEngine round-trips against
the existing single-array paths, and the cycle-accounting model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cim, encrypt, verify
from repro.core.engine import BankGeometry, CimEngine
from repro.kernels import ops

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# banked compute == loop of per-array compute, bit-exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("banks,rows,cols,pairs,seed", [
    (1, 4, 8, 1, 0), (3, 8, 16, 4, 1), (8, 6, 32, 2, 2), (13, 10, 5, 5, 3),
])
@pytest.mark.parametrize("op", ["xor", "xnor"])
def test_banked_compute_matches_single_array_loop(banks, rows, cols, pairs,
                                                  seed, op):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (banks, rows, cols))
    ra = rng.integers(0, rows, (banks, pairs))
    rb = (ra + 1 + rng.integers(0, rows - 1, (banks, pairs))) % rows
    state = cim.make_array(jnp.asarray(bits))
    out = np.asarray(cim.compute(state, jnp.asarray(ra), jnp.asarray(rb), op))
    assert out.shape == (banks, pairs, cols)
    for b in range(banks):
        single = cim.make_array(jnp.asarray(bits[b]))
        for p in range(pairs):
            want = cim.compute(single, int(ra[b, p]), int(rb[b, p]), op)
            assert np.array_equal(out[b, p], np.asarray(want)), (b, p)


def test_banked_compute_matches_vmap():
    bits = RNG.integers(0, 2, (6, 4, 12))
    state = cim.make_array(jnp.asarray(bits))
    banked = cim.compute(state, 0, 1, "xor")
    vmapped = jax.vmap(lambda r: cim.compute(cim.ArrayState(
        r, state.leak_lrs, state.leak_hrs), 0, 1, "xor"))(state.r)
    assert np.array_equal(np.asarray(banked), np.asarray(vmapped))


def test_banked_read_and_write():
    bits = RNG.integers(0, 2, (4, 6, 9))
    state = cim.make_array(jnp.asarray(bits))
    got = np.asarray(cim.read(state, jnp.arange(6)))
    assert np.array_equal(got, bits.astype(bool))
    per_bank = RNG.integers(0, 2, (4,))
    state = cim.write(state, 2, 3, jnp.asarray(per_bank))
    assert np.array_equal(np.asarray(cim.read(state, 2))[:, 3],
                          per_bank.astype(bool))


def test_shared_pair_indices_broadcast_over_banks():
    bits = RNG.integers(0, 2, (5, 8, 7))
    state = cim.make_array(jnp.asarray(bits))
    ra, rb = jnp.array([0, 2, 4]), jnp.array([1, 3, 5])
    out = np.asarray(cim.compute(state, ra, rb, "xor"))
    assert out.shape == (5, 3, 7)
    want = bits[:, [0, 2, 4]] ^ bits[:, [1, 3, 5]]
    assert np.array_equal(out, want.astype(bool))


# ---------------------------------------------------------------------------
# CimEngine.simulate: analog banked path == digital truth
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 3, 7, 16, 31])
def test_simulate_matches_digital_xor(n):
    eng = CimEngine(BankGeometry(banks=4, rows=16, cols=24), impl="ref")
    a = RNG.integers(0, 2, (n, 24))
    b = RNG.integers(0, 2, (n, 24))
    out = np.asarray(eng.simulate(jnp.asarray(a), jnp.asarray(b), "xor"))
    assert np.array_equal(out, (a ^ b).astype(bool))
    outn = np.asarray(eng.simulate(jnp.asarray(a), jnp.asarray(b), "xnor"))
    assert np.array_equal(outn, ~(a ^ b).astype(bool))


def test_simulate_rejects_overflow():
    eng = CimEngine(BankGeometry(banks=2, rows=4, cols=8))
    ok = jnp.zeros((4, 8))        # 2 pairs/bank = 4 rows: fits exactly
    eng.simulate(ok, ok)
    with pytest.raises(ValueError, match="rows"):
        eng.simulate(jnp.zeros((5, 8)), jnp.zeros((5, 8)))  # needs 6 rows
    with pytest.raises(ValueError, match="exceed bank width"):
        eng.simulate(jnp.zeros((1, 9)), jnp.zeros((1, 9)))  # too wide
    with pytest.raises(ValueError, match="shapes differ"):
        eng.simulate(jnp.zeros((2, 8)), jnp.zeros((3, 8)))
    assert eng.stats.calls == 1   # failed dispatches must not be accounted


# ---------------------------------------------------------------------------
# CimEngine round-trips bit-exactly against the single-array paths
# ---------------------------------------------------------------------------

def test_engine_digest_matches_ops_digest():
    eng = CimEngine(impl="ref")
    buf = jnp.asarray(RNG.integers(0, 2**32, 5000, dtype=np.uint32))
    assert np.array_equal(np.asarray(eng.digest(buf)),
                          np.asarray(ops.digest(buf, impl="ref")))


def test_engine_cipher_matches_ops_and_involutes():
    eng = CimEngine(impl="ref")
    buf = jnp.asarray(RNG.integers(0, 2**32, 4096, dtype=np.uint32))
    key = jnp.array([11, 42], dtype=jnp.uint32)
    enc = eng.stream_cipher(buf, key, counter=9)
    assert np.array_equal(
        np.asarray(enc),
        np.asarray(ops.stream_cipher(buf, key, counter=9, impl="ref")))
    assert np.array_equal(np.asarray(eng.stream_cipher(enc, key, counter=9)),
                          np.asarray(buf))


def test_tree_digest_through_engine_matches_direct_path():
    tree = {"w": jnp.asarray(RNG.standard_normal((64, 32)), jnp.float32),
            "b": jnp.asarray(RNG.standard_normal((128,)), jnp.float32)}
    eng = CimEngine(impl="ref")
    via_engine = verify.tree_digest(tree, engine=eng)
    direct = {k: ops.digest(v, verify.DIGEST_WIDTH, impl="ref")
              for k, v in tree.items()}
    for k in tree:
        assert np.array_equal(np.asarray(via_engine[k]),
                              np.asarray(direct[k])), k
    ok, _ = verify.verify_trees(tree, tree, engine=eng)
    assert bool(ok)


def test_encrypt_device_through_engine_round_trips():
    eng = CimEngine(impl="ref")
    buf = jnp.asarray(RNG.integers(0, 2**32, 1024, dtype=np.uint32))
    enc = encrypt.encrypt_device(buf, "root", "leaf", engine=eng)
    assert not np.array_equal(np.asarray(enc), np.asarray(buf))
    assert np.array_equal(
        np.asarray(encrypt.encrypt_device(enc, "root", "leaf", engine=eng)),
        np.asarray(buf))
    # engine path == legacy direct path, bit-exactly
    assert np.array_equal(
        np.asarray(enc),
        np.asarray(encrypt.encrypt_device(buf, "root", "leaf", impl="ref")))


def test_engine_verify_copy_flags_corruption():
    eng = CimEngine(impl="ref")
    buf = jnp.asarray(RNG.integers(0, 2**32, 600, dtype=np.uint32))
    assert bool(eng.verify_copy(buf, buf))
    assert not bool(eng.verify_copy(buf, buf.at[123].set(buf[123] ^ 1)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16, jnp.int8,
                                   jnp.float64])
def test_verify_copy_accepts_non_uint32_buffers(dtype):
    """Non-uint32 operands route through as_words instead of crashing in the
    uint32-only bulk_op."""
    if dtype == jnp.float64 and not jax.config.jax_enable_x64:
        dtype = jnp.int32
    eng = CimEngine(impl="ref")
    x = jnp.asarray(RNG.standard_normal((33, 5))).astype(dtype)
    assert bool(eng.verify_copy(x, jnp.array(x)))
    y = x.at[32, 4].set(x[32, 4] + 1)
    assert not bool(eng.verify_copy(x, y))


def test_verify_copy_is_byte_true_for_64bit_numpy():
    """A corruption living only in the upper bytes of an int64/float64 numpy
    buffer must be caught — an x64-off downcast would discard it and report
    the copy intact."""
    eng = CimEngine(impl="ref")
    a = np.arange(64, dtype=np.int64)
    bad = a.copy()
    bad[3] ^= np.int64(1) << 40              # flips bits the downcast drops
    assert bool(eng.verify_copy(a, a.copy()))
    assert not bool(eng.verify_copy(a, bad))
    d = np.linspace(0.0, 1.0, 64, dtype=np.float64)
    bad_d = d.copy()
    bad_d.view(np.uint64)[5] ^= np.uint64(1)  # lowest mantissa bit
    assert not bool(eng.verify_copy(d, bad_d))


def test_verify_copy_rejects_mismatch_with_clear_error():
    eng = CimEngine(impl="ref")
    x = jnp.zeros((4, 8), jnp.float32)
    with pytest.raises(ValueError, match="shape/dtype"):
        eng.verify_copy(x, x.reshape(8, 4))   # same bytes, different layout
    with pytest.raises(ValueError, match="shape/dtype"):
        eng.verify_copy(x, x.astype(jnp.int32))


# ---------------------------------------------------------------------------
# cycle accounting
# ---------------------------------------------------------------------------

def test_cycle_model_scales_inversely_with_banks():
    nbits = 1 << 20
    cycles = [CimEngine(BankGeometry(banks=b, cols=128)).cycles_for(nbits)
              for b in (1, 8, 64)]
    assert cycles[0] == 8 * cycles[1] == 64 * cycles[2]


def test_engine_stats_accumulate():
    eng = CimEngine(BankGeometry(banks=2, rows=8, cols=32), impl="ref")
    a = jnp.asarray(RNG.integers(0, 2**32, 64, dtype=np.uint32))
    eng.xor(a, a)
    assert eng.stats.calls == 1
    assert eng.stats.bit_ops == 64 * 32
    assert eng.stats.cycles == eng.cycles_for(64 * 32)
    eng.simulate(jnp.zeros((6, 32)), jnp.zeros((6, 32)))
    assert eng.stats.calls == 2
    assert eng.stats.cycles == eng.cycles_for(64 * 32) + 3  # 6 pairs / 2 banks


def test_engine_stats_break_down_by_op_and_snapshot():
    eng = CimEngine(BankGeometry(banks=2, rows=8, cols=32), impl="ref")
    a = jnp.asarray(RNG.integers(0, 2**32, 64, dtype=np.uint32))
    eng.xor(a, a)
    eng.digest(a)
    eng.digest(a)
    eng.stream_cipher(a, jnp.array([1, 2], dtype=jnp.uint32))
    per = eng.cycles_for(64 * 32)
    assert eng.stats.by_op["xor"] == [per, 64 * 32, 1]
    assert eng.stats.by_op["digest"] == [2 * per, 2 * 64 * 32, 2]
    assert eng.stats.by_op["cipher"][2] == 1
    snap = eng.stats.snapshot()
    eng.digest(a)
    assert eng.stats.cycles - snap.cycles == per
    assert eng.stats.by_op["digest"][2] - snap.by_op["digest"][2] == 1
    assert snap.by_op["digest"][2] == 2       # snapshot deep-copied by_op


@pytest.mark.parametrize("method", ["xor", "digest", "cipher", "simulate"])
def test_jitted_engine_ops_account_once_per_call(method):
    """Accounting must happen per execution, not per trace: wrapping an
    engine method in jax.jit and calling it N times records N calls."""
    eng = CimEngine(BankGeometry(banks=2, rows=8, cols=32), impl="ref")
    buf = jnp.asarray(RNG.integers(0, 2**32, 64, dtype=np.uint32))
    key = jnp.array([1, 2], dtype=jnp.uint32)
    f = {"xor": lambda: jax.jit(eng.xor)(buf, buf),
         "digest": lambda: jax.jit(eng.digest)(buf),
         "cipher": lambda: jax.jit(lambda b: eng.stream_cipher(b, key))(buf),
         "simulate": lambda: jax.jit(
             lambda x: eng.simulate(x, x))(jnp.zeros((4, 32)))}[method]
    n = 3
    for _ in range(n):
        jax.block_until_ready(f())
    jax.effects_barrier()         # flush the per-execution stats callbacks
    assert eng.stats.calls == n, eng.stats
    if method != "simulate":
        assert eng.stats.cycles == n * eng.cycles_for(64 * 32)


# ---------------------------------------------------------------------------
# chunked streaming mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [999, 4096, None])
def test_xor_stream_matches_one_shot(chunk):
    eng = CimEngine(impl="ref")
    a = jnp.asarray(RNG.integers(0, 2**32, 100001, dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, 100001, dtype=np.uint32))
    assert np.array_equal(np.asarray(eng.xor_stream(a, b, chunk_words=chunk)),
                          np.asarray(eng.xor(a, b)))
    assert np.array_equal(np.asarray(eng.xnor_stream(a, b,
                                                     chunk_words=chunk)),
                          np.asarray(eng.xnor(a, b)))


@pytest.mark.parametrize("chunk,width", [(999, 128), (4096, 128), (640, 96),
                                         (None, 128)])
def test_digest_stream_matches_one_shot(chunk, width):
    """Stability invariant: the chunked fold equals the one-shot digest for
    any chunk size (chunks are aligned up to whole digest rows)."""
    eng = CimEngine(impl="ref")
    buf = jnp.asarray(RNG.integers(0, 2**32, 100001, dtype=np.uint32))
    assert np.array_equal(
        np.asarray(eng.digest_stream(buf, width, chunk_words=chunk)),
        np.asarray(eng.digest(buf, width)))


def test_digest_stream_handles_non_uint32_leaves():
    eng = CimEngine(impl="ref")
    x = jnp.asarray(RNG.standard_normal(70001), jnp.float32)
    assert np.array_equal(np.asarray(eng.digest_stream(x, chunk_words=4096)),
                          np.asarray(eng.digest(x)))


def test_stream_rejects_shape_mismatch():
    eng = CimEngine(impl="ref")
    with pytest.raises(ValueError):
        eng.xor_stream(jnp.zeros(8, jnp.uint32), jnp.zeros(9, jnp.uint32))
