"""Flash-attention kernel (§Perf It8b follow-up) vs the plain-softmax
oracle, swept over shapes/block sizes/causality in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention, flash_attention_ref

RNG = np.random.default_rng(0)


def _mk(bh, s, dh, dtype=np.float32):
    q = jnp.asarray(RNG.standard_normal((bh, s, dh)), dtype)
    k = jnp.asarray(RNG.standard_normal((bh, s, dh)), dtype)
    v = jnp.asarray(RNG.standard_normal((bh, s, dh)), dtype)
    return q, k, v


@pytest.mark.parametrize("bh,s,dh", [(2, 64, 16), (1, 128, 32), (3, 256, 8)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(bh, s, dh, causal):
    q, k, v = _mk(bh, s, dh)
    want = flash_attention_ref(q, k, v, causal)
    got = flash_attention(q, k, v, causal=causal, bq=32, bk=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(16, 64), (64, 16), (128, 32)])
def test_flash_block_shapes(bq, bk):
    q, k, v = _mk(2, 128, 16)
    want = flash_attention_ref(q, k, v, True)
    got = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q, k, v = _mk(2, 64, 16, np.float32)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    want = flash_attention_ref(qb, kb, vb, True)
    got = flash_attention(qb, kb, vb, causal=True, bq=32, bk=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
