"""Serving workloads over the unchanged engine core (DESIGN.md §16).

Pins the two §16 workload drivers:

* :class:`TranscriptionService` — transcripts are schedule-independent
  (any slot count yields the same tokens, the §13 (rid, step) seed-folding
  guarantee lifted to chained sessions), incremental (each window's prompt
  carries the transcript tail), and fully drain the engine.
* :class:`ClassifierService` — the paper's Fig. 6 classification workload:
  accuracy through the serve path, packed-XNOR == float-sign predictions,
  and one-shot (``max_new_tokens=1``) slot turnover with more images than
  slots.
"""

import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import bcnn, lm
from repro.serve import (ClassifierService, TranscriptStream,
                         TranscriptionService, synthetic_audio_trace)


@pytest.fixture(scope="module")
def whisper():
    cfg = configs.get("whisper-tiny").smoke(dtype=jnp.float32)
    key = jax.random.PRNGKey(zlib.crc32(b"whisper-tiny") % 2**31)
    return cfg, lm.init_params(cfg, key)


@pytest.fixture(scope="module")
def classifier():
    """One trained service shared by the classifier tests (training is the
    expensive part; the tests exercise serving)."""
    return ClassifierService(slots=3, train_steps=150, seed=0)


# ---------------------------------------------------------------------------
# streaming transcription
# ---------------------------------------------------------------------------


def test_transcription_schedule_independent(whisper):
    """slots=1 (fully serial) and slots=3 (streams interleaved) emit
    bit-identical transcripts — scheduling never leaks into sampling."""
    cfg, params = whisper
    streams = synthetic_audio_trace(3, 2, n_ctx_tokens=cfg.n_ctx_tokens,
                                    d_model=cfg.d_model, seed=5)
    outs = [TranscriptionService(cfg, params, slots=s, seed=7)
            .transcribe(streams) for s in (1, 3)]
    assert outs[0] == outs[1]
    assert sorted(outs[0]) == [0, 1, 2]
    # every window contributed its full budget (eos is disabled)
    assert all(len(t) == 2 * 4 for t in outs[0].values())


def test_transcription_is_incremental(whisper):
    """Window prompts carry the transcript tail (bounded by ``carry``),
    and the engine sees exactly one prefill per window."""
    cfg, params = whisper
    svc = TranscriptionService(cfg, params, slots=2, tokens_per_window=3,
                               carry=4, seed=1)
    assert svc._prompt([]).tolist() == [svc.bos_id]
    assert svc._prompt([5, 6]).tolist() == [svc.bos_id, 5, 6]
    assert svc._prompt(list(range(10))).tolist() == [svc.bos_id, 6, 7, 8, 9]
    streams = synthetic_audio_trace(2, 3, n_ctx_tokens=cfg.n_ctx_tokens,
                                    d_model=cfg.d_model, seed=2)
    out = svc.transcribe(streams)
    assert all(len(t) == 3 * 3 for t in out.values())
    assert svc.stats.prefills == 2 * 3
    # a second transcribe() call starts from a fresh engine + rid space
    assert svc.transcribe(streams) == out


def test_transcription_validation(whisper):
    cfg, params = whisper
    with pytest.raises(ValueError, match="enc-dec"):
        TranscriptionService(configs.get("qwen3-4b").smoke(), params)
    with pytest.raises(ValueError, match="s_max"):
        TranscriptionService(cfg, params, carry=30, tokens_per_window=8,
                             s_max=32)
    svc = TranscriptionService(cfg, params, slots=2)
    w = np.zeros((cfg.n_ctx_tokens, cfg.d_model), np.float32)
    dup = [TranscriptStream(sid=1, windows=[w]),
           TranscriptStream(sid=1, windows=[w])]
    with pytest.raises(ValueError, match="duplicate"):
        svc.transcribe(dup)
    with pytest.raises(ValueError, match="no windows"):
        TranscriptStream(sid=0, windows=[])


# ---------------------------------------------------------------------------
# XNOR-CNN classification
# ---------------------------------------------------------------------------


def test_classifier_accuracy_through_engine(classifier):
    """Serve-path predictions hit the example's accuracy on held-out
    images, and every emitted token is a class id (training suppressed the
    query/spare vocab entries)."""
    assert classifier.train_acc >= 0.95
    imgs, y = bcnn.synthetic_images(jax.random.PRNGKey(99), 32)
    pred = classifier.classify(np.asarray(imgs))
    assert pred.shape == (32,)
    assert set(np.unique(pred)) <= {0, 1}
    assert float(np.mean(pred == np.asarray(y))) >= 0.9


def test_classifier_packed_matches_float(classifier):
    """pack=True (resident packed bit-planes, popcount GEMM) and
    pack=False (float sign weights) classify identically."""
    imgs, _ = bcnn.synthetic_images(jax.random.PRNGKey(7), 16)
    packed = classifier.classify(np.asarray(imgs))
    float_svc = ClassifierService(cfg=classifier.cfg,
                                  params=classifier.params,
                                  slots=3, pack=False)
    np.testing.assert_array_equal(float_svc.classify(np.asarray(imgs)),
                                  packed)


def test_classifier_one_shot_sessions(classifier):
    """More images than slots: every request is a one-shot session that
    finishes at its prefill sample, so slots turn over and the whole batch
    drains without decode budget."""
    before = classifier.stats.prefills
    imgs, _ = bcnn.synthetic_images(jax.random.PRNGKey(11), 10)
    pred = classifier.classify(np.asarray(imgs))
    assert pred.shape == (10,)
    assert classifier.stats.prefills == before + 10
    sessions = list(classifier.engine.sessions.values())[-10:]
    assert all(s.finish_reason == "length" and len(s.tokens) == 1
               for s in sessions)
    # persistent engine: a repeat batch reuses slots under fresh rids and
    # stays deterministic (temperature is pinned to 0)
    np.testing.assert_array_equal(classifier.classify(np.asarray(imgs)),
                                  pred)


def test_classifier_rejects_wrong_geometry(classifier):
    with pytest.raises(ValueError, match="pixels"):
        classifier.classify(np.zeros((2, 8, 8), np.float32))
