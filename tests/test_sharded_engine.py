"""Sharded engine (DESIGN.md §11): ShardedCimEngine must be bit-identical to
the single-device CimEngine on whatever device grid the host exposes (1 in
the plain suite; the interpret+8-device CI job and the subprocess sweep in
test_distributed.py exercise real multi-device meshes), plus the streaming
mode and the device tier of the cycle model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import verify
from repro.core.engine import BankGeometry, CimEngine, ShardedCimEngine
from repro.launch import mesh as mesh_mod

RNG = np.random.default_rng(0)


@pytest.fixture(scope="module")
def sharded():
    return ShardedCimEngine(mesh_mod.make_engine_mesh(), impl="ref")


@pytest.fixture
def single():
    return CimEngine(impl="ref")


@pytest.mark.parametrize("n", [1, 37, 4096, 70001])
@pytest.mark.parametrize("op", ["xor", "xnor"])
def test_sharded_bulk_matches_single_device(sharded, single, n, op):
    a = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    got = getattr(sharded, op)(a, b)
    want = getattr(single, op)(a, b)
    assert got.shape == a.shape and got.dtype == jnp.uint32
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,width", [(1, 128), (5000, 128), (70001, 128),
                                     (5000, 96), (333, 32)])
def test_sharded_digest_matches_single_device(sharded, single, n, width):
    buf = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    assert np.array_equal(np.asarray(sharded.digest(buf, width)),
                          np.asarray(single.digest(buf, width)))


@pytest.mark.parametrize("n,ctr", [(1, 0), (4096, 11), (70001, 2**32 - 7)])
def test_sharded_cipher_matches_single_device_and_involutes(sharded, single,
                                                            n, ctr):
    buf = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    key = jnp.asarray(RNG.integers(0, 2**32, 2, dtype=np.uint32))
    enc = sharded.stream_cipher(buf, key, counter=ctr)
    assert np.array_equal(np.asarray(enc),
                          np.asarray(single.stream_cipher(buf, key,
                                                          counter=ctr)))
    dec = sharded.stream_cipher(enc, key, counter=ctr)
    assert np.array_equal(np.asarray(dec), np.asarray(buf))


def test_sharded_digest_of_float_tree_matches_host(sharded):
    tree = {"w": jnp.asarray(RNG.standard_normal((64, 33)), jnp.float32),
            "b": jnp.asarray(RNG.standard_normal((129,)), jnp.float32)}
    dig = verify.tree_digest(tree, engine=sharded)
    for k, v in tree.items():
        assert np.array_equal(np.asarray(dig[k]),
                              verify.np_digest(np.asarray(v))), k
    ok, _ = verify.verify_trees(tree, tree, engine=sharded)
    assert bool(ok)


def test_sharded_engine_streams_in_chunks(sharded, single):
    buf = jnp.asarray(RNG.integers(0, 2**32, 100001, dtype=np.uint32))
    b2 = jnp.asarray(RNG.integers(0, 2**32, 100001, dtype=np.uint32))
    for chunk in (999, 1 << 14):
        assert np.array_equal(np.asarray(sharded.xor_stream(buf, b2, chunk)),
                              np.asarray(single.xor(buf, b2)))
        assert np.array_equal(
            np.asarray(sharded.digest_stream(buf, chunk_words=chunk)),
            np.asarray(single.digest(buf)))


def test_device_tier_of_cycle_model(sharded):
    """devices x banks x cols bits/cycle: the mesh multiplies throughput."""
    d = sharded.geometry.devices
    assert d == len(sharded.mesh.devices)
    base = BankGeometry()
    assert sharded.geometry.bits_per_cycle == d * base.bits_per_cycle
    nbits = 1 << 24
    assert sharded.cycles_for(nbits) == -(-nbits
                                          // (d * base.banks * base.cols))


def test_sharded_engine_accounts_stats():
    eng = ShardedCimEngine(mesh_mod.make_engine_mesh(), impl="ref")
    buf = jnp.asarray(RNG.integers(0, 2**32, 256, dtype=np.uint32))
    eng.xor(buf, buf)
    eng.digest(buf)
    assert eng.stats.calls == 2
    assert eng.stats.bit_ops == 2 * 256 * 32
    assert eng.stats.cycles == 2 * eng.cycles_for(256 * 32)


def test_sharded_verify_copy_accepts_non_uint32(sharded):
    """verify_copy must route non-uint32 buffers through as_words on the
    sharded engine too (the bulk path is uint32-only)."""
    x = jnp.asarray(RNG.standard_normal((65, 7)), jnp.float32)
    assert bool(sharded.verify_copy(x, jnp.array(x)))
    assert not bool(sharded.verify_copy(x, x.at[64, 6].set(x[64, 6] + 1)))
    with pytest.raises(ValueError, match="shape/dtype"):
        sharded.verify_copy(x, x.astype(jnp.int32))


def test_sharded_digest_chunks_matches_single_device(sharded, single):
    buf = jnp.asarray(RNG.integers(0, 2**32, 5 * 384 + 100, dtype=np.uint32))
    got = np.asarray(sharded.digest_chunks(buf, 384))
    want = np.asarray(single.digest_chunks(buf, 384))
    assert got.shape == (6, 128)
    assert np.array_equal(got, want)


def test_sharded_engine_rejects_bad_inputs(sharded):
    a = jnp.zeros(8, jnp.uint32)
    with pytest.raises(TypeError):
        sharded.xor(a.astype(jnp.float32), a)
    with pytest.raises(ValueError):
        sharded.xor(a, jnp.zeros(9, jnp.uint32))
    with pytest.raises(TypeError):
        sharded.stream_cipher(jnp.zeros(4, jnp.float32), jnp.zeros(2,
                                                                   jnp.uint32))
    with pytest.raises(ValueError):
        ShardedCimEngine(mesh_mod.make_engine_mesh(), axis="nope")


def test_engine_mesh_axis_is_bank():
    mesh = mesh_mod.make_engine_mesh()
    assert mesh.axis_names == ("bank",)
    with pytest.raises(ValueError):
        mesh_mod.make_engine_mesh(len(jax.devices()) + 1)
