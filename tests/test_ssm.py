"""Recurrent-mixer math: chunkwise mLSTM vs sequential oracle, RG-LRU
associative scan vs stepwise, conv1d train/decode agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm

RNG = np.random.default_rng(1)


def _mk(b, s, nh, dh):
    q = jnp.asarray(RNG.standard_normal((b, s, nh, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, nh, dh)), jnp.float32) / np.sqrt(dh)
    v = jnp.asarray(RNG.standard_normal((b, s, nh, dh)), jnp.float32)
    i = jnp.asarray(RNG.standard_normal((b, s, nh)) * 2, jnp.float32)
    f = jnp.asarray(RNG.standard_normal((b, s, nh)) * 2 + 2, jnp.float32)
    return q, k, v, i, f


@pytest.mark.parametrize("chunk", [4, 8, 16, 32, 7])
def test_mlstm_chunkwise_matches_sequential(chunk):
    b, s, nh, dh = 2, 32, 3, 8
    q, k, v, i, f = _mk(b, s, nh, dh)
    st0 = ssm.MLSTMState.zeros(b, nh, dh)
    h_seq, st_seq = ssm.mlstm_sequential(q, k, v, i, f, st0)
    h_ch, st_ch = ssm.mlstm_chunkwise(q, k, v, i, f, st0, chunk)
    np.testing.assert_allclose(np.asarray(h_seq), np.asarray(h_ch),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_seq.c), np.asarray(st_ch.c),
                               rtol=2e-4, atol=2e-5)


def test_mlstm_state_continuation():
    b, s, nh, dh = 2, 32, 2, 8
    q, k, v, i, f = _mk(b, s, nh, dh)
    st0 = ssm.MLSTMState.zeros(b, nh, dh)
    h_all, _ = ssm.mlstm_sequential(q, k, v, i, f, st0)
    h1, st1 = ssm.mlstm_chunkwise(q[:, :16], k[:, :16], v[:, :16],
                                  i[:, :16], f[:, :16], st0, 8)
    h2, _ = ssm.mlstm_chunkwise(q[:, 16:], k[:, 16:], v[:, 16:],
                                i[:, 16:], f[:, 16:], st1, 8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(h_all), rtol=2e-4, atol=2e-5)


def test_mlstm_grad_finite_through_chunkwise():
    b, s, nh, dh = 1, 16, 2, 4
    q, k, v, i, f = _mk(b, s, nh, dh)

    def loss(q):
        h, _ = ssm.mlstm_chunkwise(q, k, v, i, f,
                                   ssm.MLSTMState.zeros(b, nh, dh), 8)
        return jnp.sum(h * h)
    g = jax.grad(loss)(q)
    assert np.isfinite(np.asarray(g)).all()


def test_rglru_scan_matches_steps():
    b, s, d = 2, 24, 16
    x, r, i = (jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
               for _ in range(3))
    lam = jnp.asarray(RNG.standard_normal((d,)), jnp.float32)
    h_par, st_par = ssm.rglru(x, r, i, lam, 8.0, ssm.RGLRUState.zeros(b, d))
    st = ssm.RGLRUState.zeros(b, d)
    hs = []
    for t in range(s):
        ht, st = ssm.rglru_step(x[:, t:t+1], r[:, t:t+1], i[:, t:t+1],
                                lam, 8.0, st)
        hs.append(ht)
    np.testing.assert_allclose(np.asarray(h_par),
                               np.asarray(jnp.concatenate(hs, 1)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_par.h), np.asarray(st.h),
                               rtol=1e-5, atol=1e-6)


def test_rglru_decay_bounded():
    """|a_t| < 1 => bounded state for bounded input (stability invariant)."""
    b, s, d = 1, 512, 8
    x = jnp.ones((b, s, d))
    r = jnp.full((b, s, d), 5.0)
    i = jnp.zeros((b, s, d))
    lam = jnp.ones((d,))
    h, _ = ssm.rglru(x, r, i, lam, 8.0, ssm.RGLRUState.zeros(b, d))
    assert np.isfinite(np.asarray(h)).all()
    assert np.abs(np.asarray(h)).max() < 100


def test_conv1d_step_matches_sequence():
    b, s, d, w = 2, 10, 6, 4
    x = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    kern = jnp.asarray(RNG.standard_normal((w, d)), jnp.float32)
    y_full = ssm.conv1d(x, kern)
    buf = jnp.zeros((b, w - 1, d))
    ys = []
    for t in range(s):
        yt, buf = ssm.conv1d_step(buf, x[:, t:t+1], kern)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-5, atol=1e-6)


def test_slstm_finite_and_gated():
    b, s, d, nh = 2, 64, 16, 4
    xg = jnp.asarray(RNG.standard_normal((b, s, 4 * d)), jnp.float32)
    rk = jnp.asarray(RNG.standard_normal((4, nh, d // nh, d // nh)) * 0.1,
                     jnp.float32)
    h, st = ssm.slstm_sequence(xg, rk, ssm.SLSTMState.zeros(b, d), nh)
    assert np.isfinite(np.asarray(h)).all()
    assert np.abs(np.asarray(h)).max() <= 1.0 + 1e-5  # |o*c/n| <= 1 with tanh z
