"""Continuous-batching serve engine + packed-weight residency tests
(DESIGN.md §13).

Scheduler bookkeeping is exercised as pure host logic (SlotPool); the
engine is checked token-for-token against a per-request static reference
(heterogeneous prompts sharing a batch must not change any request's
tokens); the packed serve path is checked *bit-exact* against the float
sign path for every arch that binarizes linears, with the float weights
asserted absent from the resident tree.
"""

import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.checkpoint import ckpt
from repro.core.xnor_layers import PackedLinear
from repro.models import lm
from repro.models import params as pdefs
from repro.serve import Request, ServeEngine, Session, SlotPool, synthetic_trace

ARCHS = sorted(configs.ALL)


def _setup(name, seed_salt="", **over):
    cfg = configs.get(name).smoke(dtype=jnp.float32, **over)
    key = jax.random.PRNGKey(zlib.crc32((name + seed_salt).encode()) % 2**31)
    params = lm.init_params(cfg, key)
    return cfg, params


def _ref_generate(cfg, params, req, s_max):
    """Static per-request greedy reference (eager prefill + decode loop)."""
    ctx = None if req.ctx is None else jnp.asarray(np.asarray(req.ctx)[None])
    lg, st = lm.prefill(cfg, params, jnp.asarray(req.prompt[None]), ctx,
                        s_max=s_max)
    tok = jnp.argmax(lg[..., :cfg.vocab][:, -1], -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    for _ in range(req.max_new_tokens - 1):
        lg, st = lm.decode_step(cfg, params, tok, st)
        tok = jnp.argmax(lg[..., :cfg.vocab][:, -1],
                         -1).astype(jnp.int32)[:, None]
        out.append(int(tok[0, 0]))
    return out


# ---------------------------------------------------------------------------
# SlotPool: pure scheduling bookkeeping
# ---------------------------------------------------------------------------


def _sess(rid):
    return Session(Request(rid=rid, prompt=np.array([1]), max_new_tokens=1),
                   t_submit=0.0)


def test_slot_pool_fifo_admission_lowest_slot():
    pool = SlotPool(2)
    for rid in range(4):
        pool.submit(_sess(rid))
    s0, slot0 = pool.admit()
    s1, slot1 = pool.admit()
    assert (s0.request.rid, slot0) == (0, 0)
    assert (s1.request.rid, slot1) == (1, 1)
    assert not pool.admissible()          # full
    pool.evict(slot0)
    assert pool.free_slots == [0]
    s2, slot2 = pool.admit()
    assert (s2.request.rid, slot2) == (2, 0)   # FIFO into the freed slot


def test_slot_pool_lowest_free_slot_reused_first():
    pool = SlotPool(3)
    for rid in range(6):
        pool.submit(_sess(rid))
    slots = [pool.admit()[1] for _ in range(3)]
    assert slots == [0, 1, 2]
    pool.evict(2)
    pool.evict(0)
    assert pool.free_slots == [0, 2]      # kept sorted: lowest first
    assert pool.admit()[1] == 0
    assert pool.admit()[1] == 2


def test_slot_pool_errors_and_idle():
    pool = SlotPool(1)
    with pytest.raises(RuntimeError):
        pool.admit()                      # empty queue
    pool.submit(_sess(0))
    _, slot = pool.admit()
    pool.submit(_sess(1))
    with pytest.raises(RuntimeError):
        pool.admit()                      # no free slot
    with pytest.raises(KeyError):
        pool.evict(slot + 1)
    assert not pool.idle()
    pool.evict(slot)
    pool.admit()
    pool.evict(slot)
    assert pool.idle()
    with pytest.raises(ValueError):
        SlotPool(0)


# ---------------------------------------------------------------------------
# engine: heterogeneous batches match the per-request static reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["qwen3-4b", "recurrentgemma-2b",
                                  "whisper-tiny"])
def test_engine_matches_static_reference(name):
    """Mixed prompt lengths / budgets sharing a batch: every request's
    tokens equal its standalone static decode (dense, local-window
    rolling cache, and enc-dec cross-attn state all scattered per slot)."""
    cfg, params = _setup(name)
    trace = synthetic_trace(5, cfg.vocab, seed=2, prompt_lens=(4, 6, 9),
                            new_tokens=(3, 6),
                            n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model)
    eng = ServeEngine(cfg, params, slots=2, s_max=24)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    assert report.prefills == len(trace)
    for r in trace:
        want = _ref_generate(cfg, params, r, s_max=24)
        assert report.tokens(r.rid).tolist() == want, r.rid
        sess = report.sessions[r.rid]
        assert sess.finish_reason == "length"
        assert sess.t_submit <= sess.t_admit <= sess.t_first <= sess.t_done
    assert eng.pool.idle()


def test_engine_single_slot_reuses_and_preserves_order():
    cfg, params = _setup("qwen3-4b")
    trace = synthetic_trace(3, cfg.vocab, seed=5, prompt_lens=(4, 7),
                            new_tokens=(2, 4))
    eng = ServeEngine(cfg, params, slots=1, s_max=16)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    admits = sorted(report.sessions.values(), key=lambda s: s.t_admit)
    assert [s.request.rid for s in admits] == [0, 1, 2]   # FIFO through 1 slot
    for r in trace:
        assert report.tokens(r.rid).tolist() == _ref_generate(
            cfg, params, r, s_max=16)


def test_engine_eos_eviction():
    """EOS terminates a request early (including at prefill) and frees the
    slot for the queue; non-EOS requests run to budget."""
    cfg, params = _setup("qwen3-4b")
    trace = synthetic_trace(4, cfg.vocab, seed=9, prompt_lens=(4, 6, 8),
                            new_tokens=(6,))
    refs = {r.rid: _ref_generate(cfg, params, r, s_max=20) for r in trace}
    eos = refs[0][1]      # second token of request 0 -> it must stop at 2
    eng = ServeEngine(cfg, params, slots=2, s_max=20, eos_id=eos)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    for r in trace:
        want = refs[r.rid]
        if eos in want:
            want = want[:want.index(eos) + 1]
            assert report.sessions[r.rid].finish_reason == "eos"
        else:
            assert report.sessions[r.rid].finish_reason == "length"
        assert report.tokens(r.rid).tolist() == want, r.rid
    assert len(report.tokens(0)) == 2
    assert eng.pool.idle()


def test_engine_deterministic_across_slot_counts():
    """Sampling keys depend on (request, step) only: the same seeded trace
    gives identical tokens whatever the slot count / schedule."""
    cfg, params = _setup("qwen3-4b")

    def run(slots):
        eng = ServeEngine(cfg, params, slots=slots, s_max=20,
                          temperature=0.7, seed=11)
        for r in synthetic_trace(5, cfg.vocab, seed=3, prompt_lens=(4, 6),
                                 new_tokens=(3, 5)):
            eng.submit(r)
        rep = eng.run()
        return {rid: rep.tokens(rid).tolist() for rid in rep.sessions}

    a, b, c = run(1), run(2), run(4)
    assert a == b == c


def test_engine_submit_validation():
    cfg, params = _setup("qwen3-4b")
    eng = ServeEngine(cfg, params, slots=1, s_max=8)
    eng.submit(Request(rid=0, prompt=np.arange(4), max_new_tokens=5))
    with pytest.raises(ValueError):       # duplicate rid
        eng.submit(Request(rid=0, prompt=np.arange(4), max_new_tokens=2))
    with pytest.raises(ValueError):       # prompt + budget - 1 > s_max
        eng.submit(Request(rid=1, prompt=np.arange(6), max_new_tokens=4))
    with pytest.raises(ValueError):
        Request(rid=2, prompt=np.arange(4), max_new_tokens=0)


def test_report_quantiles_nan_safe_with_nothing_finished():
    """A report over only in-flight (or zero) sessions must answer every
    quantile helper with NaN — never raise, and never a fake 0.0 that a
    dashboard or CI gate would read as "instant".  The replicated router
    aggregates per-replica reports mid-drill, where a replica can
    legitimately have nothing finished yet."""
    from repro.serve import ServeReport

    live = Session(Request(rid=0, prompt=np.arange(4), max_new_tokens=3),
                   t_submit=100.0)     # never admitted, never finished
    for sessions in ({}, {0: live}):
        rep = ServeReport(sessions=sessions, wall=0.5, decode_steps=0,
                          prefills=0)
        for qs in (rep.latency_quantiles(), rep.ttft_quantiles(),
                   rep.ttft_step_quantiles(), rep.queue_wait_quantiles()):
            assert set(qs) == {0.5, 0.95}
            assert all(np.isnan(v) for v in qs.values()), (sessions, qs)
    assert rep.generated == 0 and rep.tok_per_s == 0.0


def test_report_quantiles_ignore_in_flight_sessions():
    """Finished sessions dominate the quantiles; in-flight ones (NaN
    latency/ttft) are dropped from the sample, not poisoning it."""
    from repro.serve import ServeReport

    done = Session(Request(rid=1, prompt=np.arange(4), max_new_tokens=2),
                   t_submit=10.0)
    done.t_admit, done.t_first, done.t_done = 11.0, 12.0, 14.0
    done.finish_reason = "length"
    live = Session(Request(rid=2, prompt=np.arange(4), max_new_tokens=2),
                   t_submit=10.0)
    rep = ServeReport(sessions={1: done, 2: live}, wall=1.0,
                      decode_steps=0, prefills=0)
    lat = rep.latency_quantiles()
    assert lat[0.5] == pytest.approx(4.0) and lat[0.95] == pytest.approx(4.0)
    assert rep.ttft_quantiles()[0.5] == pytest.approx(2.0)
    assert rep.queue_wait_quantiles()[0.5] == pytest.approx(1.0)


def test_generate_wrapper_matches_static_loop():
    """serve_step.generate (now an engine wrapper) is token-identical to
    the historical static-batch loop for greedy decoding."""
    from repro.train import serve_step

    cfg, params = _setup("qwen3-4b")
    key = jax.random.PRNGKey(8)
    B, P, N = 3, 6, 5
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)
    got = np.asarray(serve_step.generate(cfg, params, prompt, N))
    for i in range(B):
        req = Request(rid=i, prompt=np.asarray(prompt[i]), max_new_tokens=N)
        assert got[i].tolist() == _ref_generate(cfg, params, req, s_max=P + N)


def test_decode_state_spec_per_slot_pos():
    cfg = configs.get("qwen3-4b").smoke()
    st = lm.decode_state_spec(cfg, 3, 16, abstract=True, per_slot_pos=True)
    assert st.pos.shape == (3,) and st.pos.dtype == jnp.int32
    st0 = lm.decode_state_spec(cfg, 3, 16, abstract=True)
    assert st0.pos.shape == ()


# ---------------------------------------------------------------------------
# packed-weight residency: bit-exactness + float absence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ARCHS)
def test_packed_serve_path_bit_exact(name):
    """Full-model prefill+decode logits from prepacked weights are
    bit-identical to the float sign path, for every arch under +xnor
    (runs in whichever REPRO_KERNEL_IMPL mode CI selects)."""
    cfg, params = _setup(name + "+xnor")
    assert cfg.quant == "xnor"
    packed = lm.pack_params(cfg, params)
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    ctx = None
    if cfg.n_ctx_tokens:
        ctx = jax.random.normal(key, (2, cfg.n_ctx_tokens, cfg.d_model),
                                jnp.float32) * 0.1
    lf, sf = lm.prefill(cfg, params, tokens[:, :5], ctx, s_max=10)
    lp, sp = lm.prefill(cfg, packed, tokens[:, :5], ctx, s_max=10)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lp))
    for t in range(5, 7):
        lf, sf = lm.decode_step(cfg, params, tokens[:, t:t+1], sf)
        lp, sp = lm.decode_step(cfg, packed, tokens[:, t:t+1], sp)
        np.testing.assert_array_equal(np.asarray(lf), np.asarray(lp))


def test_packed_params_hold_no_float_binary_weights():
    """The packed-residency contract: every binarizable linear's float
    weight is absent from the serve tree (only uint32 planes + f32 beta
    remain), and the resident footprint shrinks."""
    cfg, params = _setup("qwen2-7b+xnor")
    packed = lm.pack_params(cfg, params)
    defs = lm.param_defs(cfg)
    flat_defs = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, pdefs.ParamDef))[0]
    flat_params = dict(jax.tree_util.tree_flatten_with_path(params)[0])
    # the engine keeps the packed tree resident, not the float one
    eng = ServeEngine(cfg, params, slots=1, s_max=8)
    n_bin = 0
    for tree in (packed, eng.params):
        flat_packed = dict(jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: isinstance(x, PackedLinear))[0])
        for path, d in flat_defs:
            leaf = flat_packed[path]
            if d.binarize:
                n_bin += 1
                assert isinstance(leaf, PackedLinear), path
                assert leaf.pb.dtype == jnp.uint32
                assert leaf.beta.dtype == jnp.float32
                n, k, m = d.shape
                assert leaf.pb.shape == (n, m, -(-k // 32))
                assert leaf.beta.shape == (n, m)
                assert leaf.k == k      # true K rides as static aux data
            else:
                assert not isinstance(leaf, PackedLinear), path
                np.testing.assert_array_equal(np.asarray(leaf),
                                              np.asarray(flat_params[path]))
    assert n_bin > 0
    fbytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))
    pbytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(packed))
    assert pbytes < fbytes


def test_pack_params_identity_for_unquantized():
    cfg, params = _setup("qwen3-4b")
    assert lm.pack_params(cfg, params) is params


def test_pack_params_idempotent_and_composes_with_restore_packed(tmp_path):
    """A tree loaded via restore_packed can feed consumers that pack by
    default (ServeEngine): pack() passes PackedLinear leaves through."""
    cfg, params = _setup("qwen2-7b+xnor")
    p1 = lm.pack_params(cfg, params)
    p2 = lm.pack_params(cfg, p1)
    assert jax.tree.structure(p1) == jax.tree.structure(p2)
    assert all(a is b for a, b in zip(jax.tree.leaves(p1),
                                      jax.tree.leaves(p2)))
    ckpt.save(str(tmp_path), 1, params)
    loaded, _ = ckpt.restore_packed(str(tmp_path), None, cfg)

    def run(tree):
        eng = ServeEngine(cfg, tree, slots=1, s_max=12)
        eng.submit(Request(rid=0, prompt=np.arange(4), max_new_tokens=3))
        return eng.run().tokens(0).tolist()

    assert run(loaded) == run(params)


def test_prepacked_width_mismatch_raises():
    """The true-K aux check is a raise (survives python -O), not an assert:
    word-rounded width mismatches must never mis-correct the popcount."""
    from repro.core import xnor_layers

    pl = xnor_layers.pack_linear(jnp.ones((8, 3)))
    assert pl.k == 8
    with pytest.raises(ValueError, match="true K"):
        xnor_layers.xnor_linear_prepacked(jnp.ones((2, 6)), pl.pb, pl.beta,
                                          valid_k=pl.k)


def test_engine_serves_packed_exactly_as_float():
    """End-to-end: packed-resident engine emits the same tokens as the
    float-weight engine on the same trace."""
    cfg, params = _setup("qwen2-7b+xnor")
    trace_args = dict(seed=6, prompt_lens=(4, 7), new_tokens=(3, 5))

    def run(pack):
        eng = ServeEngine(cfg, params, slots=2, s_max=16, pack=pack)
        for r in synthetic_trace(4, cfg.vocab, **trace_args):
            eng.submit(r)
        rep = eng.run()
        return {rid: rep.tokens(rid).tolist() for rid in rep.sessions}

    assert run(True) == run(False)


def test_restore_packed_matches_pack_params(tmp_path):
    cfg, params = _setup("xlstm-350m+xnor")
    ckpt.save(str(tmp_path), 1, params)
    got, step = ckpt.restore_packed(str(tmp_path), None, cfg)
    want = lm.pack_params(cfg, params)
    assert step == 1
    assert jax.tree.structure(got) == jax.tree.structure(want)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_packed_passthrough_unquantized(tmp_path):
    cfg, params = _setup("qwen3-4b")
    ckpt.save(str(tmp_path), 3, params)
    got, _ = ckpt.restore_packed(str(tmp_path), 3, cfg)
    assert jax.tree.structure(got) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
