"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle vs
float-domain semantics, swept over shapes/dtypes, plus hypothesis properties
on the bit-domain invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitpack
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(m, k, dtype=np.float32):
    return RNG.standard_normal((m, k)).astype(dtype)


# ---------------------------------------------------------------------------
# bitpack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 32), (4, 64), (3, 5, 96)])
def test_pack_unpack_roundtrip(shape):
    x = _rand(int(np.prod(shape[:-1])), shape[-1]).reshape(shape)
    p = bitpack.pack_bits(jnp.asarray(x))
    u = bitpack.unpack_bits(p)
    assert np.array_equal(np.asarray(u), np.where(x >= 0, 1.0, -1.0))


@given(st.integers(1, 8), st.integers(1, 130))
@settings(max_examples=30, deadline=None)
def test_pack_roundtrip_property(m, k):
    x = RNG.standard_normal((m, k)).astype(np.float32)
    xp = bitpack.pad_to_word(jnp.asarray(x))
    u = bitpack.unpack_bits(bitpack.pack_bits(xp), k)
    assert np.array_equal(np.asarray(u), np.where(x >= 0, 1.0, -1.0))


def test_binarize_alpha():
    x = _rand(5, 50)
    _, alpha = bitpack.binarize(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(alpha), np.abs(x).mean(-1), rtol=1e-6)


# ---------------------------------------------------------------------------
# xnor gemm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(8, 16, 64), (130, 70, 100), (1, 1, 32),
                                   (33, 5, 31), (256, 128, 512)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_xnor_gemm_matches_float_oracle(m, n, k, dtype):
    a, b = _rand(m, k, dtype), _rand(n, k, dtype)
    pa = bitpack.pack_bits(bitpack.pad_to_word(jnp.asarray(a)))
    pb = bitpack.pack_bits(bitpack.pad_to_word(jnp.asarray(b)))
    want = ref.xnor_dot_float(jnp.asarray(a), jnp.asarray(b))
    got_ref = ops.xnor_matmul(pa, pb, k, impl="ref")
    got_pl = ops.xnor_matmul(pa, pb, k, impl="interpret", bm=8, bn=8, bk=2)
    assert np.array_equal(np.asarray(want), np.asarray(got_ref))
    assert np.array_equal(np.asarray(want), np.asarray(got_pl))


@pytest.mark.parametrize("blocks", [dict(bm=8, bn=8, bk=1),
                                    dict(bm=16, bn=32, bk=4),
                                    dict(bm=128, bn=128, bk=8)])
def test_xnor_gemm_block_shapes(blocks):
    a, b = _rand(64, 256), _rand(48, 256)
    pa = bitpack.pack_bits(jnp.asarray(a))
    pb = bitpack.pack_bits(jnp.asarray(b))
    want = ops.xnor_matmul(pa, pb, 256, impl="ref")
    got = ops.xnor_matmul(pa, pb, 256, impl="interpret", **blocks)
    assert np.array_equal(np.asarray(want), np.asarray(got))


@given(st.integers(1, 20), st.integers(1, 20), st.integers(1, 80))
@settings(max_examples=20, deadline=None)
def test_xnor_gemm_bounds_property(m, n, k):
    """|dot| <= K and dot parity == K parity (±1 sums)."""
    a, b = RNG.standard_normal((m, k)), RNG.standard_normal((n, k))
    pa = bitpack.pack_bits(bitpack.pad_to_word(jnp.asarray(a, jnp.float32)))
    pb = bitpack.pack_bits(bitpack.pad_to_word(jnp.asarray(b, jnp.float32)))
    d = np.asarray(ops.xnor_matmul(pa, pb, k, impl="ref"))
    assert np.abs(d).max() <= k
    assert ((d - k) % 2 == 0).all()


# ---------------------------------------------------------------------------
# fused pack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k", [(4, 64), (7, 50), (256, 1024), (1, 32)])
def test_fused_pack(m, k):
    x = jnp.asarray(_rand(m, k))
    p1, a1 = ops.binarize(x, impl="ref")
    p2, a2 = ops.binarize(x, impl="interpret", bm=4)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6)


# ---------------------------------------------------------------------------
# parity digest
# ---------------------------------------------------------------------------

def test_digest_interpret_matches_ref():
    buf = jnp.asarray(RNG.integers(0, 2**32, 5000, dtype=np.uint32))
    assert np.array_equal(np.asarray(ops.digest(buf, impl="ref")),
                          np.asarray(ops.digest(buf, impl="interpret")))


@given(st.integers(0, 4999), st.integers(0, 31))
@settings(max_examples=25, deadline=None)
def test_digest_detects_any_single_bit_flip(pos, bit):
    buf = jnp.asarray(RNG.integers(0, 2**32, 5000, dtype=np.uint32))
    d0 = np.asarray(ops.digest(buf, impl="ref"))
    flipped = buf.at[pos].set(buf[pos] ^ np.uint32(1 << bit))
    d1 = np.asarray(ops.digest(flipped, impl="ref"))
    # XOR linearity: exactly one digest bit differs
    diff = d0 ^ d1
    assert sum(int(x).bit_count() for x in diff) == 1


def test_digest_order_sensitivity_is_columnwise():
    """Digest folds rows; swapping two words in the same column is invisible
    (XOR commutes) — documented property, not a defect of parity checking
    (the paper's check is positional row-vs-row, ours is stream parity)."""
    buf = jnp.arange(512, dtype=jnp.uint32)
    swapped = buf.at[0].set(buf[128]).at[128].set(buf[0])
    assert np.array_equal(np.asarray(ops.digest(buf)), np.asarray(ops.digest(swapped)))


# ---------------------------------------------------------------------------
# cipher
# ---------------------------------------------------------------------------

@given(st.integers(1, 3000), st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_cipher_involution_property(n, ctr):
    buf = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    key = jnp.asarray(RNG.integers(0, 2**32, 2, dtype=np.uint32))
    enc = ops.stream_cipher(buf, key, counter=ctr, impl="ref")
    dec = ops.stream_cipher(enc, key, counter=ctr, impl="ref")
    assert np.array_equal(np.asarray(dec), np.asarray(buf))


def test_cipher_interpret_matches_ref_and_scrambles():
    buf = jnp.asarray(RNG.integers(0, 2**32, 4096, dtype=np.uint32))
    key = jnp.array([123, 456], dtype=jnp.uint32)
    c1 = ops.stream_cipher(buf, key, counter=7, impl="ref")
    c2 = ops.stream_cipher(buf, key, counter=7, impl="interpret")
    assert np.array_equal(np.asarray(c1), np.asarray(c2))
    assert not np.array_equal(np.asarray(c1), np.asarray(buf))
    # different key/counter -> different stream
    c3 = ops.stream_cipher(buf, key, counter=8, impl="ref")
    assert not np.array_equal(np.asarray(c1), np.asarray(c3))


def test_cipher_rejects_non_uint32():
    with pytest.raises(TypeError):
        ops.stream_cipher(jnp.zeros(4, jnp.float32), jnp.zeros(2, jnp.uint32))
