"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle vs
float-domain semantics, swept over shapes/dtypes.  Hypothesis properties on
the bit-domain invariants live in test_kernels_properties.py (importorskip-
guarded so this file collects without hypothesis installed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitpack
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(m, k, dtype=np.float32):
    return RNG.standard_normal((m, k)).astype(dtype)


# ---------------------------------------------------------------------------
# bitpack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 32), (4, 64), (3, 5, 96)])
def test_pack_unpack_roundtrip(shape):
    x = _rand(int(np.prod(shape[:-1])), shape[-1]).reshape(shape)
    p = bitpack.pack_bits(jnp.asarray(x))
    u = bitpack.unpack_bits(p)
    assert np.array_equal(np.asarray(u), np.where(x >= 0, 1.0, -1.0))


def test_binarize_alpha():
    x = _rand(5, 50)
    _, alpha = bitpack.binarize(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(alpha), np.abs(x).mean(-1), rtol=1e-6)


# ---------------------------------------------------------------------------
# xnor gemm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(8, 16, 64), (130, 70, 100), (1, 1, 32),
                                   (33, 5, 31), (256, 128, 512)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_xnor_gemm_matches_float_oracle(m, n, k, dtype):
    a, b = _rand(m, k, dtype), _rand(n, k, dtype)
    pa = bitpack.pack_bits(bitpack.pad_to_word(jnp.asarray(a)))
    pb = bitpack.pack_bits(bitpack.pad_to_word(jnp.asarray(b)))
    want = ref.xnor_dot_float(jnp.asarray(a), jnp.asarray(b))
    got_ref = ops.xnor_matmul(pa, pb, k, impl="ref")
    got_pl = ops.xnor_matmul(pa, pb, k, impl="interpret", bm=8, bn=8, bk=2)
    assert np.array_equal(np.asarray(want), np.asarray(got_ref))
    assert np.array_equal(np.asarray(want), np.asarray(got_pl))


@pytest.mark.parametrize("blocks", [dict(bm=8, bn=8, bk=1),
                                    dict(bm=16, bn=32, bk=4),
                                    dict(bm=128, bn=128, bk=8)])
def test_xnor_gemm_block_shapes(blocks):
    a, b = _rand(64, 256), _rand(48, 256)
    pa = bitpack.pack_bits(jnp.asarray(a))
    pb = bitpack.pack_bits(jnp.asarray(b))
    want = ops.xnor_matmul(pa, pb, 256, impl="ref")
    got = ops.xnor_matmul(pa, pb, 256, impl="interpret", **blocks)
    assert np.array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# fused pack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k", [(4, 64), (7, 50), (256, 1024), (1, 32)])
def test_fused_pack(m, k):
    x = jnp.asarray(_rand(m, k))
    p1, a1 = ops.binarize(x, impl="ref")
    p2, a2 = ops.binarize(x, impl="interpret", bm=4)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6)


# ---------------------------------------------------------------------------
# parity digest
# ---------------------------------------------------------------------------

def test_digest_interpret_matches_ref():
    buf = jnp.asarray(RNG.integers(0, 2**32, 5000, dtype=np.uint32))
    assert np.array_equal(np.asarray(ops.digest(buf, impl="ref")),
                          np.asarray(ops.digest(buf, impl="interpret")))


def test_digest_single_bit_flip_flips_one_digest_bit():
    buf = jnp.asarray(RNG.integers(0, 2**32, 5000, dtype=np.uint32))
    d0 = np.asarray(ops.digest(buf, impl="ref"))
    for pos, bit in [(0, 0), (1234, 17), (4999, 31)]:
        flipped = buf.at[pos].set(buf[pos] ^ np.uint32(1 << bit))
        d1 = np.asarray(ops.digest(flipped, impl="ref"))
        # XOR linearity: exactly one digest bit differs
        diff = d0 ^ d1
        assert sum(int(x).bit_count() for x in diff) == 1


def test_digest_order_sensitivity_is_columnwise():
    """Digest folds rows; swapping two words in the same column is invisible
    (XOR commutes) — documented property, not a defect of parity checking
    (the paper's check is positional row-vs-row, ours is stream parity)."""
    buf = jnp.arange(512, dtype=jnp.uint32)
    swapped = buf.at[0].set(buf[128]).at[128].set(buf[0])
    assert np.array_equal(np.asarray(ops.digest(buf)), np.asarray(ops.digest(swapped)))


# ---------------------------------------------------------------------------
# cipher
# ---------------------------------------------------------------------------

def test_cipher_involution():
    for n, ctr in [(1, 0), (37, 5), (3000, 2**32 - 7)]:
        buf = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
        key = jnp.asarray(RNG.integers(0, 2**32, 2, dtype=np.uint32))
        enc = ops.stream_cipher(buf, key, counter=ctr, impl="ref")
        dec = ops.stream_cipher(enc, key, counter=ctr, impl="ref")
        assert np.array_equal(np.asarray(dec), np.asarray(buf))


def test_cipher_interpret_matches_ref_and_scrambles():
    buf = jnp.asarray(RNG.integers(0, 2**32, 4096, dtype=np.uint32))
    key = jnp.array([123, 456], dtype=jnp.uint32)
    c1 = ops.stream_cipher(buf, key, counter=7, impl="ref")
    c2 = ops.stream_cipher(buf, key, counter=7, impl="interpret")
    assert np.array_equal(np.asarray(c1), np.asarray(c2))
    assert not np.array_equal(np.asarray(c1), np.asarray(buf))
    # different key/counter -> different stream
    c3 = ops.stream_cipher(buf, key, counter=8, impl="ref")
    assert not np.array_equal(np.asarray(c1), np.asarray(c3))


def test_cipher_traced_counter_matches_python_int():
    """The sharded engine offsets the counter per device as a traced uint32;
    the dispatch must accept it and hash identically to the int path."""
    buf = jnp.asarray(RNG.integers(0, 2**32, 300, dtype=np.uint32))
    key = jnp.array([5, 6], dtype=jnp.uint32)
    want = ops.stream_cipher(buf, key, counter=41, impl="ref")
    got = jax.jit(lambda c: ops.stream_cipher(buf, key, counter=c,
                                              impl="ref"))(jnp.uint32(41))
    assert np.array_equal(np.asarray(want), np.asarray(got))


def test_cipher_rejects_non_uint32():
    with pytest.raises(TypeError):
        ops.stream_cipher(jnp.zeros(4, jnp.float32), jnp.zeros(2, jnp.uint32))


# ---------------------------------------------------------------------------
# grid regression: non-divisible shapes must pad up to the tile, never shrink
# the tile to 1 (which explodes the Pallas grid to one row/word per step)
# ---------------------------------------------------------------------------

N_ODD = 513 * 128  # 513 tile rows of 128 words: 513 % 512 != 0


def _spy(monkeypatch, module, name):
    """Record the first operand's shape and the kwargs of a kernel call."""
    seen = {}
    real = getattr(module, name)

    def wrapper(x, *args, **kw):
        seen["rows"], seen["shape"] = x.shape[0], x.shape
        seen.update(kw)
        return real(x, *args, **kw)

    monkeypatch.setattr(module, name, wrapper)
    return seen


def test_digest_grid_never_degenerates_to_one_row(monkeypatch):
    seen = _spy(monkeypatch, ops._parity, "parity_digest")
    buf = jnp.asarray(RNG.integers(0, 2**32, N_ODD, dtype=np.uint32))
    got = ops.digest(buf, impl="interpret")
    assert seen["br"] == 512, seen            # full tile, not br=1
    assert seen["rows"] % seen["br"] == 0
    assert seen["rows"] // seen["br"] == 2    # grid of 2 steps, not 513
    assert np.array_equal(np.asarray(got),
                          np.asarray(ops.digest(buf, impl="ref")))


def test_cipher_grid_never_degenerates_to_one_row(monkeypatch):
    seen = _spy(monkeypatch, ops._cipher, "xor_cipher")
    buf = jnp.asarray(RNG.integers(0, 2**32, N_ODD, dtype=np.uint32))
    key = jnp.array([3, 4], dtype=jnp.uint32)
    got = ops.stream_cipher(buf, key, counter=5, impl="interpret")
    assert seen["br"] == 512, seen
    assert seen["rows"] % seen["br"] == 0
    assert seen["rows"] // seen["br"] == 2
    assert np.array_equal(
        np.asarray(got),
        np.asarray(ops.stream_cipher(buf, key, counter=5, impl="ref")))


def test_binarize_grid_never_degenerates_to_one_row(monkeypatch):
    """300 rows with bm=256 must pad to 512 (grid of 2), not shrink to bm=1
    (grid of 300) — the digest/stream_cipher fix applied to the fused pack."""
    seen = _spy(monkeypatch, ops._pack, "pack")
    x = jnp.asarray(_rand(300, 64))
    p, a = ops.binarize(x, impl="interpret")
    assert seen["bm"] == 256, seen
    assert seen["rows"] % seen["bm"] == 0
    assert seen["rows"] // seen["bm"] == 2    # grid of 2 steps, not 300
    p_ref, a_ref = ops.binarize(x, impl="ref")
    assert p.shape == p_ref.shape and a.shape == a_ref.shape
    assert np.array_equal(np.asarray(p), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=1e-6)


def test_xnor_matmul_tile_never_degenerates_to_bk_one(monkeypatch):
    """kw=96 packed words with bk=64 must pad kw to 128 (k-grid of 2), not
    shrink to bk=1 (k-grid of 96); valid_k keeps the result exact."""
    seen = _spy(monkeypatch, ops._xnor_gemm, "xnor_gemm")
    k = 96 * 32                               # kw = 96 words
    a, b = _rand(16, k), _rand(8, k)
    pa = bitpack.pack_bits(jnp.asarray(a))
    pb = bitpack.pack_bits(jnp.asarray(b))
    got = ops.xnor_matmul(pa, pb, k, impl="interpret")   # default bk=64
    assert seen["bk"] == 64, seen
    assert seen["shape"][1] % seen["bk"] == 0
    assert seen["shape"][1] // seen["bk"] == 2           # k-grid of 2, not 96
    want = ref.xnor_dot_float(jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# bulk XOR/XNOR (the banked engine's compute tile, DESIGN.md §10)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 37, 4096, 70000])
@pytest.mark.parametrize("op", ["xor", "xnor"])
def test_bulk_op_matches_numpy_all_impls(n, op):
    a = RNG.integers(0, 2**32, n, dtype=np.uint32)
    b = RNG.integers(0, 2**32, n, dtype=np.uint32)
    want = ~(a ^ b) if op == "xnor" else a ^ b
    got_ref = ops.bulk_op(jnp.asarray(a), jnp.asarray(b), op, impl="ref")
    got_pl = ops.bulk_op(jnp.asarray(a), jnp.asarray(b), op, impl="interpret")
    assert np.array_equal(np.asarray(got_ref), want)
    assert np.array_equal(np.asarray(got_pl), want)


def test_bulk_op_preserves_shape():
    a = jnp.asarray(RNG.integers(0, 2**32, (13, 17), dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, (13, 17), dtype=np.uint32))
    out = ops.bulk_op(a, b, "xnor", impl="interpret")
    assert out.shape == a.shape and out.dtype == jnp.uint32


def test_as_words_is_byte_true_for_host_64bit_arrays():
    """numpy float64/int64 inputs must stream their true bytes — with x64
    off, a jnp.asarray-first path would silently drop half of every
    element."""
    x = np.arange(10, dtype=np.float64) * 0.5
    w = np.asarray(ops.as_words(x))
    assert w.size == 20 and w.tobytes() == x.tobytes()
    i = np.arange(10, dtype=np.int64) << 40     # live bits above bit 31
    assert np.asarray(ops.as_words(i)).tobytes() == i.tobytes()
    # jax-array and numpy paths agree for 32-bit dtypes
    f = RNG.standard_normal(33).astype(np.float32)
    assert np.array_equal(np.asarray(ops.as_words(f)),
                          np.asarray(ops.as_words(jnp.asarray(f))))


def test_bulk_op_rejects_bad_inputs():
    a = jnp.zeros(8, jnp.uint32)
    with pytest.raises(ValueError):
        ops.bulk_op(a, a, "and")
    with pytest.raises(TypeError):
        ops.bulk_op(a.astype(jnp.float32), a, "xor")
    with pytest.raises(ValueError):
        ops.bulk_op(a, jnp.zeros(9, jnp.uint32), "xor")
