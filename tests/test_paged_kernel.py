"""Fused paged-decode kernel + fused XNOR linear parity suite (DESIGN.md §18).

Two layers of contract:

* kernel vs oracle — ``kernels/paged_attn.py`` (interpret mode) against the
  pure-jnp one-shot-softmax oracle ``kernels/ref.py::paged_decode`` across
  monotone tables, window rings (including recycling past the ring
  capacity), ragged table tails (pos mid-block), GQA groups, bf16 and the
  i8 KV pool; plus the fused XNOR linear against its unfused chain.  These
  are allclose pins: the online-softmax recurrence equals one-shot softmax
  exactly in real arithmetic but not bit-for-bit in floats.

* engine tokens — a paged engine decoding with ``REPRO_FUSED_DECODE=on``
  (the Pallas kernel on the decode path) produces the same tokens as with
  ``off`` (the unfused chain) across the paged arch families, float and
  packed residency, and the i8 KV cache.  With the env var unset the
  dispatch itself guarantees bitwise identity on CPU CI (``auto`` resolves
  to the unfused twin — ``test_fused_mode_resolution``), so the existing
  cross-layout pins (paged == dense, prefix on == off, migration identity)
  are untouched in both ``REPRO_KERNEL_IMPL`` modes.

Runs in whichever kernel mode CI selects.
"""

import zlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.core import bitpack, xnor_layers
from repro.kernels import ops, paged_attn, ref
from repro.models import lm
from repro.roofline import analysis
from repro.serve import ServeEngine, synthetic_trace

# paged attn families: dense GQA / local-window ring / enc-dec / vlm
# (xlstm is pure-recurrent — no paged pool, the kernel never engages — and
# rides along to pin that the dispatch is a no-op there)
SWEEP_ARCHS = ["qwen3-4b", "recurrentgemma-2b", "whisper-tiny",
               "llama-3.2-vision-11b", "xlstm-350m"]

RNG = np.random.default_rng(0)


def _case(*, b=3, kv=2, g=2, dh=16, bs=8, w=5, dtype=jnp.float32, i8=False,
          seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, kv, g, dh)), dtype)
    ck = rng.standard_normal((1 + b * w, kv, bs, dh))
    cv = rng.standard_normal((1 + b * w, kv, bs, dh))
    scale, out_scale = dh ** -0.5, 1.0
    if i8:
        ck = np.clip(np.round(ck * 32.0), -127, 127).astype(np.int8)
        cv = np.clip(np.round(cv * 32.0), -127, 127).astype(np.int8)
        scale, out_scale = scale / 32.0, 1.0 / 32.0
    else:
        ck = ck.astype(dtype)
        cv = cv.astype(dtype)
    table = jnp.asarray(rng.permutation(b * w).reshape(b, w) + 1, jnp.int32)
    return q, jnp.asarray(ck), jnp.asarray(cv), table, float(scale), \
        float(out_scale)


def _parity(q, ck, cv, table, pos, *, window, scale, out_scale, tol):
    got = paged_attn.paged_decode_attention(
        q, ck, cv, table, jnp.asarray(pos, jnp.int32), window=window,
        scale=scale, out_scale=out_scale, interpret=True)
    want = ref.paged_decode(q, ck, cv, table, jnp.asarray(pos, jnp.int32),
                            window=window, scale=scale, out_scale=out_scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_kernel_full_monotone(dtype, tol):
    q, ck, cv, table, scale, out_scale = _case(dtype=dtype)
    # ragged tails: positions mid-block and at block boundaries
    _parity(q, ck, cv, table, [0, 17, 39], window=0, scale=scale,
            out_scale=out_scale, tol=tol)
    _parity(q, ck, cv, table, [7, 8, 24], window=0, scale=scale,
            out_scale=out_scale, tol=tol)


@pytest.mark.parametrize("pos", [[3, 17, 39],     # before first wrap
                                 [40, 41, 57],    # at/just past capacity
                                 [45, 80, 113]])  # multiple wraps
def test_kernel_window_ring_recycling(pos):
    q, ck, cv, table, scale, out_scale = _case()
    _parity(q, ck, cv, table, pos, window=12, scale=scale,
            out_scale=out_scale, tol=2e-5)


def test_kernel_i8_kv():
    q, ck, cv, table, scale, out_scale = _case(i8=True)
    assert ck.dtype == jnp.int8
    _parity(q, ck, cv, table, [5, 19, 38], window=0, scale=scale,
            out_scale=out_scale, tol=2e-5)
    _parity(q, ck, cv, table, [45, 80, 113], window=12, scale=scale,
            out_scale=out_scale, tol=2e-5)


def test_kernel_gqa_groups():
    q, ck, cv, table, scale, out_scale = _case(kv=1, g=4)
    _parity(q, ck, cv, table, [2, 13, 31], window=0, scale=scale,
            out_scale=out_scale, tol=2e-5)


def test_kernel_is_one_dispatch():
    """The fused path traces to exactly one pallas_call; the unfused
    oracle chain is strictly more dispatches."""
    import functools
    q, ck, cv, table, scale, out_scale = _case()
    pos = jnp.asarray([3, 17, 39], jnp.int32)
    fused = functools.partial(paged_attn.paged_decode_attention, window=0,
                              scale=scale, interpret=True)
    unfused = functools.partial(ref.paged_decode, window=0, scale=scale)
    nf = analysis.dispatch_count(jax.make_jaxpr(fused)(q, ck, cv, table, pos))
    nu = analysis.dispatch_count(
        jax.make_jaxpr(unfused)(q, ck, cv, table, pos))
    assert nf == 1
    assert nu > nf


def test_fused_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_FUSED_DECODE", raising=False)
    # with no override, CPU backends keep the bit-exact unfused twin
    if jax.default_backend() != "tpu":
        assert ops.fused_mode("auto") == "ref"
    assert ops.fused_mode("off") == "ref"
    assert ops.fused_mode("unfused") == "ref"
    assert ops.fused_mode("on") == "kernel"
    assert ops.fused_mode("fused") == "kernel"
    with pytest.raises(ValueError):
        ops.fused_mode("bogus")
    # env var wins over the config value, and is read per call
    monkeypatch.setenv("REPRO_FUSED_DECODE", "on")
    assert ops.fused_mode("off") == "kernel"
    monkeypatch.setenv("REPRO_FUSED_DECODE", "off")
    assert ops.fused_mode("on") == "ref"


# ---------------------------------------------------------------------------
# fused XNOR linear (binarize + popcount GEMM + alpha/beta epilogue)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(5, 70, 9), (17, 200, 33), (128, 64, 128)])
def test_xnor_fused_matches_unfused_chain(m, k, n):
    """Fused kernel vs the three-dispatch chain, including ragged K (not a
    word multiple).  The ref impl of the fused op is bit-identical to the
    chain; the kernel is allclose (its alpha mean associates differently)
    with bit-identical integer dots by construction."""
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((n, k)), jnp.float32)
    pb, beta = xnor_layers.pack_weights(w, impl="ref")
    alpha = jnp.mean(jnp.abs(x), axis=-1)
    # integer dots are exact whichever impl REPRO_KERNEL_IMPL forces
    dots = ops.xnor_matmul(ops.binarize(x, impl="ref")[0], pb, k, impl="ref")
    chain = dots.astype(jnp.float32) * alpha[:, None] * beta[None, :]
    # the oracle directly — REPRO_KERNEL_IMPL=interpret overrides impl="ref"
    # at the ops layer, and the kernel's alpha is only allclose to the chain
    fused_ref = ref.xnor_linear_fused(x, pb, beta, k)
    assert np.array_equal(np.asarray(fused_ref), np.asarray(chain))
    fused_k = ops.xnor_linear_fused(x, pb, beta, k, impl="interpret")
    np.testing.assert_allclose(np.asarray(fused_k), np.asarray(chain),
                               rtol=2e-5, atol=2e-5)


def test_xnor_fused_exact_on_pm1():
    """±1 activations make alpha = 1 exactly — fused output must be the
    exact integer dot scaled by beta, bitwise across impls."""
    x = jnp.asarray(RNG.choice([-1.0, 1.0], (8, 96)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((6, 96)), jnp.float32)
    pb, beta = xnor_layers.pack_weights(w, impl="ref")
    a = ref.xnor_linear_fused(x, pb, beta, 96)
    b = ops.xnor_linear_fused(x, pb, beta, 96, impl="interpret")
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_prepacked_layer_fused_mode(monkeypatch):
    """xnor_linear_prepacked under REPRO_FUSED_DECODE=on routes through the
    fused kernel and stays allclose to the unfused default."""
    x = jnp.asarray(RNG.standard_normal((2, 7, 48)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((48, 10)), jnp.float32)
    pl = xnor_layers.pack_linear(w, impl="ref")
    monkeypatch.delenv("REPRO_FUSED_DECODE", raising=False)
    base = xnor_layers.xnor_linear_prepacked(x, pl.pb, pl.beta, pl.k)
    monkeypatch.setenv("REPRO_FUSED_DECODE", "on")
    fused = xnor_layers.xnor_linear_prepacked(x, pl.pb, pl.beta, pl.k)
    assert fused.shape == base.shape == (2, 7, 10)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(base),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# engine tokens: fused decode == unfused decode
# ---------------------------------------------------------------------------


def _engine_tokens(name, monkeypatch, fused, *, pack=False, **over):
    monkeypatch.setenv("REPRO_FUSED_DECODE", fused)
    cfg = configs.get(name).smoke(dtype=jnp.float32, **over)
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % 2**31)
    params = lm.init_params(cfg, key)
    eng = ServeEngine(cfg, params, slots=2, s_max=24, pack=pack, paged=True)
    for r in synthetic_trace(4, cfg.vocab, seed=3,
                             n_ctx_tokens=cfg.n_ctx_tokens,
                             d_model=cfg.d_model):
        eng.submit(r)
    rep = eng.run()
    return {rid: rep.tokens(rid).tolist() for rid in rep.sessions}


@pytest.mark.parametrize("name", SWEEP_ARCHS)
def test_fused_engine_tokens(name, monkeypatch):
    on = _engine_tokens(name, monkeypatch, "on")
    off = _engine_tokens(name, monkeypatch, "off")
    assert on == off, f"{name}: fused decode tokens diverge from unfused"


def test_fused_engine_tokens_packed(monkeypatch):
    on = _engine_tokens("qwen2-7b+xnor", monkeypatch, "on", pack=True)
    off = _engine_tokens("qwen2-7b+xnor", monkeypatch, "off", pack=True)
    assert on == off


def test_fused_engine_tokens_i8(monkeypatch):
    on = _engine_tokens("qwen3-4b", monkeypatch, "on",
                        kv_cache_dtype="i8")
    off = _engine_tokens("qwen3-4b", monkeypatch, "off",
                        kv_cache_dtype="i8")
    assert on == off


def test_auto_mode_is_bitwise_off_on_cpu(monkeypatch):
    """The production default: with no override and no TPU, ``auto`` decodes
    through the identical program as ``off`` — this is what keeps every
    pre-existing cross-layout token pin bitwise in both CI modes."""
    if jax.default_backend() == "tpu":
        pytest.skip("auto resolves to the kernel on TPU")
    auto = _engine_tokens("qwen3-4b", monkeypatch, "auto")
    off = _engine_tokens("qwen3-4b", monkeypatch, "off")
    assert auto == off


# ---------------------------------------------------------------------------
# property: random block-table layouts (hypothesis)
# ---------------------------------------------------------------------------

def _random_layout_case(b, w, bs, seed, ring):
    """Kernel == oracle for any permutation of pool blocks into tables,
    any per-slot position (including far past the ring capacity), any
    block geometry.  The table walk must be fully layout-agnostic."""
    rng = np.random.default_rng(seed)
    kv, g, dh = 1, 2, 8
    cap = w * bs
    q = jnp.asarray(rng.standard_normal((b, kv, g, dh)), jnp.float32)
    n_blocks = 1 + b * w
    ck = jnp.asarray(rng.standard_normal((n_blocks, kv, bs, dh)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((n_blocks, kv, bs, dh)), jnp.float32)
    table = jnp.asarray(rng.permutation(b * w).reshape(b, w) + 1, jnp.int32)
    pos = rng.integers(0, 3 * cap if ring else cap, size=(b,))
    window = int(rng.integers(1, cap + 1)) if ring else 0
    _parity(q, ck, cv, table, pos.tolist(), window=window,
            scale=dh ** -0.5, out_scale=1.0, tol=2e-5)


try:                                             # optional dep, like
    from hypothesis import given, settings, strategies as st  # noqa: E501
except ImportError:                              # test_kernels_properties.py
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_block_table_layouts():
        pass
else:
    @given(st.integers(1, 4),        # slots
           st.integers(1, 4),        # blocks per table
           st.integers(1, 16),       # block size
           st.integers(0, 1000),     # layout seed
           st.booleans())            # window ring?
    @settings(max_examples=25, deadline=None)
    def test_random_block_table_layouts(b, w, bs, seed, ring):
        _random_layout_case(b, w, bs, seed, ring)


def test_random_block_table_layouts_pinned():
    """A deterministic slice of the property sweep so the layout-agnostic
    claim is exercised even where hypothesis is unavailable."""
    for b, w, bs, seed, ring in [(1, 1, 1, 0, False), (3, 4, 8, 1, False),
                                 (4, 2, 16, 2, True), (2, 3, 5, 3, True)]:
        _random_layout_case(b, w, bs, seed, ring)
