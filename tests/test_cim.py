"""Circuit-level reproduction tests: truth tables (Fig. 4), current levels,
Monte-Carlo robustness (Fig. 5), array scalability, speedup model (Fig. 6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cim, logic, montecarlo, speedup

TT = {
    "xor":  {(0, 0): 0, (0, 1): 1, (1, 0): 1, (1, 1): 0},
    "xnor": {(0, 0): 1, (0, 1): 0, (1, 0): 0, (1, 1): 1},
    "and":  {(0, 0): 0, (0, 1): 0, (1, 0): 0, (1, 1): 1},
    "or":   {(0, 0): 0, (0, 1): 1, (1, 0): 1, (1, 1): 1},
    "nand": {(0, 0): 1, (0, 1): 1, (1, 0): 1, (1, 1): 0},
    "nor":  {(0, 0): 1, (0, 1): 0, (1, 0): 0, (1, 1): 0},
}


@pytest.mark.parametrize("op", sorted(TT))
def test_truth_tables(op):
    for a, b, out in logic.truth_table(logic.op_table()[op]):
        assert out == TT[op][(a, b)], (op, a, b)


def test_sl_current_levels_match_paper():
    """Fig. 4(d): I_00 ~ 0.1 nA, I_01 ~ 7.87 uA, I_11 ~ 15.7 uA on the 3x3."""
    st = cim.make_array(jnp.array([[1, 0, 1], [0, 0, 1], [1, 1, 0]]))
    i = np.asarray(cim.sl_currents(st, jnp.array([True, True, False])))
    assert abs(i[1] - 0.1e-9) < 1e-9          # '00' column
    np.testing.assert_allclose(i[0], 7.87e-6, rtol=0.02)   # '01'
    np.testing.assert_allclose(i[2], 15.7e-6, rtol=0.02)   # '11'


def test_array_compute_and_readback():
    bits = jnp.array([[1, 0, 1, 0], [0, 0, 1, 1], [1, 1, 0, 0]])
    st = cim.make_array(bits)
    want_xor = np.asarray(bits[0] ^ bits[1], bool)
    assert np.array_equal(np.asarray(cim.compute(st, 0, 1, "xor")), want_xor)
    assert np.array_equal(np.asarray(cim.compute(st, 0, 1, "xnor")), ~want_xor)
    for r in range(3):
        assert np.array_equal(np.asarray(cim.read(st, r)),
                              np.asarray(bits[r], bool))


def test_write_then_compute():
    st = cim.make_array(jnp.zeros((3, 4)))
    st = cim.write(st, 0, 1, 1)
    st = cim.write(st, 1, 2, 1)
    out = np.asarray(cim.compute(st, 0, 1, "xor"))
    assert np.array_equal(out, [False, True, True, False])


def test_banked_array_compute_and_read():
    """A (B, rows, cols) state computes every bank in one call (DESIGN.md
    §10); scalar row indices keep the classic per-array semantics."""
    rng = np.random.default_rng(3)
    bits = rng.integers(0, 2, (4, 3, 8))
    st = cim.make_array(jnp.asarray(bits))
    out = np.asarray(cim.compute(st, 0, 1, "xor"))
    assert np.array_equal(out, (bits[:, 0] ^ bits[:, 1]).astype(bool))
    assert np.array_equal(np.asarray(cim.read(st, 2)),
                          bits[:, 2].astype(bool))


def test_pair_vectorized_compute_single_array():
    """(P,) row indices compute P row-pairs of one array in one call."""
    rng = np.random.default_rng(4)
    bits = rng.integers(0, 2, (6, 10))
    st = cim.make_array(jnp.asarray(bits))
    ra, rb = jnp.array([0, 2, 4]), jnp.array([1, 3, 5])
    out = np.asarray(cim.compute(st, ra, rb, "xnor"))
    want = ~(bits[[0, 2, 4]] ^ bits[[1, 3, 5]]).astype(bool)
    assert np.array_equal(out, want)


def test_montecarlo_5000_points_no_errors():
    """Paper §V: levels stay separable under LRS/HRS (3sig=10%) + Vt (25 mV)."""
    res = montecarlo.run(jax.random.PRNGKey(0), samples=5000, rows=3)
    assert float(res.error_rate.max()) == 0.0
    means = np.asarray(res.i_sl.mean(0))
    assert means[0] < 1e-9 and 6e-6 < means[1] < 9e-6 and 1.4e-5 < means[2] < 1.7e-5
    # worst-case sense margins stay positive
    assert float(res.margins.min()) > 0


def test_max_rows_scales_with_on_off_ratio():
    """Fig. 5(b): larger HRS/LRS ratio -> more allowed rows; supports the
    paper's 512-row bank at nominal device values."""
    ratios = jnp.array([1e4, 1e5, 3e5])
    rows = np.asarray(montecarlo.max_rows_sweep(ratios))
    assert (np.diff(rows) < 0).all()          # vary LRS at fixed HRS
    assert float(montecarlo.max_rows()) >= 512


def test_speedup_formula():
    """Paper: N_O = 64 CPU baseline gives ~64x; speedup is monotone in N_O
    and saturates below the ideal limit."""
    s64 = float(speedup.xnornet_speedup(64))
    assert 60 < s64 < 64.1
    n_os = jnp.array([64, 256, 1024, 8192, speedup.tpu_n_o()])
    ss = np.asarray(speedup.xnornet_speedup(n_os))
    assert (np.diff(ss) > 0).all()
    assert ss[-1] < 256 * 14**2 * 9 / 9  # bounded by c*N_W


def test_table1_latency_ranking():
    """This work: single-cycle — beats every other CMOS-compatible design."""
    n = 10**6
    ours = speedup.design_cycles("this_work", n)
    for d in ["pinatubo", "xorim", "cmos_memristive", "felix"]:
        assert speedup.design_cycles(d, n) >= 2 * ours
    assert speedup.design_cycles("sixor", n) == ours  # memristor-only rival
