"""Hypothesis property tests on the bit-domain invariants.

Kept separate from tests/test_kernels.py so the deterministic kernel suite
still collects when hypothesis is not installed (requirements-dev.txt pins
it for CI; the importorskip guard keeps bare environments green)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import bitpack  # noqa: E402
from repro.kernels import ops  # noqa: E402

RNG = np.random.default_rng(0)


@given(st.integers(1, 8), st.integers(1, 130))
@settings(max_examples=30, deadline=None)
def test_pack_roundtrip_property(m, k):
    x = RNG.standard_normal((m, k)).astype(np.float32)
    xp = bitpack.pad_to_word(jnp.asarray(x))
    u = bitpack.unpack_bits(bitpack.pack_bits(xp), k)
    assert np.array_equal(np.asarray(u), np.where(x >= 0, 1.0, -1.0))


@given(st.integers(1, 20), st.integers(1, 20), st.integers(1, 80))
@settings(max_examples=20, deadline=None)
def test_xnor_gemm_bounds_property(m, n, k):
    """|dot| <= K and dot parity == K parity (±1 sums)."""
    a, b = RNG.standard_normal((m, k)), RNG.standard_normal((n, k))
    pa = bitpack.pack_bits(bitpack.pad_to_word(jnp.asarray(a, jnp.float32)))
    pb = bitpack.pack_bits(bitpack.pad_to_word(jnp.asarray(b, jnp.float32)))
    d = np.asarray(ops.xnor_matmul(pa, pb, k, impl="ref"))
    assert np.abs(d).max() <= k
    assert ((d - k) % 2 == 0).all()


@given(st.integers(0, 4999), st.integers(0, 31))
@settings(max_examples=25, deadline=None)
def test_digest_detects_any_single_bit_flip(pos, bit):
    buf = jnp.asarray(RNG.integers(0, 2**32, 5000, dtype=np.uint32))
    d0 = np.asarray(ops.digest(buf, impl="ref"))
    flipped = buf.at[pos].set(buf[pos] ^ np.uint32(1 << bit))
    d1 = np.asarray(ops.digest(flipped, impl="ref"))
    # XOR linearity: exactly one digest bit differs
    diff = d0 ^ d1
    assert sum(int(x).bit_count() for x in diff) == 1


@given(st.integers(1, 3000), st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_cipher_involution_property(n, ctr):
    buf = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    key = jnp.asarray(RNG.integers(0, 2**32, 2, dtype=np.uint32))
    enc = ops.stream_cipher(buf, key, counter=ctr, impl="ref")
    dec = ops.stream_cipher(enc, key, counter=ctr, impl="ref")
    assert np.array_equal(np.asarray(dec), np.asarray(buf))


@given(st.integers(2, 10), st.integers(1, 255), st.data())
@settings(max_examples=25, deadline=None)
def test_digest_cache_redigests_exactly_the_dirty_chunks(n_chunks, tail,
                                                         data):
    """DigestCache property (DESIGN.md §12): flipping bits in any subset of
    chunks re-dispatches exactly that many chunk digests, and the updated
    digest equals a fresh one-shot digest."""
    from repro.core.engine import CimEngine
    from repro.core.incremental import DigestCache
    chunk = 256
    n = (n_chunks - 1) * chunk + tail
    eng = CimEngine(impl="ref")
    cache = DigestCache(engine=eng, chunk_words=chunk)
    buf = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    cache.digests({"x": buf})

    dirty = data.draw(st.sets(st.integers(0, n_chunks - 1), max_size=n_chunks))
    new = buf
    for i in sorted(dirty):
        pos = data.draw(st.integers(i * chunk,
                                    min((i + 1) * chunk, n) - 1))
        new = new.at[pos].set(new[pos] ^ np.uint32(1))
    calls0 = eng.stats.by_op["digest"][2]
    got = cache.digests({"x": new})
    assert cache.last.dirty_chunks == len(dirty)
    assert eng.stats.by_op["digest"][2] - calls0 == len(dirty)
    assert np.array_equal(got["x"], np.asarray(ops.digest(new, impl="ref")))


@given(st.integers(1, 3000))
@settings(max_examples=20, deadline=None)
def test_bulk_op_involution_and_complement_property(n):
    """xor(xor(a,b),b) == a and xnor == ~xor, any buffer length."""
    a = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    x = ops.bulk_op(a, b, "xor", impl="ref")
    assert np.array_equal(np.asarray(ops.bulk_op(x, b, "xor", impl="ref")),
                          np.asarray(a))
    xn = ops.bulk_op(a, b, "xnor", impl="ref")
    assert np.array_equal(np.asarray(x ^ xn), np.full(n, 2**32 - 1, np.uint32))
