"""Integration: the train loop learns, resumes deterministically after a
simulated failure, and the 1-bit compression path is mathematically sane."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.data import synthetic
from repro.distributed import fault
from repro.models import lm
from repro.optim import adamw, compress, schedule
from repro.train import train_step as train_mod


def _run(cfg, steps, state=None, start=0, seed=0):
    pipe = synthetic.Pipeline(cfg, batch_size=8, seq_len=32, seed=seed)
    if state is None:
        state = train_mod.init_state(cfg, jax.random.PRNGKey(seed))

    @jax.jit
    def step_fn(state, batch, step):
        return train_mod.train_step(cfg, state, batch, step, peak_lr=3e-3,
                                    warmup=10, total=steps)

    losses = []
    for step in range(start, steps):
        batch = jax.tree.map(jnp.asarray, pipe.get(step))
        state, m = step_fn(state, batch, jnp.asarray(step, jnp.int32))
        losses.append(float(m["loss"]))
    return state, losses


def test_loss_decreases():
    cfg = configs.get("qwen2-7b").smoke()
    _, losses = _run(cfg, 30)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_restart_resumes_identically():
    """Crash-restart determinism: 20 straight steps == 10 steps + checkpoint
    + restore + 10 steps (data pipeline is step-addressed)."""
    cfg = configs.get("qwen2-7b").smoke()
    state_a, losses_a = _run(cfg, 20)

    state_b, _ = _run(cfg, 10)
    with tempfile.TemporaryDirectory() as d:
        from repro.checkpoint import ckpt
        ckpt.save(d, 10, state_b)
        like = train_mod.abstract_state(cfg)
        restored, step = ckpt.restore(d, None, like)
    assert step == 10
    state_c, losses_c = _run(cfg, 20, state=restored, start=10)
    for la, lc in zip(jax.tree.leaves(state_a.params),
                      jax.tree.leaves(state_c.params)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lc, np.float32),
                                   rtol=2e-2, atol=2e-3)
    assert abs(losses_a[-1] - losses_c[-1]) < 2e-2


def test_data_pipeline_deterministic_and_structured():
    b1 = synthetic.batch(0, 7, 4, 32, 1000)
    b2 = synthetic.batch(0, 7, 4, 32, 1000)
    b3 = synthetic.batch(0, 8, 4, 32, 1000)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 1000
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    assert (b1["labels"][:, -1] == -1).all()


def test_onebit_compression_error_feedback():
    """sign+EF: the residual makes the *cumulative* compressed sum track the
    cumulative true gradient (Karimireddy et al. 2019)."""
    rng = np.random.default_rng(0)
    g_seq = [jnp.asarray(rng.standard_normal((64,)), jnp.float32) * 0.1
             for _ in range(50)]
    e = jnp.zeros((64,))
    acc_true = jnp.zeros((64,))
    acc_comp = jnp.zeros((64,))
    for g in g_seq:
        planes, scale, e = compress.compress_leaf(g, e)
        approx = compress.decompress_leaf(planes, scale, (64,), jnp.float32)
        acc_true += g
        acc_comp += approx
    # residual bound: |sum(true) - sum(compressed)| == |final residual|
    np.testing.assert_allclose(np.asarray(acc_true - acc_comp),
                               np.asarray(e), rtol=1e-4, atol=1e-5)
    # and it is small relative to the accumulated signal
    assert float(jnp.linalg.norm(e)) < 0.5 * float(jnp.linalg.norm(acc_true))


def test_schedules():
    lr = schedule.warmup_cosine(jnp.arange(100), peak_lr=1.0, warmup=10,
                                total=100)
    assert float(lr[0]) == 0.0 and abs(float(lr[10]) - 1.0) < 1e-6
    assert float(lr[99]) < 0.2
    lr2 = schedule.wsd(jnp.arange(100), peak_lr=1.0, warmup=10, total=100)
    assert abs(float(lr2[50]) - 1.0) < 1e-6 and float(lr2[99]) < 0.2


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.array([5.0, -3.0])}
    st = adamw.init(params)
    for i in range(200):
        grads = {"w": 2 * params["w"]}       # d/dw w^2
        params, st, _ = adamw.update(params, grads, st, lr=0.05,
                                     weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.2
