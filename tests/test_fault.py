"""Unit tests for the fault-tolerance primitives (distributed/fault.py):
the straggler watermark policy and the restartable Runner loop — the
pieces the replicated serve router (DESIGN.md §17) reuses for replica
heartbeats and the migration checkpoint machinery sits beside."""

import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.distributed.fault import Runner, StragglerPolicy


# ---------------------------------------------------------------------------
# StragglerPolicy.observe
# ---------------------------------------------------------------------------


def test_policy_warmup_is_always_ok():
    """Fewer than 5 samples: no watermark yet, everything is "ok" — even a
    grossly slow step (no median to compare against)."""
    p = StragglerPolicy()
    assert [p.observe(s, dt) for s, dt in
            enumerate([0.01, 0.01, 5.0, 0.01])] == ["ok"] * 4
    assert p.events == [] and p.strikes == 0


def test_policy_flags_slow_step_against_trailing_median():
    p = StragglerPolicy(straggler_factor=2.0)
    for s in range(6):
        assert p.observe(s, 0.01) == "ok"
    # 0.05 > 2.0 * median(0.01) -> straggler, with the event recorded
    assert p.observe(6, 0.05) == "straggler"
    assert p.strikes == 1
    [(step, dt, med)] = p.events
    assert step == 6 and dt == 0.05 and med == pytest.approx(0.01)


def test_policy_fast_step_within_factor_is_ok():
    p = StragglerPolicy(straggler_factor=2.0)
    for s in range(6):
        p.observe(s, 0.01)
    # exactly at the threshold is NOT a straggler (strict >)
    assert p.observe(6, 0.02) == "ok"
    assert p.strikes == 0


def test_policy_reshard_after_max_strikes_then_resets():
    p = StragglerPolicy(straggler_factor=2.0, max_strikes=3, window=50)
    for s in range(20):
        p.observe(s, 0.01)
    verdicts = [p.observe(100 + i, 0.05) for i in range(3)]
    assert verdicts == ["straggler", "straggler", "reshard"]
    # the reshard consumed the strikes: the counter starts over
    assert p.strikes == 0
    assert p.observe(200, 0.05) == "straggler"
    assert len(p.events) == 4         # every strike logged, reshard included


def test_policy_window_bounds_the_memory():
    p = StragglerPolicy(window=5)
    for s in range(100):
        p.observe(s, 0.01)
    assert len(p._times) == 5


def test_policy_median_excludes_current_sample():
    """The watermark is the *trailing* median: a slow step must not dilute
    the median it is judged against (with itself included a single huge
    sample could mask itself at small windows)."""
    p = StragglerPolicy(straggler_factor=2.0, window=5)
    for s in range(5):
        p.observe(s, 0.01)
    p.observe(5, 10.0)
    assert p.events[-1][2] == pytest.approx(0.01)


# ---------------------------------------------------------------------------
# Runner: resume_or_init / maybe_save cadence / _gc retention
# ---------------------------------------------------------------------------


def _state(v: float):
    return {"w": np.full((4,), v, np.float32),
            "b": np.arange(3, dtype=np.int32)}


def _like():
    import jax

    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                        _state(0.0))


def test_runner_init_when_empty(tmp_path):
    r = Runner(str(tmp_path / "ckpt"))
    state, step = r.resume_or_init(_like(), lambda: _state(7.0))
    assert step == 0
    np.testing.assert_array_equal(state["w"], _state(7.0)["w"])


def test_runner_maybe_save_cadence(tmp_path):
    r = Runner(str(tmp_path), save_every=10)
    saved = [s for s in range(1, 35) if r.maybe_save(s, _state(float(s)))]
    assert saved == [10, 20, 30]
    assert r._steps() == [10, 20, 30]


def test_runner_resumes_latest(tmp_path):
    r = Runner(str(tmp_path), save_every=10)
    for s in (10, 20, 30):
        r.maybe_save(s, _state(float(s)))
    state, step = r.resume_or_init(_like(), lambda: _state(0.0))
    assert step == 30
    np.testing.assert_array_equal(state["w"], _state(30.0)["w"])


def test_runner_falls_back_past_corrupt_checkpoint(tmp_path):
    """A truncated latest npz reads as a failed node: resume falls back one
    checkpoint instead of wedging or replaying from scratch."""
    r = Runner(str(tmp_path), save_every=10)
    for s in (10, 20):
        r.maybe_save(s, _state(float(s)))
    (tmp_path / "ckpt_00000020.npz").write_bytes(b"garbage")
    state, step = r.resume_or_init(_like(), lambda: _state(0.0))
    assert step == 10
    np.testing.assert_array_equal(state["w"], _state(10.0)["w"])


def test_runner_falls_back_to_init_when_all_corrupt(tmp_path):
    r = Runner(str(tmp_path), save_every=10)
    r.maybe_save(10, _state(10.0))
    (tmp_path / "ckpt_00000010.npz").write_bytes(b"garbage")
    state, step = r.resume_or_init(_like(), lambda: _state(-1.0))
    assert step == 0
    np.testing.assert_array_equal(state["w"], _state(-1.0)["w"])


def test_runner_gc_keeps_last_k(tmp_path):
    r = Runner(str(tmp_path), save_every=1, keep_last=3)
    for s in range(1, 8):
        r.maybe_save(s, _state(float(s)))
    assert r._steps() == [5, 6, 7]
    # manifests garbage-collect together with their npz
    manifests = sorted(f.name for f in tmp_path.glob("manifest_*.msgpack"))
    assert manifests == [f"manifest_{s:08d}.msgpack" for s in (5, 6, 7)]
    # the survivors stay restorable
    state, step = ckpt.restore(str(tmp_path), None, _like())
    assert step == 7


def test_runner_encrypted_roundtrip(tmp_path):
    """root_key threads through save and resume (the serve router's
    migration checkpoints ride the same keyed path)."""
    r = Runner(str(tmp_path), save_every=1, root_key="runner-key")
    r.maybe_save(1, _state(3.0))
    state, step = r.resume_or_init(_like(), lambda: _state(0.0))
    assert step == 1
    np.testing.assert_array_equal(state["w"], _state(3.0)["w"])
    # wrong key: decrypt garbage fails parity -> falls back to init
    r2 = Runner(str(tmp_path), save_every=1, root_key="wrong-key")
    state, step = r2.resume_or_init(_like(), lambda: _state(-2.0))
    assert step == 0
    np.testing.assert_array_equal(state["w"], _state(-2.0)["w"])


def test_runner_observe_step_delegates_to_policy(tmp_path):
    r = Runner(str(tmp_path), policy=StragglerPolicy(straggler_factor=2.0))
    for s in range(6):
        assert r.observe_step(s, 0.01) == "ok"
    assert r.observe_step(6, 0.1) == "straggler"
