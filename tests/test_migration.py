"""Acceptance suite for live session migration (DESIGN.md §17).

The contract under test: a session exported mid-flight from one engine —
through an **encrypted checkpoint** on disk, restored on a different
engine against a spec that engine derives from nothing but the request —
finishes with tokens **bit-identical** to a run that never moved.  This
must hold mid-decode and mid-chunked-prefill, for float and packed
residency, across arch families with genuinely different paged state:
full-attention KV, sliding-window rings, recurrent carries, and enc-dec
cross-attention ctx-KV.  Sampling runs at temperature > 0 throughout, so
identity leans on the engine's (rid, token index) seed contract rather
than greedy argmax luck.
"""

import numpy as np
import pytest

import repro.configs as configs
from repro.checkpoint import ckpt
from repro.core.incremental import DigestCache
from repro.models import lm
from repro.serve import Request, ServeEngine, synthetic_trace

# one family each: dense GQA attention, recurrent hybrid (carries +
# window rings), enc-dec audio (cross-attn ctx-KV), xLSTM (pure
# recurrent matrix memory)
ARCHS = ["qwen3-4b", "recurrentgemma-2b", "whisper-tiny", "xlstm-350m"]


def _setup(arch: str):
    import jax

    cfg = configs.get(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk(cfg, params, **kw):
    base = dict(slots=2, s_max=48, seed=0, pack=False, paged=True,
                temperature=0.8)
    base.update(kw)
    return ServeEngine(cfg, params, **base)


def _trace(cfg, n, *, plens=(6, 11, 17), ntoks=(5, 9), seed=3):
    return synthetic_trace(n, cfg.vocab, seed=seed, prompt_lens=plens,
                           new_tokens=ntoks, n_ctx_tokens=cfg.n_ctx_tokens,
                           d_model=cfg.d_model)


def _baseline(cfg, params, trace, **kw):
    eng = _mk(cfg, params, **kw)
    for r in trace:
        eng.submit(r)
    rep = eng.run()
    return {r.rid: list(rep.tokens(r.rid)) for r in trace}


def _ship(src, dst, rid, req, d, *, step=1, cache=None, key="mig-test"):
    """export -> encrypted (delta) checkpoint -> restore-against-spec ->
    import -> release: the exact hop the router's migrate() performs."""
    wire = src.export_session(rid)
    if step == 1:
        ckpt.save(d, step, wire, root_key=key)
        if cache is not None:
            cache.digests(wire)
            cache.mark_saved()
    else:
        ckpt.save_delta(d, step, wire, root_key=key, cache=cache)
    like = dst.export_spec(req)
    restored, _ = ckpt.restore(d, step, like, root_key=key)
    dst.import_session(req, restored)
    src.release_migrated(rid)


def _mid_decode_session(eng, max_steps=12):
    """Step until some admitted session has emitted tokens but is neither
    finished nor still prefilling — the mid-decode capture point."""
    for _ in range(max_steps):
        eng.step()
        for slot, sess in eng.pool.active.items():
            if sess.tokens and not sess.done and slot not in eng._prefilling:
                return sess
    raise AssertionError("trace never produced a mid-decode session")


def _finish_and_collect(trace, engines, where):
    reps = {k: e.run() for k, e in engines.items()}
    return {r.rid: list(reps[where(r.rid)].tokens(r.rid)) for r in trace}


@pytest.mark.parametrize("arch", ARCHS)
def test_migration_identity_mid_decode(arch, tmp_path):
    cfg, params = _setup(arch)
    trace = _trace(cfg, 4)
    want = _baseline(cfg, params, trace)

    a = _mk(cfg, params)
    for r in trace:
        a.submit(r)
    sess = _mid_decode_session(a)
    rid = sess.request.rid
    b = _mk(cfg, params)
    _ship(a, b, rid, sess.request, str(tmp_path / "wire"))

    assert rid not in a.sessions          # source forgot the session...
    assert b.sessions[rid].tokens == sess.tokens   # ...dst resumed it
    got = _finish_and_collect(trace, {"a": a, "b": b},
                              lambda r: "b" if r == rid else "a")
    assert got == want, f"{arch}: migration mid-decode changed tokens"


@pytest.mark.parametrize("arch", ARCHS)
def test_migration_identity_mid_chunked_prefill(arch, tmp_path):
    cfg, params = _setup(arch)
    # prompts span 3 chunks (prefill_chunk is 8 in smoke configs): after
    # one engine step the head session is mid-prefill, chunk cursor > 0
    trace = _trace(cfg, 3, plens=(20, 23), ntoks=(6, 8))
    want = _baseline(cfg, params, trace)

    a = _mk(cfg, params)
    for r in trace:
        a.submit(r)
    a.step()
    assert a._prefilling, "prompt did not span multiple prefill chunks"
    slot = next(iter(a._prefilling))
    sess = a.pool.active[slot]
    rid = sess.request.rid
    b = _mk(cfg, params)
    _ship(a, b, rid, sess.request, str(tmp_path / "wire"))

    # the destination picks the prefill up at the exact chunk boundary
    assert b._prefilling, "import dropped the chunked-prefill progress"
    got = _finish_and_collect(trace, {"a": a, "b": b},
                              lambda r: "b" if r == rid else "a")
    assert got == want, f"{arch}: migration mid-prefill changed tokens"


def test_migration_identity_packed_residency(tmp_path):
    """On a +xnor arch with pack=True the resident weights are uint32
    sign-planes and the migrated KV was written by the popcount GEMM —
    identity must survive packed residency too."""
    cfg, params = _setup("qwen2-7b+xnor")
    assert cfg.quant == "xnor"
    trace = _trace(cfg, 4)
    want = _baseline(cfg, params, trace, pack=True)

    a = _mk(cfg, params, pack=True)
    for r in trace:
        a.submit(r)
    sess = _mid_decode_session(a)
    rid = sess.request.rid
    b = _mk(cfg, params, pack=True)
    _ship(a, b, rid, sess.request, str(tmp_path / "wire"))
    got = _finish_and_collect(trace, {"a": a, "b": b},
                              lambda r: "b" if r == rid else "a")
    assert got == want, "packed-residency migration changed tokens"


def test_double_migration_delta_chain(tmp_path):
    """A -> B -> A: hop 2 rides ckpt.save_delta against the per-rid
    DigestCache the first hop primed, so unchanged leaves (prompt, any
    still-identical KV) resolve through the chain instead of being
    re-stored — and the bounced session still finishes bit-identical."""
    cfg, params = _setup("qwen3-4b")
    trace = _trace(cfg, 3, plens=(6, 10), ntoks=(14, 18))
    want = _baseline(cfg, params, trace)

    a = _mk(cfg, params)
    for r in trace:
        a.submit(r)
    sess = _mid_decode_session(a)
    rid = sess.request.rid
    b = _mk(cfg, params)
    d = str(tmp_path / "wire")
    cache = DigestCache()
    _ship(a, b, rid, sess.request, d, step=1, cache=cache)
    for _ in range(2):                    # B decodes a couple of tokens
        b.step()
    assert not b.sessions[rid].done, "budget too small to bounce back"
    _ship(b, a, rid, sess.request, d, step=2, cache=cache)

    # the delta hop stored strictly less than the full first hop
    npz = {p.name: p.stat().st_size for p in (tmp_path / "wire").iterdir()
           if p.suffix == ".npz"}
    assert npz["ckpt_00000002.npz"] < npz["ckpt_00000001.npz"], npz

    got = _finish_and_collect(trace, {"a": a, "b": b}, lambda r: "a")
    assert got == want, "A->B->A double migration changed tokens"


def test_migration_wire_is_encrypted(tmp_path):
    """The wire is unreadable without the root key: restoring with a
    wrong key must fail, not silently produce a corrupt session."""
    cfg, params = _setup("qwen3-4b")
    trace = _trace(cfg, 2)
    a = _mk(cfg, params)
    for r in trace:
        a.submit(r)
    sess = _mid_decode_session(a)
    rid = sess.request.rid
    wire = a.export_session(rid)
    d = str(tmp_path / "wire")
    ckpt.save(d, 1, wire, root_key="right-key")

    b = _mk(cfg, params)
    like = b.export_spec(sess.request)
    with pytest.raises(Exception):
        ckpt.restore(d, 1, like, root_key="wrong-key")
    # prompt tokens must not appear in the clear anywhere on disk
    blob = b"".join(p.read_bytes() for p in (tmp_path / "wire").iterdir())
    assert sess.request.prompt.astype(np.int32).tobytes() not in blob


def test_release_migrated_returns_capacity(tmp_path):
    """After the hop the source engine's slot and blocks are genuinely
    free again: a new request admits into the vacated capacity."""
    cfg, params = _setup("qwen3-4b")
    trace = _trace(cfg, 2)
    a = _mk(cfg, params, slots=2)
    for r in trace:
        a.submit(r)
    sess = _mid_decode_session(a)
    rid = sess.request.rid
    in_use_before = a.blocks.in_use

    b = _mk(cfg, params)
    _ship(a, b, rid, sess.request, str(tmp_path / "wire"))
    assert a.blocks.in_use < in_use_before
    assert a.pool.free_slots, "migration did not free the source slot"

    late = Request(rid=99, prompt=np.arange(5) % cfg.vocab,
                   max_new_tokens=4)
    a.submit(late)
    rep_a, rep_b = a.run(), b.run()
    assert rep_a.sessions[99].done and rep_b.sessions[rid].done
