"""Block-paged KV cache + chunked prefill (DESIGN.md §14).

The contract under test: the paged serve path — shared block pool,
per-slot block tables, fixed-size chunked prefill — is *token-identical*
to the slot-dense path for every arch family that caches attention state
(dense / local-window / enc-dec / vlm) and for the pure-recurrent archs
(whose per-slot state stays dense by design); MoE archs are exempt from
cross-layout identity (expert capacity is a function of the dispatch
group length, so C-sized chunks legitimately drop differently than a
P-length exact prefill) and are pinned for schedule-independence instead.
Runs in whichever REPRO_KERNEL_IMPL mode CI selects, so both kernel modes
cover the sweep.  BlockPool is pure host logic, unit-tested without a
model.
"""

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm
from repro.serve import BlockPool, ServeEngine, synthetic_trace

# dense / local+recurrent / enc-dec / vlm / pure-recurrent — the identity
# sweep the acceptance criteria pin (MoE is exercised separately)
SWEEP_ARCHS = ["qwen3-4b", "recurrentgemma-2b", "whisper-tiny",
               "llama-3.2-vision-11b", "xlstm-350m"]


def _setup(name, **over):
    cfg = configs.get(name).smoke(dtype=jnp.float32, **over)
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % 2**31)
    return cfg, lm.init_params(cfg, key)


def _run(cfg, params, trace, *, paged, slots=2, s_max=24, pack=True,
         n_blocks=0, seed=0, temperature=0.0):
    eng = ServeEngine(cfg, params, slots=slots, s_max=s_max, pack=pack,
                      paged=paged, n_blocks=n_blocks, seed=seed,
                      temperature=temperature)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    toks = {rid: report.tokens(rid).tolist() for rid in report.sessions}
    return toks, eng


# ---------------------------------------------------------------------------
# BlockPool: pure allocation bookkeeping
# ---------------------------------------------------------------------------


def test_block_pool_alloc_lowest_first_deterministic():
    pool = BlockPool(8)
    assert pool.capacity == 7 and pool.available == 7 and pool.in_use == 0
    a = pool.alloc(0, 3)
    assert a == [1, 2, 3]            # block 0 reserved (trash), lowest first
    b = pool.alloc(1, 2)
    assert b == [4, 5]
    pool.free(0)
    assert pool.available == 5
    # freed ids return sorted: the next alloc reuses the lowest again
    assert pool.alloc(2, 3) == [1, 2, 3]
    assert pool.in_use == 5


def test_block_pool_oom_and_free_reclaims_all():
    pool = BlockPool(5)
    pool.alloc(7, 2)
    pool.alloc(7, 1)                 # same request grows its hold
    assert pool.held(7) == [1, 2, 3]
    with pytest.raises(RuntimeError):
        pool.alloc(8, 2)             # only 1 free
    assert pool.free(7) == 3         # eviction reclaims every held block
    assert pool.available == pool.capacity
    assert pool.free(7) == 0         # idempotent
    with pytest.raises(ValueError):
        BlockPool(1)                 # trash block alone is not a pool
    with pytest.raises(ValueError):
        pool.alloc(9, -1)


# ---------------------------------------------------------------------------
# paged == dense token identity (the tentpole contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", SWEEP_ARCHS)
def test_paged_matches_dense_tokens(name):
    """Property-style sweep: same seeded mixed-length trace through the
    slot-dense and block-paged engines -> identical tokens per request
    (chunked prefill + table gather/scatter vs exact-length prefill +
    contiguous cache)."""
    cfg, params = _setup(name)
    trace = synthetic_trace(5, cfg.vocab, seed=2, prompt_lens=(4, 6, 9),
                            new_tokens=(3, 6), n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model)
    dense, _ = _run(cfg, params, trace, paged=False)
    paged, eng = _run(cfg, params, trace, paged=True)
    assert dense == paged
    assert eng.stats.prefills == len(trace)
    if eng.blocks is not None:
        assert eng.blocks.in_use == 0        # every eviction returned blocks
        assert eng.stats.blocks_peak > 0


def test_paged_packed_residency_matches_dense_and_float():
    """Both resident modes run on the paged layout: packed-paged equals
    float-paged equals packed-dense token-for-token."""
    cfg, params = _setup("qwen2-7b+xnor")
    trace = synthetic_trace(4, cfg.vocab, seed=6, prompt_lens=(4, 7),
                            new_tokens=(3, 5))
    dense_packed, _ = _run(cfg, params, trace, paged=False, pack=True)
    paged_packed, _ = _run(cfg, params, trace, paged=True, pack=True)
    paged_float, _ = _run(cfg, params, trace, paged=True, pack=False)
    assert dense_packed == paged_packed == paged_float


@pytest.mark.parametrize("name", ["qwen3-4b", "whisper-tiny"])
def test_paged_matches_dense_i8_cache(name):
    """The fixed-point i8 cache runs on both layouts and stays identical —
    including enc-dec, whose dense resident self-cache must be allocated
    i8 for _kv_from_seq's scaled words to be decoded with the correction."""
    cfg, params = _setup(name)
    cfg = dataclasses.replace(cfg, kv_cache_dtype="i8")
    trace = synthetic_trace(4, cfg.vocab, seed=8, prompt_lens=(4, 7),
                            new_tokens=(3, 5), n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model)
    dense, deng = _run(cfg, params, trace, paged=False)
    paged, peng = _run(cfg, params, trace, paged=True)
    assert dense == paged
    for eng in (deng, peng):
        kv = jax.tree.leaves(eng._state.seg_states)[0]
        assert kv.dtype == jnp.int8


def test_paged_moe_deterministic_across_slot_counts():
    """MoE is exempt from cross-layout identity (capacity is group-length
    dependent), but the paged path must still be schedule-independent:
    identical tokens whatever the slot count, greedy and sampled."""
    cfg, params = _setup("llama4-scout-17b-a16e")
    trace_args = dict(seed=3, prompt_lens=(4, 6, 9), new_tokens=(3, 5))

    def run(slots, temperature):
        trace = synthetic_trace(5, cfg.vocab, **trace_args)
        toks, _ = _run(cfg, params, trace, paged=True, slots=slots,
                       temperature=temperature, seed=11)
        return toks

    assert run(1, 0.0) == run(2, 0.0) == run(4, 0.0)
    assert run(1, 0.7) == run(3, 0.7)


def test_paged_local_window_ring_recycles_blocks():
    """A prompt much longer than the window: the ring holds only
    ceil((window + C - 1) / bs) blocks however long the prompt — blocks
    that fall out of the window are recycled, never accumulated — and the
    tokens still match the dense rolling-buffer path."""
    cfg, params = _setup("recurrentgemma-2b", local_window=8)
    widths = lm.paged_table_widths(cfg, 32, cfg.block_size,
                                   cfg.prefill_chunk)
    assert set(widths) == {"win"}            # no full-attention layers
    assert widths["win"] == 2                # (8 + 8 - 1) tokens over 8-blocks
    rng = np.random.default_rng(0)
    from repro.serve import Request
    trace = [Request(rid=0, prompt=rng.integers(0, cfg.vocab, 20),
                     max_new_tokens=5),
             Request(rid=1, prompt=rng.integers(0, cfg.vocab, 23),
                     max_new_tokens=4)]
    dense, _ = _run(cfg, params, trace, paged=False, s_max=32)
    paged, eng = _run(cfg, params, trace, paged=True, s_max=32)
    assert dense == paged
    # 2 slots x 2-block ring is the whole worst case, prompt length be damned
    assert eng.stats.blocks_peak <= 2 * widths["win"]


# ---------------------------------------------------------------------------
# chunked prefill: one program for any prompt-length mix
# ---------------------------------------------------------------------------


def test_chunked_prefill_traces_one_program():
    """A mixed-length trace compiles exactly one prefill program and one
    decode program under the paged engine; the dense engine traces prefill
    once per distinct prompt length."""
    cfg, params = _setup("qwen3-4b")
    trace = synthetic_trace(6, cfg.vocab, seed=4, prompt_lens=(3, 5, 9, 11),
                            new_tokens=(2, 4))
    lens = {r.prompt.shape[0] for r in trace}
    assert len(lens) >= 3                    # the mix is genuinely mixed
    _, eng = _run(cfg, params, trace, paged=True)
    assert eng.stats.prefill_traces == 1
    assert eng.stats.decode_traces == 1
    assert eng.stats.prefill_chunks >= eng.stats.prefills
    _, dense_eng = _run(cfg, params, trace, paged=False)
    assert dense_eng.stats.prefill_traces == len(lens)


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt admitted beside a decoding request is consumed one
    chunk per engine step — the decode batch advances between chunks
    instead of stalling head-of-line — and tokens still match the dense
    engine (the mid-prefill slot rides the decode batch inertly: recurrent
    state frozen, KV writes trash-routed)."""
    from repro.serve import Request

    cfg, params = _setup("recurrentgemma-2b")       # recurrent + local attn
    rng = np.random.default_rng(5)
    c = cfg.prefill_chunk
    long_p, short_p = 5 * c, 3
    trace = [Request(rid=0, prompt=rng.integers(0, cfg.vocab, short_p),
                     max_new_tokens=8),
             Request(rid=1, prompt=rng.integers(0, cfg.vocab, long_p),
                     max_new_tokens=3)]
    s_max = long_p + 8
    dense, _ = _run(cfg, params, trace, paged=False, s_max=s_max)

    eng = ServeEngine(cfg, params, slots=2, s_max=s_max, paged=True)
    for r in trace:
        eng.submit(r)
    eng.step()
    # step 1: both admitted; each advanced exactly ONE chunk; the short
    # prompt (1 chunk) finished prefill and decoded, the long one did not
    assert eng.stats.prefill_chunks == 2
    assert eng.stats.decode_steps == 1
    assert len(eng.sessions[0].tokens) == 2          # prefill tok + 1 decode
    assert len(eng.sessions[1].tokens) == 0          # still prefilling
    for _ in range(3):
        eng.step()
    # the short request decoded every step while the long prefill ran
    assert eng.stats.prefill_chunks == 5
    assert len(eng.sessions[0].tokens) == 5
    while eng.step():
        pass
    paged = {rid: eng.sessions[rid].tokens for rid in eng.sessions}
    assert paged == dense


def test_paged_oom_backpressure_serializes_and_completes():
    """A pool sized for one request at a time: admissions serialize behind
    block availability (FIFO head waits, nobody starves), every request
    completes, and the tokens are unchanged."""
    cfg, params = _setup("qwen3-4b")
    trace = synthetic_trace(4, cfg.vocab, seed=7, prompt_lens=(4, 6),
                            new_tokens=(4, 6))
    need = max(-(-(r.prompt.shape[0] + r.max_new_tokens - 1)
                 // cfg.block_size) for r in trace)
    free_run, _ = _run(cfg, params, trace, paged=True, slots=2)
    tight, eng = _run(cfg, params, trace, paged=True, slots=2,
                      n_blocks=need + 1)
    assert tight == free_run
    assert all(s.done for s in eng.sessions.values())
    assert eng.stats.blocks_peak <= need
    assert eng.blocks.in_use == 0
    # queue-wait is visible: later requests waited for blocks/slots
    waits = [s.queue_wait for s in eng.sessions.values()]
    assert all(w == w for w in waits)        # no NaN: everyone was admitted
    assert max(waits) > min(waits)


def test_paged_submit_rejects_impossible_request():
    cfg, params = _setup("qwen3-4b")
    from repro.serve import Request
    eng = ServeEngine(cfg, params, slots=1, s_max=64, paged=True,
                      n_blocks=3)
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(Request(rid=0, prompt=np.arange(30), max_new_tokens=20))


# ---------------------------------------------------------------------------
# paged layout plumbing
# ---------------------------------------------------------------------------


def test_paged_decode_state_spec_shapes():
    cfg = configs.get("qwen3-4b").smoke()
    st = lm.paged_decode_state_spec(cfg, 3, 24, n_blocks=10, block_size=8,
                                    abstract=True)
    assert st.pos.shape == (3,) and st.pos.dtype == jnp.int32
    assert st.ctx is None
    pool = st.seg_states[0].k                # stacked per layer
    n_layers = cfg.segments()[0][1]
    assert pool.shape == (n_layers, 10, cfg.n_kv_heads, 8, cfg.d_head)


def test_paged_table_widths():
    cfg = configs.get("qwen3-4b").smoke()            # attn only
    assert lm.paged_table_widths(cfg, 48, 8, 8) == {"full": 6}
    cfg = configs.get("recurrentgemma-2b").smoke()   # local only (window 32)
    assert lm.paged_table_widths(cfg, 256, 8, 8) == {"win": 5}  # 39 tokens
    cfg = configs.get("xlstm-350m").smoke()          # no KV cache at all
    assert lm.paged_table_widths(cfg, 48, 8, 8) == {}


def test_engine_stats_block_occupancy_quantities():
    from repro.serve import EngineStats

    st = EngineStats(blocks_total=10)
    for u in (2, 6, 4):
        st.observe_blocks(u)
    assert st.blocks_peak == 6
    assert st.blocks_in_use == 4
    assert st.blocks_mean == pytest.approx(4.0)
    assert st.block_utilization == pytest.approx(0.4)
    assert EngineStats().block_utilization == 0.0


def test_report_ttft_and_queue_wait_quantiles():
    cfg, params = _setup("qwen3-4b")
    trace = synthetic_trace(3, cfg.vocab, seed=1, prompt_lens=(4, 6),
                            new_tokens=(3,))
    eng = ServeEngine(cfg, params, slots=1, s_max=16, paged=True)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    ttft = report.ttft_quantiles((0.5, 0.95))
    qw = report.queue_wait_quantiles((0.5, 0.95))
    lat = report.latency_quantiles((0.5, 0.95))
    assert 0.0 <= qw[0.5] <= ttft[0.5] <= lat[0.5]
    assert ttft[0.95] <= lat[0.95]
    for s in report.sessions.values():       # queue_wait <= ttft per session
        assert s.queue_wait <= s.ttft
