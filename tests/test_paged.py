"""Block-paged KV cache + chunked prefill + prefix caching (DESIGN.md
§14–§15).

The contract under test: the paged serve path — shared block pool,
per-slot block tables, fixed-size chunked prefill — is *token-identical*
to the slot-dense path for every arch family that caches attention state
(dense / local-window / enc-dec / vlm) and for the pure-recurrent archs
(whose per-slot state stays dense by design); MoE archs are exempt from
cross-layout identity (expert capacity is a function of the dispatch
group length, so C-sized chunks legitimately drop differently than a
P-length exact prefill) and are pinned for schedule-independence instead.
§15 extends the contract: serving a shared-prefix trace with the prefix
cache on is token-identical to serving it with the cache off, across the
same arch sweep, float/packed residency and the i8 KV cache, with every
divergence point (block boundary, mid-block, full-prompt hit, mid-prefill
donor) costing exactly one copy-on-write copy.  Runs in whichever
REPRO_KERNEL_IMPL mode CI selects, so both kernel modes cover the sweep.
BlockPool and PrefixIndex are pure host logic, unit-tested without a
model (random-interleaving properties live in test_serve_properties.py).
"""

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm
from repro.serve import (BlockPool, PrefixIndex, Request, ServeEngine,
                         synthetic_trace)

# dense / local+recurrent / enc-dec / vlm / pure-recurrent — the identity
# sweep the acceptance criteria pin (MoE is exercised separately)
SWEEP_ARCHS = ["qwen3-4b", "recurrentgemma-2b", "whisper-tiny",
               "llama-3.2-vision-11b", "xlstm-350m"]


def _setup(name, **over):
    cfg = configs.get(name).smoke(dtype=jnp.float32, **over)
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % 2**31)
    return cfg, lm.init_params(cfg, key)


def _run(cfg, params, trace, *, paged, slots=2, s_max=24, pack=True,
         n_blocks=0, seed=0, temperature=0.0, prefix_cache=True):
    eng = ServeEngine(cfg, params, slots=slots, s_max=s_max, pack=pack,
                      paged=paged, n_blocks=n_blocks, seed=seed,
                      temperature=temperature, prefix_cache=prefix_cache)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    toks = {rid: report.tokens(rid).tolist() for rid in report.sessions}
    return toks, eng


# ---------------------------------------------------------------------------
# BlockPool: pure allocation bookkeeping
# ---------------------------------------------------------------------------


def test_block_pool_alloc_lowest_first_deterministic():
    pool = BlockPool(8)
    assert pool.capacity == 7 and pool.available == 7 and pool.in_use == 0
    a = pool.alloc(0, 3)
    assert a == [1, 2, 3]            # block 0 reserved (trash), lowest first
    b = pool.alloc(1, 2)
    assert b == [4, 5]
    pool.free(0)
    assert pool.available == 5
    # freed ids return sorted: the next alloc reuses the lowest again
    assert pool.alloc(2, 3) == [1, 2, 3]
    assert pool.in_use == 5


def test_block_pool_oom_and_free_reclaims_all():
    pool = BlockPool(5)
    pool.alloc(7, 2)
    pool.alloc(7, 1)                 # same request grows its hold
    assert pool.held(7) == [1, 2, 3]
    with pytest.raises(RuntimeError):
        pool.alloc(8, 2)             # only 1 free
    assert pool.free(7) == 3         # eviction reclaims every held block
    assert pool.available == pool.capacity
    assert pool.free(7) == 0         # idempotent
    with pytest.raises(ValueError):
        BlockPool(1)                 # trash block alone is not a pool
    with pytest.raises(ValueError):
        pool.alloc(9, -1)


# ---------------------------------------------------------------------------
# paged == dense token identity (the tentpole contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", SWEEP_ARCHS)
def test_paged_matches_dense_tokens(name):
    """Property-style sweep: same seeded mixed-length trace through the
    slot-dense and block-paged engines -> identical tokens per request
    (chunked prefill + table gather/scatter vs exact-length prefill +
    contiguous cache)."""
    cfg, params = _setup(name)
    trace = synthetic_trace(5, cfg.vocab, seed=2, prompt_lens=(4, 6, 9),
                            new_tokens=(3, 6), n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model)
    dense, _ = _run(cfg, params, trace, paged=False)
    paged, eng = _run(cfg, params, trace, paged=True)
    assert dense == paged
    assert eng.stats.prefills == len(trace)
    if eng.blocks is not None:
        assert eng.blocks.in_use == 0        # every eviction returned blocks
        assert eng.stats.blocks_peak > 0


def test_paged_packed_residency_matches_dense_and_float():
    """Both resident modes run on the paged layout: packed-paged equals
    float-paged equals packed-dense token-for-token."""
    cfg, params = _setup("qwen2-7b+xnor")
    trace = synthetic_trace(4, cfg.vocab, seed=6, prompt_lens=(4, 7),
                            new_tokens=(3, 5))
    dense_packed, _ = _run(cfg, params, trace, paged=False, pack=True)
    paged_packed, _ = _run(cfg, params, trace, paged=True, pack=True)
    paged_float, _ = _run(cfg, params, trace, paged=True, pack=False)
    assert dense_packed == paged_packed == paged_float


@pytest.mark.parametrize("name", ["qwen3-4b", "whisper-tiny"])
def test_paged_matches_dense_i8_cache(name):
    """The fixed-point i8 cache runs on both layouts and stays identical —
    including enc-dec, whose dense resident self-cache must be allocated
    i8 for _kv_from_seq's scaled words to be decoded with the correction."""
    cfg, params = _setup(name)
    cfg = dataclasses.replace(cfg, kv_cache_dtype="i8")
    trace = synthetic_trace(4, cfg.vocab, seed=8, prompt_lens=(4, 7),
                            new_tokens=(3, 5), n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model)
    dense, deng = _run(cfg, params, trace, paged=False)
    paged, peng = _run(cfg, params, trace, paged=True)
    assert dense == paged
    for eng in (deng, peng):
        kv = jax.tree.leaves(eng._state.seg_states)[0]
        assert kv.dtype == jnp.int8


def test_paged_moe_deterministic_across_slot_counts():
    """MoE is exempt from cross-layout identity (capacity is group-length
    dependent), but the paged path must still be schedule-independent:
    identical tokens whatever the slot count, greedy and sampled."""
    cfg, params = _setup("llama4-scout-17b-a16e")
    trace_args = dict(seed=3, prompt_lens=(4, 6, 9), new_tokens=(3, 5))

    def run(slots, temperature):
        trace = synthetic_trace(5, cfg.vocab, **trace_args)
        toks, _ = _run(cfg, params, trace, paged=True, slots=slots,
                       temperature=temperature, seed=11)
        return toks

    assert run(1, 0.0) == run(2, 0.0) == run(4, 0.0)
    assert run(1, 0.7) == run(3, 0.7)


def test_paged_local_window_ring_recycles_blocks():
    """A prompt much longer than the window: the ring holds only
    ceil((window + C - 1) / bs) blocks however long the prompt — blocks
    that fall out of the window are recycled, never accumulated — and the
    tokens still match the dense rolling-buffer path."""
    cfg, params = _setup("recurrentgemma-2b", local_window=8)
    widths = lm.paged_table_widths(cfg, 32, cfg.block_size,
                                   cfg.prefill_chunk)
    assert set(widths) == {"win"}            # no full-attention layers
    assert widths["win"] == 2                # (8 + 8 - 1) tokens over 8-blocks
    rng = np.random.default_rng(0)
    from repro.serve import Request
    trace = [Request(rid=0, prompt=rng.integers(0, cfg.vocab, 20),
                     max_new_tokens=5),
             Request(rid=1, prompt=rng.integers(0, cfg.vocab, 23),
                     max_new_tokens=4)]
    dense, _ = _run(cfg, params, trace, paged=False, s_max=32)
    paged, eng = _run(cfg, params, trace, paged=True, s_max=32)
    assert dense == paged
    # 2 slots x 2-block ring is the whole worst case, prompt length be damned
    assert eng.stats.blocks_peak <= 2 * widths["win"]


# ---------------------------------------------------------------------------
# chunked prefill: one program for any prompt-length mix
# ---------------------------------------------------------------------------


def test_chunked_prefill_traces_one_program():
    """A mixed-length trace compiles exactly one prefill program and one
    decode program under the paged engine; the dense engine traces prefill
    once per distinct prompt length."""
    cfg, params = _setup("qwen3-4b")
    trace = synthetic_trace(6, cfg.vocab, seed=4, prompt_lens=(3, 5, 9, 11),
                            new_tokens=(2, 4))
    lens = {r.prompt.shape[0] for r in trace}
    assert len(lens) >= 3                    # the mix is genuinely mixed
    _, eng = _run(cfg, params, trace, paged=True)
    assert eng.stats.prefill_traces == 1
    assert eng.stats.decode_traces == 1
    assert eng.stats.prefill_chunks >= eng.stats.prefills
    _, dense_eng = _run(cfg, params, trace, paged=False)
    assert dense_eng.stats.prefill_traces == len(lens)


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt admitted beside a decoding request is consumed one
    chunk per engine step — the decode batch advances between chunks
    instead of stalling head-of-line — and tokens still match the dense
    engine (the mid-prefill slot rides the decode batch inertly: recurrent
    state frozen, KV writes trash-routed)."""
    from repro.serve import Request

    cfg, params = _setup("recurrentgemma-2b")       # recurrent + local attn
    rng = np.random.default_rng(5)
    c = cfg.prefill_chunk
    long_p, short_p = 5 * c, 3
    trace = [Request(rid=0, prompt=rng.integers(0, cfg.vocab, short_p),
                     max_new_tokens=8),
             Request(rid=1, prompt=rng.integers(0, cfg.vocab, long_p),
                     max_new_tokens=3)]
    s_max = long_p + 8
    dense, _ = _run(cfg, params, trace, paged=False, s_max=s_max)

    eng = ServeEngine(cfg, params, slots=2, s_max=s_max, paged=True)
    for r in trace:
        eng.submit(r)
    eng.step()
    # step 1: both admitted; each advanced exactly ONE chunk; the short
    # prompt (1 chunk) finished prefill and decoded, the long one did not
    assert eng.stats.prefill_chunks == 2
    assert eng.stats.decode_steps == 1
    assert len(eng.sessions[0].tokens) == 2          # prefill tok + 1 decode
    assert len(eng.sessions[1].tokens) == 0          # still prefilling
    for _ in range(3):
        eng.step()
    # the short request decoded every step while the long prefill ran
    assert eng.stats.prefill_chunks == 5
    assert len(eng.sessions[0].tokens) == 5
    while eng.step():
        pass
    paged = {rid: eng.sessions[rid].tokens for rid in eng.sessions}
    assert paged == dense


def test_paged_oom_backpressure_serializes_and_completes():
    """A pool sized for one request at a time: admissions serialize behind
    block availability (FIFO head waits, nobody starves), every request
    completes, and the tokens are unchanged."""
    cfg, params = _setup("qwen3-4b")
    trace = synthetic_trace(4, cfg.vocab, seed=7, prompt_lens=(4, 6),
                            new_tokens=(4, 6))
    need = max(-(-(r.prompt.shape[0] + r.max_new_tokens - 1)
                 // cfg.block_size) for r in trace)
    free_run, _ = _run(cfg, params, trace, paged=True, slots=2)
    tight, eng = _run(cfg, params, trace, paged=True, slots=2,
                      n_blocks=need + 1)
    assert tight == free_run
    assert all(s.done for s in eng.sessions.values())
    assert eng.stats.blocks_peak <= need
    assert eng.blocks.in_use == 0
    # queue-wait is visible: later requests waited for blocks/slots
    waits = [s.queue_wait for s in eng.sessions.values()]
    assert all(w == w for w in waits)        # no NaN: everyone was admitted
    assert max(waits) > min(waits)


def test_paged_submit_rejects_impossible_request():
    cfg, params = _setup("qwen3-4b")
    from repro.serve import Request
    eng = ServeEngine(cfg, params, slots=1, s_max=64, paged=True,
                      n_blocks=3)
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(Request(rid=0, prompt=np.arange(30), max_new_tokens=20))


# ---------------------------------------------------------------------------
# paged layout plumbing
# ---------------------------------------------------------------------------


def test_paged_decode_state_spec_shapes():
    cfg = configs.get("qwen3-4b").smoke()
    st = lm.paged_decode_state_spec(cfg, 3, 24, n_blocks=10, block_size=8,
                                    abstract=True)
    assert st.pos.shape == (3,) and st.pos.dtype == jnp.int32
    assert st.ctx is None
    pool = st.seg_states[0].k                # stacked per layer
    n_layers = cfg.segments()[0][1]
    assert pool.shape == (n_layers, 10, cfg.n_kv_heads, 8, cfg.d_head)


def test_paged_table_widths():
    cfg = configs.get("qwen3-4b").smoke()            # attn only
    assert lm.paged_table_widths(cfg, 48, 8, 8) == {"full": 6}
    cfg = configs.get("recurrentgemma-2b").smoke()   # local only (window 32)
    assert lm.paged_table_widths(cfg, 256, 8, 8) == {"win": 5}  # 39 tokens
    cfg = configs.get("xlstm-350m").smoke()          # no KV cache at all
    assert lm.paged_table_widths(cfg, 48, 8, 8) == {}


def test_engine_stats_block_occupancy_quantities():
    from repro.serve import EngineStats

    st = EngineStats(blocks_total=10)
    for u in (2, 6, 4):
        st.observe_blocks(u)
    assert st.blocks_peak == 6
    assert st.blocks_in_use == 4
    assert st.blocks_mean == pytest.approx(4.0)
    assert st.block_utilization == pytest.approx(0.4)
    assert EngineStats().block_utilization == 0.0


def test_report_ttft_and_queue_wait_quantiles():
    cfg, params = _setup("qwen3-4b")
    trace = synthetic_trace(3, cfg.vocab, seed=1, prompt_lens=(4, 6),
                            new_tokens=(3,))
    eng = ServeEngine(cfg, params, slots=1, s_max=16, paged=True)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    ttft = report.ttft_quantiles((0.5, 0.95))
    qw = report.queue_wait_quantiles((0.5, 0.95))
    lat = report.latency_quantiles((0.5, 0.95))
    assert 0.0 <= qw[0.5] <= ttft[0.5] <= lat[0.5]
    assert ttft[0.95] <= lat[0.95]
    for s in report.sessions.values():       # queue_wait <= ttft per session
        assert s.queue_wait <= s.ttft


# ---------------------------------------------------------------------------
# BlockPool refcounts + idle tier (DESIGN.md §15 host bookkeeping)
# ---------------------------------------------------------------------------


def test_block_pool_share_refcount_lifecycle():
    pool = BlockPool(8)
    a = pool.alloc(0, 3)                     # [1, 2, 3], ref 1 each
    pool.share(1, a)                         # rid 1 maps them read-only
    assert [pool.refcount(b) for b in a] == [2, 2, 2]
    assert pool.free(0) == 3                 # donor leaves; blocks stay held
    assert [pool.refcount(b) for b in a] == [1, 1, 1]
    assert pool.available == 4 and pool.in_use == 3
    assert pool.free(1) == 3                 # uncached -> straight to free
    assert pool.available == 7 and pool.idle == 0
    with pytest.raises(RuntimeError, match="free"):
        pool.share(2, [1])                   # sharing a free block is a bug
    with pytest.raises(ValueError):
        pool.share(2, [0])                   # the trash block, ever
    pool.alloc(3, 1)
    pool.share(4, [1])
    with pytest.raises(RuntimeError, match="already holds"):
        pool.share(4, [1])


def test_block_pool_cached_blocks_idle_then_evict_lru():
    pool = BlockPool(8)
    a = pool.alloc(0, 3)                     # [1, 2, 3]
    for b in a:
        pool.set_cached(b)
    pool.free(0)
    # cached blocks park idle (resident, not in use) instead of freeing
    assert pool.available == 4 and pool.idle == 3 and pool.in_use == 0
    assert pool.reclaimable == 7
    assert pool.idle_blocks == [1, 2, 3]     # LRU = release order
    pool.share(1, [2])                       # revive from idle
    assert pool.idle_blocks == [1, 3] and pool.refcount(2) == 1
    assert pool.cached(2)
    pool.free(1)
    assert pool.idle_blocks == [1, 3, 2]     # re-idled last -> evicted last
    assert pool.evict_idle(2) == [1, 3]
    assert not pool.cached(1) and pool.available == 6
    with pytest.raises(RuntimeError, match="idle"):
        pool.evict_idle(2)                   # only block 2 is left idle
    assert pool.alloc(5, 6) == [1, 3, 4, 5, 6, 7]   # evicted ids reusable
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(6, 1)                     # idle blocks need evict first


def test_block_pool_drop_single_hold_cow_path():
    pool = BlockPool(6)
    a = pool.alloc(0, 2)
    pool.set_cached(a[0])
    pool.share(1, a)
    pool.drop(1, a[0])                       # rid 1 lets go of one block
    assert pool.refcount(a[0]) == 1 and pool.held(1) == [a[1]]
    with pytest.raises(KeyError):
        pool.drop(1, a[0])                   # no double-drop
    pool.free(0)
    assert pool.idle_blocks == [a[0]]        # cached -> idle on last release
    with pytest.raises(RuntimeError, match="not held"):
        pool.set_cached(a[0])                # caching needs a live holder


# ---------------------------------------------------------------------------
# PrefixIndex: content-addressed chain matching (host logic, no model)
# ---------------------------------------------------------------------------


def test_prefix_index_chain_lookup_and_divergence():
    idx = PrefixIndex(4)
    donor = np.arange(12, dtype=np.int32)    # 3 full blocks
    for bid, (key, parent, toks) in zip([5, 6, 7], idx.chain(donor)):
        assert idx.register(key, parent, bid, toks)
    assert len(idx) == 3
    # full match walks the whole chain; no continuation block exists
    ids, n_full, child = idx.lookup(donor)
    assert ids == [5, 6, 7] and n_full == 3 and child is None
    # divergence mid block 1: one full block + the divergence block with
    # its common-token count
    probe = np.array([0, 1, 2, 3, 4, 5, 99, 99], np.int32)
    ids, n_full, child = idx.lookup(probe)
    assert ids == [5] and n_full == 1 and child == (6, 2)
    # boundary divergence: the continuation block matches 0 extra tokens
    probe = np.array([0, 1, 2, 3, 99, 99, 99, 99], np.int32)
    assert idx.lookup(probe) == ([5], 1, (6, 0))
    # nothing shared at all
    assert idx.lookup(np.full(8, 77, np.int32)) == ([], 0, (5, 0))
    # keep-first: a second registration of the same content no-ops
    key, parent, toks = idx.chain(donor)[0]
    assert not idx.register(key, parent, 9, toks)
    assert idx.lookup(donor)[0] == [5, 6, 7]


def test_prefix_index_eviction_orphans_descendants():
    idx = PrefixIndex(4)
    donor = np.arange(8, dtype=np.int32)
    for bid, (key, parent, toks) in zip([3, 4], idx.chain(donor)):
        idx.register(key, parent, bid, toks)
    idx.drop_block(3)                        # evict the chain head
    assert len(idx) == 1
    # the orphaned child is unreachable (its parent key now misses) ...
    assert idx.lookup(donor) == ([], 0, None)
    # ... until re-registering the head restores the chain, child and all
    key, parent, toks = idx.chain(donor)[0]
    assert idx.register(key, parent, 9, toks)
    assert idx.lookup(donor) == ([9, 4], 2, None)


def test_prefix_index_ctx_keys_the_chain_root():
    idx = PrefixIndex(4)
    toks = np.arange(4, dtype=np.int32)
    ctx_a = np.ones((2, 3), np.float32)
    ctx_b = np.zeros((2, 3), np.float32)
    key, parent, blk = idx.chain(toks, ctx_a)[0]
    idx.register(key, parent, 5, blk)
    # same tokens under a different (or no) modality context never match
    assert idx.lookup(toks, ctx_a)[0] == [5]
    assert idx.lookup(toks, ctx_b) == ([], 0, None)
    assert idx.lookup(toks, None) == ([], 0, None)


# ---------------------------------------------------------------------------
# prefix caching: cross-arch sharing identity (the §15 tentpole contract)
# ---------------------------------------------------------------------------

# which sweep archs can share prefixes: recurrent carries and local window
# rings cannot be rebuilt from cached blocks, so the engine auto-disables
ELIGIBLE = {"qwen3-4b": True, "recurrentgemma-2b": False,
            "whisper-tiny": True, "llama-3.2-vision-11b": True,
            "xlstm-350m": False}


def _shared_trace(cfg, seed=13):
    return synthetic_trace(6, cfg.vocab, seed=seed, prompt_lens=(3, 5),
                           new_tokens=(3, 5), prefix_frac=0.9, prefix_len=9,
                           n_ctx_tokens=cfg.n_ctx_tokens,
                           d_model=cfg.d_model)


@pytest.mark.parametrize("name", SWEEP_ARCHS)
def test_prefix_sharing_identity_sweep(name):
    """A 90%-shared-prefix trace is token-identical with the prefix cache
    on vs off, for every paged arch family — and the cache genuinely
    engages where it is sound (skipped tokens, shared blocks) while the
    recurrent/window-ring archs take the documented disabled path."""
    cfg, params = _setup(name)
    trace = _shared_trace(cfg)
    off, _ = _run(cfg, params, trace, paged=True, prefix_cache=False)
    on, eng = _run(cfg, params, trace, paged=True, prefix_cache=True)
    assert on == off
    assert eng.prefix_caching == ELIGIBLE[name]
    if ELIGIBLE[name]:
        assert eng.stats.prefix_hits > 0
        assert eng.stats.prefix_tokens > 0
        assert 0.0 < eng.stats.prefix_hit_rate < 1.0
        assert eng.stats.blocks_per_request > 0
        # the trash block is never registered or cached
        assert 0 not in eng._prefix._by_block
        assert not eng.blocks.cached(0)
    else:
        assert eng.stats.prefix_hits == 0
        assert eng.stats.cow_copies == 0
    if eng.blocks is not None:
        assert eng.blocks.in_use == 0        # only idle cached blocks remain


def test_prefix_sharing_identity_packed_residency():
    """Sharing under packed-weight residency: the cached KV a request maps
    was produced by the same packed kernels, so identity must hold
    packed-on == packed-off == float-off."""
    cfg, params = _setup("qwen2-7b+xnor")
    trace = _shared_trace(cfg, seed=21)
    packed_off, _ = _run(cfg, params, trace, paged=True, pack=True,
                         prefix_cache=False)
    packed_on, eng = _run(cfg, params, trace, paged=True, pack=True,
                          prefix_cache=True)
    float_off, _ = _run(cfg, params, trace, paged=True, pack=False,
                        prefix_cache=False)
    assert packed_on == packed_off == float_off
    assert eng.stats.prefix_hits > 0


@pytest.mark.parametrize("name", ["qwen3-4b", "whisper-tiny"])
def test_prefix_sharing_identity_i8_cache(name):
    """Sharing over the fixed-point i8 KV cache: the donor's quantized
    words are bitwise what the sharer would have written, so identity
    holds with no requantization drift."""
    cfg, params = _setup(name)
    cfg = dataclasses.replace(cfg, kv_cache_dtype="i8")
    trace = _shared_trace(cfg, seed=8)
    off, _ = _run(cfg, params, trace, paged=True, prefix_cache=False)
    on, eng = _run(cfg, params, trace, paged=True, prefix_cache=True)
    assert on == off
    assert eng.stats.prefix_hits > 0


def test_prefix_sharing_moe_deterministic_replay():
    """MoE shares prefixes too (its KV is ordinary paged state) but is
    exempt from identity vs the cache-off path (§14: expert capacity is
    group-length dependent, and a cache hit legitimately shortens the
    dispatched group).  The pinned property is determinism: replaying the
    same shared trace through an identically configured engine — sharing,
    COW, LRU eviction and all — reproduces the tokens exactly."""
    cfg, params = _setup("llama4-scout-17b-a16e")

    def run():
        toks, eng = _run(cfg, params, _shared_trace(cfg, seed=3),
                         paged=True, slots=2, prefix_cache=True)
        return toks, eng

    t1, e1 = run()
    t2, e2 = run()
    assert t1 == t2
    assert e1.prefix_caching and e1.stats.prefix_hits > 0
    assert e1.stats.prefix_hits == e2.stats.prefix_hits
    assert e1.stats.cow_copies == e2.stats.cow_copies


# ---------------------------------------------------------------------------
# adversarial divergence points: exactly one COW each (EngineStats-pinned)
# ---------------------------------------------------------------------------


def _engine(cfg, params, *, slots=1, s_max=40, prefix_cache=True,
            n_blocks=0):
    return ServeEngine(cfg, params, slots=slots, s_max=s_max, paged=True,
                       prefix_cache=prefix_cache, n_blocks=n_blocks)


def _serve(eng, rid, prompt, new=4):
    eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=new))
    eng.run()
    return list(eng.sessions[rid].tokens)


def test_prefix_divergence_points_cost_exactly_one_cow():
    """Divergence at a block boundary, one token after it, mid-block, and
    a full-prompt hit: each admission maps the donor's blocks, triggers
    exactly ONE copy-on-write copy, skips to the divergence point, and
    produces the same tokens as a cache-off engine.  A donor replay after
    a divergent sharer proves no COW bleed back into shared blocks."""
    cfg, params = _setup("qwen3-4b")
    bs = cfg.block_size
    rng = np.random.default_rng(0)
    donor = rng.integers(0, cfg.vocab, 3 * bs).astype(np.int32)
    diff = (donor + 1) % cfg.vocab           # divergent everywhere
    probes = {
        "boundary": np.concatenate([donor[:2 * bs], diff[:bs]]),
        "one_after_boundary": np.concatenate([donor[:2 * bs + 1],
                                              diff[:bs - 1]]),
        "mid_block": np.concatenate([donor[:2 * bs + 4], diff[:bs - 4]]),
        "full_hit": donor.copy(),
    }
    expect_skip = {"boundary": 2 * bs, "one_after_boundary": 2 * bs + 1,
                   "mid_block": 2 * bs + 4, "full_hit": 3 * bs - 1}

    ref = _engine(cfg, params, prefix_cache=False)
    eng = _engine(cfg, params, prefix_cache=True)
    donor_ref = _serve(ref, 0, donor)
    assert _serve(eng, 0, donor) == donor_ref
    assert eng.stats.cow_copies == 0         # the cold donor never COWs
    for i, (case, probe) in enumerate(probes.items(), start=1):
        cow0, skip0 = eng.stats.cow_copies, eng.stats.prefix_tokens
        toks = _serve(eng, i, probe)
        assert toks == _serve(ref, i, probe), case
        assert eng.stats.cow_copies - cow0 == 1, case
        assert eng.stats.prefix_tokens - skip0 == expect_skip[case], case
    # no bleed: the donor's cached blocks survived four divergent sharers
    assert _serve(eng, 99, donor.copy()) == donor_ref


def test_prefix_sharing_with_mid_prefill_donor():
    """A request can share blocks a *still-prefilling* donor has already
    written (registration follows the one-chunk-per-step dispatch order):
    the sharer diverges mid-block inside the donor's registered region,
    costs exactly one COW, and both match their cache-off tokens."""
    cfg, params = _setup("qwen3-4b")
    bs, c = cfg.block_size, cfg.prefill_chunk
    rng = np.random.default_rng(1)
    donor = rng.integers(0, cfg.vocab, 5 * c).astype(np.int32)
    probe = np.concatenate([donor[:bs + 4],
                            (donor[bs + 4:2 * bs] + 1) % cfg.vocab])

    def staggered(prefix_cache):
        eng = _engine(cfg, params, slots=2, s_max=48,
                      prefix_cache=prefix_cache)
        eng.submit(Request(rid=0, prompt=donor, max_new_tokens=4))
        eng.step()
        eng.step()          # donor has dispatched 2 chunks -> 2 full blocks
        eng.submit(Request(rid=1, prompt=probe, max_new_tokens=4))
        while eng.step():
            pass
        return {rid: eng.sessions[rid].tokens for rid in eng.sessions}, eng

    on, eng = staggered(True)
    off, _ = staggered(False)
    assert on == off
    assert eng.stats.cow_copies == 1
    assert eng.stats.prefix_tokens == bs + 4


def test_prefix_disabled_inside_local_window_ring():
    """The window-ring exception (§15): ring blocks are recycled in place,
    so their contents are never registrable — a shared-prefix trace on a
    local-attention arch runs with sharing auto-disabled, zero COWs, and
    tokens identical to an engine with the cache explicitly off."""
    cfg, params = _setup("recurrentgemma-2b", local_window=8)
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab, 20).astype(np.int32)
    trace = [Request(rid=i,
                     prompt=np.concatenate(
                         [shared, rng.integers(0, cfg.vocab, 3)
                          .astype(np.int32)]),
                     max_new_tokens=4) for i in range(3)]
    off, _ = _run(cfg, params, trace, paged=True, s_max=32,
                  prefix_cache=False)
    on, eng = _run(cfg, params, trace, paged=True, s_max=32,
                   prefix_cache=True)
    assert on == off
    assert not eng.prefix_caching
    assert eng.stats.cow_copies == 0 and eng.stats.prefix_hits == 0


def test_prefix_ctx_mismatch_never_shares():
    """Identical token prefixes under different modality contexts must not
    share (the chain root folds a ctx digest): same audio hits, different
    audio misses, and the same-ctx replay reproduces the same tokens."""
    cfg, params = _setup("whisper-tiny")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 2 * cfg.block_size).astype(np.int32)
    ctx_a = rng.standard_normal((cfg.n_ctx_tokens, cfg.d_model)) \
        .astype(np.float32) * 0.1
    ctx_b = rng.standard_normal((cfg.n_ctx_tokens, cfg.d_model)) \
        .astype(np.float32) * 0.1
    # pool wide enough that neither foreign-ctx admission evicts rid 0's
    # cached chain before the same-ctx replay arrives
    eng = _engine(cfg, params, s_max=24, n_blocks=16)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4, ctx=ctx_a))
    eng.run()
    eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=4, ctx=ctx_b))
    eng.run()
    assert eng.stats.prefix_hits == 0        # different ctx: no sharing
    eng.submit(Request(rid=2, prompt=prompt, max_new_tokens=4, ctx=ctx_a))
    eng.run()
    assert eng.stats.prefix_hits == 1        # same ctx: full-prompt hit
    assert eng.sessions[2].tokens == eng.sessions[0].tokens


def test_prefix_eviction_under_pool_pressure_lru():
    """A tight pool: cached prefixes are evicted LRU under allocation
    pressure (never while held), the index entries drop with them, and a
    later replay of the evicted prompt simply misses — correctness is
    unchanged, greedy replay reproduces the donor's tokens."""
    cfg, params = _setup("qwen3-4b")
    bs = cfg.block_size
    rng = np.random.default_rng(4)
    donor = rng.integers(0, cfg.vocab, 2 * bs).astype(np.int32)
    eng = _engine(cfg, params, s_max=32, n_blocks=5)   # 4 allocatable
    donor_toks = _serve(eng, 0, donor)
    assert eng.stats.prefix_cached_blocks > 0
    # unrelated requests churn the pool until the donor's entries evict
    for i in range(1, 4):
        _serve(eng, i, rng.integers(0, cfg.vocab, 2 * bs).astype(np.int32))
    assert eng.stats.prefix_evictions > 0
    replay = _serve(eng, 9, donor.copy())
    assert replay == donor_toks              # miss or hit, tokens identical
    assert eng.blocks.in_use == 0


def test_prefix_gate_excludes_blocks_the_plan_itself_revives():
    """Admission must not count idle blocks the plan's own share() will
    revive as evictable: a donor finishes leaving its 2-block prefix idle
    in a 4-block pool, then a same-prompt request needing 4 blocks total
    arrives.  Sharing would revive both idle blocks and leave only 2
    evictable for 3 fresh — the old gate passed it (need 3 <= reclaimable
    4) and crashed evict_idle mid-run.  With nothing in flight the head
    degrades to a wholly-fresh plan instead of deadlocking, and the
    tokens match a cache-off engine."""
    cfg, params = _setup("qwen3-4b")
    bs = cfg.block_size
    rng = np.random.default_rng(11)
    donor = rng.integers(0, cfg.vocab, 2 * bs).astype(np.int32)
    ref = _engine(cfg, params, prefix_cache=False)
    eng = _engine(cfg, params, n_blocks=5)     # 4 allocatable
    assert _serve(eng, 0, donor, new=1) == _serve(ref, 0, donor, new=1)
    assert eng.blocks.idle == 2                # prefix parked, pool drained
    new = 2 * bs + 1                           # 4 blocks total, 3 fresh
    toks = _serve(eng, 1, donor.copy(), new=new)
    assert toks == _serve(ref, 1, donor.copy(), new=new)
    assert all(s.done for s in eng.sessions.values())
    assert eng.blocks.in_use == 0


def test_prefix_sharer_allocates_under_pressure_while_prefix_idle():
    """The sharer itself allocates under pool pressure while its shared
    prefix sits idle: sharing survives (the plan fits once its revived
    blocks are excluded from the evictable count) and the fresh
    allocation evicts an unrelated idle block — not the revived prefix —
    with no crash and cache-off-identical tokens."""
    cfg, params = _setup("qwen3-4b")
    bs = cfg.block_size
    rng = np.random.default_rng(12)
    donor = rng.integers(0, cfg.vocab, 2 * bs).astype(np.int32)
    other = rng.integers(0, cfg.vocab, 2 * bs).astype(np.int32)
    ref = _engine(cfg, params, prefix_cache=False)
    eng = _engine(cfg, params, n_blocks=7)     # 6 allocatable
    assert _serve(eng, 0, donor, new=1) == _serve(ref, 0, donor, new=1)
    assert _serve(eng, 1, other, new=1) == _serve(ref, 1, other, new=1)
    assert eng.blocks.idle == 4 and eng.blocks.available == 2
    new = 2 * bs + 1           # 3 fresh after the COW credit, 2 free
    hits0, evict0 = eng.stats.prefix_hits, eng.stats.prefix_evictions
    toks = _serve(eng, 2, donor.copy(), new=new)
    assert toks == _serve(ref, 2, donor.copy(), new=new)
    assert eng.stats.prefix_hits == hits0 + 1          # sharing survived
    assert eng.stats.prefix_evictions == evict0 + 1    # one unrelated evict
    assert eng.blocks.in_use == 0


def test_blocked_head_plan_recomputed_only_on_index_change():
    """While the FIFO head waits (here: on the single slot), its prefix
    plan is memoized on the index generation instead of re-hashing the
    whole prompt every engine step."""
    cfg, params = _setup("qwen3-4b")
    bs = cfg.block_size
    rng = np.random.default_rng(13)
    eng = _engine(cfg, params, slots=1)
    calls = 0
    orig = eng._prefix_plan

    def counting(req):
        nonlocal calls
        calls += 1
        return orig(req)

    eng._prefix_plan = counting
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, bs)
                       .astype(np.int32), max_new_tokens=24))
    eng.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab, bs)
                       .astype(np.int32), max_new_tokens=2))
    eng.run()
    assert eng.stats.decode_steps >= 20      # rid 1 was head for many steps
    assert calls <= 4                        # not once per step


def test_engine_stats_prefix_quantities():
    from repro.serve import EngineStats

    st = EngineStats()
    assert st.prefix_hit_rate == 0.0 and st.blocks_per_request == 0.0
    st.prompt_tokens, st.prefix_tokens = 40, 10
    st.prefills, st.fresh_blocks = 4, 6
    assert st.prefix_hit_rate == pytest.approx(0.25)
    assert st.blocks_per_request == pytest.approx(1.5)


def test_synthetic_trace_prefix_knobs():
    """prefix_frac/prefix_len: seeded, schedule-independent, and a pure
    extension — per-request draws are bit-identical to the base trace, the
    shared group gets the same prefix (and one shared ctx object)."""
    base = synthetic_trace(8, 256, seed=5, prompt_lens=(4, 6))
    mixed = synthetic_trace(8, 256, seed=5, prompt_lens=(4, 6),
                            prefix_frac=0.75, prefix_len=9)
    again = synthetic_trace(8, 256, seed=5, prompt_lens=(4, 6),
                            prefix_frac=0.75, prefix_len=9)
    shared = [r for r, b in zip(mixed, base)
              if r.prompt.shape[0] == b.prompt.shape[0] + 9]
    assert 0 < len(shared) < len(mixed)      # a genuine mix at 0.75
    prefix = shared[0].prompt[:9]
    for r, b in zip(mixed, base):
        if r.prompt.shape[0] == b.prompt.shape[0]:     # unshared request
            assert np.array_equal(r.prompt, b.prompt)
        else:
            assert np.array_equal(r.prompt[:9], prefix)
            assert np.array_equal(r.prompt[9:], b.prompt)
        assert r.max_new_tokens == b.max_new_tokens
    for r, r2 in zip(mixed, again):          # fully deterministic
        assert np.array_equal(r.prompt, r2.prompt)
    # ctx archs: the shared group shares ONE ctx object (sharing is keyed
    # per-ctx, so distinct ctx objects would never share)
    vl = synthetic_trace(8, 256, seed=5, prompt_lens=(4,), n_ctx_tokens=2,
                         d_model=4, prefix_frac=0.75, prefix_len=9)
    ctxs = [r.ctx for r in vl if r.prompt.shape[0] == 13]
    assert all(c is ctxs[0] for c in ctxs)
