"""Beyond-paper: summarize the dry-run roofline table (reads
experiments/dryrun/*.json produced by repro.launch.dryrun).
"""

from __future__ import annotations

import glob
import json
import os


def run() -> list[tuple]:
    rows = []
    paths = sorted(glob.glob(os.path.join("experiments", "dryrun", "*.json")))
    if not paths:
        return [("roofline_table", 0.0,
                 "no dry-run artifacts; run python -m repro.launch.dryrun")]
    for p in paths:
        r = json.load(open(p))
        name = os.path.basename(p)[:-5]
        if r.get("status") == "ok":
            rows.append((name, r.get("t_compile_s", 0) * 1e6,
                         f"bottleneck={r['bottleneck']} "
                         f"t=({r['t_compute_s']*1e3:.1f},"
                         f"{r['t_memory_s']*1e3:.1f},"
                         f"{r['t_collective_s']*1e3:.1f})ms "
                         f"useful={r.get('useful_flops_frac', 0)*100:.0f}% "
                         f"roofline={r.get('roofline_frac', 0)*100:.1f}%"))
        elif r.get("status") == "skipped":
            rows.append((name, 0.0, "skipped: " + r.get("reason", "")[:60]))
        else:
            rows.append((name, 0.0, "ERROR " + r.get("error", "")[:80]))
    return rows
