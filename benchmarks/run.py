# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (benchmarks read the paper's own artifacts: Fig. 4 functional
# verification, Fig. 5 Monte-Carlo, Table I latency, Fig. 6 XNOR-Net
# speedup, §II copy-verify/encrypt throughput, plus the beyond-paper
# roofline summary from the dry-run).
from __future__ import annotations

import sys
import traceback

from benchmarks import (bank_scaling, fig4_functional, fig5_montecarlo,
                        fig6_xnornet, incremental_verify, roofline_bench,
                        serve_throughput, serve_workloads, table1_latency,
                        verify_throughput)

SUITES = [
    ("fig4", fig4_functional),
    ("fig5", fig5_montecarlo),
    ("table1", table1_latency),
    ("fig6", fig6_xnornet),
    ("verify", verify_throughput),
    ("incremental", incremental_verify),
    ("banks", bank_scaling),
    ("serve", serve_throughput),
    ("workloads", serve_workloads),
    ("roofline", roofline_bench),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = 0
    for tag, mod in SUITES:
        try:
            for name, us, derived in mod.run():
                print(f"{tag}/{name},{us:.1f},{derived}")
        except Exception:
            failed += 1
            print(f"{tag}/ERROR,,{traceback.format_exc(limit=2)!r}",
                  file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
