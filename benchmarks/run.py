# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (benchmarks read the paper's own artifacts: Fig. 4 functional
# verification, Fig. 5 Monte-Carlo, Table I latency, Fig. 6 XNOR-Net
# speedup, §II copy-verify/encrypt throughput, plus the beyond-paper
# roofline summary from the dry-run).
#
# ``--json PATH`` additionally writes the rows as a flat JSON record list
# (schema: benchmark, config, metric, value, commit) — the serve suites'
# records are checked in as BENCH_serve.json and re-emitted as a CI
# artifact, so serving-throughput history rides along with the code.
# ``--only tag1,tag2`` restricts the run to a subset of suites.
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import traceback

from benchmarks import (bank_scaling, fig4_functional, fig5_montecarlo,
                        fig6_xnornet, incremental_verify, paged_decode_bench,
                        roofline_bench, serve_replicated, serve_throughput,
                        serve_workloads, table1_latency, verify_throughput)

SUITES = [
    ("fig4", fig4_functional),
    ("fig5", fig5_montecarlo),
    ("table1", table1_latency),
    ("fig6", fig6_xnornet),
    ("verify", verify_throughput),
    ("incremental", incremental_verify),
    ("banks", bank_scaling),
    ("serve", serve_throughput),
    ("workloads", serve_workloads),
    ("replicated", serve_replicated),
    ("paged_decode", paged_decode_bench),
    ("roofline", roofline_bench),
]


def _commit() -> str:
    try:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            text=True, stderr=subprocess.DEVNULL).strip()
    except Exception:
        return "unknown"


def _json_rows(tag: str, name: str, us: float, derived, commit: str) -> list:
    """One CSV row -> flat JSON records: the primary us_per_call metric
    plus every ``key=value`` pair in the derived column that parses as a
    number (free-text derived values stay CSV-only)."""
    rows = [{"benchmark": tag, "config": name, "metric": "us_per_call",
             "value": round(float(us), 1), "commit": commit}]
    for part in str(derived).split():
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            rows.append({"benchmark": tag, "config": name, "metric": k,
                         "value": float(v), "commit": commit})
        except ValueError:
            pass
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="also write records to this JSON file "
                         "(benchmark/config/metric/value/commit)")
    ap.add_argument("--only", default="",
                    help="comma-separated suite tags to run (default: all)")
    args = ap.parse_args(argv)
    suites = SUITES
    if args.only:
        want = set(args.only.split(","))
        unknown = want - {t for t, _ in SUITES}
        if unknown:
            raise SystemExit(f"unknown suite tags: {sorted(unknown)}")
        suites = [s for s in SUITES if s[0] in want]

    commit = _commit()
    records = []
    print("name,us_per_call,derived")
    failed = 0
    for tag, mod in suites:
        try:
            for name, us, derived in mod.run():
                print(f"{tag}/{name},{us:.1f},{derived}")
                records.extend(_json_rows(tag, name, us, derived, commit))
        except Exception:
            failed += 1
            print(f"{tag}/ERROR,,{traceback.format_exc(limit=2)!r}",
                  file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
            f.write("\n")
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
