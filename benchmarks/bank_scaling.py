"""Bank-scaling throughput: ops/cycle vs bank count (DESIGN.md §10).

The paper's throughput argument is architectural: one sense cycle computes a
row-wide XOR/XNOR, and independent banks multiply that by B.  This benchmark
drives both engine views at B in {1, 8, 64}:

* circuit path — banked analog simulation (`CimEngine.simulate`): wall-clock
  per traced call and modeled ops/cycle, which must scale linearly in B;
* engine path — the packed `bulk_op` kernel over a fixed buffer: modeled
  cycle count, which must fall as 1/B.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import BankGeometry, CimEngine

BANK_COUNTS = (1, 8, 64)
PAIRS = 8            # row-pairs scheduled per bank (P sense cycles)
COLS = 128           # bank row width (bits)
BUF_WORDS = 1 << 16  # engine-path payload: 64k uint32 words = 2 Mbit


def run() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)
    buf_a = jnp.asarray(rng.integers(0, 2**32, BUF_WORDS, dtype=np.uint32))
    buf_b = jnp.asarray(rng.integers(0, 2**32, BUF_WORDS, dtype=np.uint32))

    for banks in BANK_COUNTS:
        geo = BankGeometry(banks=banks, rows=2 * PAIRS, cols=COLS)
        eng = CimEngine(geo)
        n = banks * PAIRS
        a = jnp.asarray(rng.integers(0, 2, (n, COLS)))
        b = jnp.asarray(rng.integers(0, 2, (n, COLS)))

        out = eng.simulate(a, b, "xor")          # compile + correctness
        assert np.array_equal(np.asarray(out),
                              np.asarray(a ^ b).astype(bool))
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(eng.simulate(a, b, "xor"))
        us = (time.perf_counter() - t0) * 1e6 / reps
        rows.append((f"circuit_B{banks}", us,
                     f"{n}x{COLS}b pairs in {PAIRS} cycles = "
                     f"{geo.bits_per_cycle} ops/cycle"))

        eng2 = CimEngine(geo)
        enc = eng2.xor(buf_a, buf_b)
        jax.block_until_ready(enc)
        t0 = time.perf_counter()
        jax.block_until_ready(eng2.xor(buf_a, buf_b))
        us = (time.perf_counter() - t0) * 1e6
        cyc = eng2.cycles_for(BUF_WORDS * 32)
        rows.append((f"engine_B{banks}", us,
                     f"{BUF_WORDS * 32} bit-ops in {cyc} modeled cycles "
                     f"({eng2.stats.ops_per_cycle:.0f} ops/cycle)"))

    # linearity check across the sweep: ops/cycle ratio == bank ratio
    base = BANK_COUNTS[0]
    geo0 = BankGeometry(banks=base, rows=2 * PAIRS, cols=COLS)
    for banks in BANK_COUNTS[1:]:
        geo = BankGeometry(banks=banks, rows=2 * PAIRS, cols=COLS)
        rows.append((f"scaling_B{base}->B{banks}", 0.0,
                     f"ops/cycle x{geo.bits_per_cycle // geo0.bits_per_cycle} "
                     f"(ideal x{banks // base})"))
    return rows
