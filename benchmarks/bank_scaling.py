"""Bank- and device-scaling throughput: ops/cycle vs bank count and device
count (DESIGN.md §10–§11).

The paper's throughput argument is architectural: one sense cycle computes a
row-wide XOR/XNOR, and independent banks multiply that by B.  This benchmark
drives both engine views at B in {1, 8, 64}:

* circuit path — banked analog simulation (`CimEngine.simulate`): wall-clock
  per traced call and modeled ops/cycle, which must scale linearly in B;
* engine path — the packed `bulk_op` kernel over a fixed buffer: modeled
  cycle count, which must fall as 1/B.

The device axis extends the same argument across a mesh (`ShardedCimEngine`,
mesh-as-outer-bank): each D in {1, 2, 4, 8} runs in a subprocess with
`XLA_FLAGS=--xla_force_host_platform_device_count=D` (the flag must predate
jax init), reporting modeled ops/cycle and HBM bytes moved for sharded
xor / digest / stream_cipher — ops/cycle scales linearly in D while the
digest's cross-device traffic stays one 512-byte digest per reduce.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import BankGeometry, CimEngine

BANK_COUNTS = (1, 8, 64)
DEVICE_COUNTS = (1, 2, 4, 8)
PAIRS = 8            # row-pairs scheduled per bank (P sense cycles)
COLS = 128           # bank row width (bits)
BUF_WORDS = 1 << 16  # engine-path payload: 64k uint32 words = 2 Mbit

_DEVICE_CHILD = textwrap.dedent("""
    import json, sys, time
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.engine import BankGeometry, ShardedCimEngine
    from repro.launch.mesh import make_engine_mesh

    devices, buf_words, cols = (int(a) for a in sys.argv[1:4])
    mesh = make_engine_mesh(devices)
    # same row width (bits) as the bank sweep, default 8 banks: device_D1
    # matches engine_B8 ops/cycle, so the two axes compose comparably.
    eng = ShardedCimEngine(mesh, geometry=BankGeometry(cols=cols))
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 2**32, buf_words, dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, buf_words, dtype=np.uint32))
    key = jnp.array([7, 9], dtype=jnp.uint32)
    res = {"devices": devices, "bits_per_cycle": eng.geometry.bits_per_cycle}
    for name, fn, moved in (
            ("xor", lambda: eng.xor(a, b), 3 * 4 * buf_words),
            ("digest", lambda: eng.digest(a), 4 * buf_words + 512 * devices),
            ("cipher", lambda: eng.stream_cipher(a, key), 2 * 4 * buf_words)):
        jax.block_until_ready(fn())          # compile
        t0 = time.perf_counter()
        reps = 10
        for _ in range(reps):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) * 1e6 / reps
        res[name] = {"us": us, "bytes_moved": moved,
                     "cycles": eng.cycles_for(buf_words * 32)}
    print(json.dumps(res))
""")


def _device_rows() -> list[tuple]:
    """Sharded-engine sweep, one subprocess per simulated device count."""
    rows = []
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for d in DEVICE_COUNTS:
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={d}",
                   PYTHONPATH=os.path.join(root, "src"))
        r = subprocess.run([sys.executable, "-c", _DEVICE_CHILD, str(d),
                            str(BUF_WORDS), str(COLS)],
                           capture_output=True, text=True, env=env,
                           timeout=600)
        if r.returncode != 0:
            rows.append((f"device_D{d}_ERROR", 0.0, r.stderr[-200:]))
            continue
        res = json.loads(r.stdout.splitlines()[-1])
        opc = BUF_WORDS * 32 / res["xor"]["cycles"]
        for name in ("xor", "digest", "cipher"):
            m = res[name]
            rows.append((f"device_{name}_D{d}", m["us"],
                         f"{BUF_WORDS * 32} bit-ops in {m['cycles']} cycles"
                         f" = {opc:.0f} ops/cycle;"
                         f" {m['bytes_moved']} bytes moved"))
    return rows


def run() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)
    buf_a = jnp.asarray(rng.integers(0, 2**32, BUF_WORDS, dtype=np.uint32))
    buf_b = jnp.asarray(rng.integers(0, 2**32, BUF_WORDS, dtype=np.uint32))

    for banks in BANK_COUNTS:
        geo = BankGeometry(banks=banks, rows=2 * PAIRS, cols=COLS)
        eng = CimEngine(geo)
        n = banks * PAIRS
        a = jnp.asarray(rng.integers(0, 2, (n, COLS)))
        b = jnp.asarray(rng.integers(0, 2, (n, COLS)))

        out = eng.simulate(a, b, "xor")          # compile + correctness
        assert np.array_equal(np.asarray(out),
                              np.asarray(a ^ b).astype(bool))
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(eng.simulate(a, b, "xor"))
        us = (time.perf_counter() - t0) * 1e6 / reps
        rows.append((f"circuit_B{banks}", us,
                     f"{n}x{COLS}b pairs in {PAIRS} cycles = "
                     f"{geo.bits_per_cycle} ops/cycle"))

        eng2 = CimEngine(geo)
        enc = eng2.xor(buf_a, buf_b)
        jax.block_until_ready(enc)
        t0 = time.perf_counter()
        jax.block_until_ready(eng2.xor(buf_a, buf_b))
        us = (time.perf_counter() - t0) * 1e6
        cyc = eng2.cycles_for(BUF_WORDS * 32)
        rows.append((f"engine_B{banks}", us,
                     f"{BUF_WORDS * 32} bit-ops in {cyc} modeled cycles "
                     f"({eng2.stats.ops_per_cycle:.0f} ops/cycle)"))

    # linearity check across the sweep: ops/cycle ratio == bank ratio
    base = BANK_COUNTS[0]
    geo0 = BankGeometry(banks=base, rows=2 * PAIRS, cols=COLS)
    for banks in BANK_COUNTS[1:]:
        geo = BankGeometry(banks=banks, rows=2 * PAIRS, cols=COLS)
        rows.append((f"scaling_B{base}->B{banks}", 0.0,
                     f"ops/cycle x{geo.bits_per_cycle // geo0.bits_per_cycle} "
                     f"(ideal x{banks // base})"))

    rows.extend(_device_rows())
    return rows
