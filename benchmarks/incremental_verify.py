"""Dirty-fraction sweep for the incremental verification + delta checkpoint
subsystem (DESIGN.md §12).

The paper's headline workload is the periodic backup scrub: XOR the copy
against the source, zero means intact.  This sweep measures what the
DigestCache saves when only a fraction of the pool moved between scrubs —
engine digest cycles and wall time vs the full re-digest, plus the bytes a
delta checkpoint writes vs a full one, for dirty fractions of 1%, 10% and
100% of the tree's chunks.

Run:  PYTHONPATH=src python benchmarks/incremental_verify.py [--smoke]
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

FRACTIONS = (0.01, 0.10, 1.00)


def _build(n_chunks: int, chunk_words: int, n_leaves: int):
    rng = np.random.default_rng(0)
    import jax.numpy as jnp
    per = n_chunks * chunk_words
    return {f"layer{i}": jnp.asarray(
        rng.integers(0, 2**32, per, dtype=np.uint32))
        for i in range(n_leaves)}


def _dirty(tree, frac: float, chunk_words: int, seed: int):
    """Flip one bit in ``frac`` of the tree's chunks, picked globally.

    Leaves that draw no chunk keep their identity (the cache's cheapest
    path); flip offsets vary per chunk so an even number of same-column
    flips can't cancel in a leaf's XOR fold (digests are columnwise parity
    — see test_digest_order_sensitivity_is_columnwise).
    """
    rng = np.random.default_rng(seed)
    spans = [(k, i) for k, buf in tree.items()
             for i in range(buf.shape[0] // chunk_words)]
    m = max(1, int(round(frac * len(spans))))
    by_key: dict = {}
    for p in rng.choice(len(spans), size=m, replace=False):
        k, i = spans[int(p)]
        by_key.setdefault(k, []).append(i)
    out = dict(tree)
    for k, idxs in by_key.items():
        # one batched scatter per leaf: a per-flip .at.set would rebuild the
        # whole leaf once per chunk (GBs of setup traffic at 100% dirty)
        import jax.numpy as jnp
        pos = jnp.asarray([i * chunk_words + int(rng.integers(chunk_words))
                           for i in idxs])
        out[k] = tree[k].at[pos].set(tree[k][pos] ^ np.uint32(1))
    return out, m


def run(smoke: bool = False) -> list[tuple]:
    from repro.checkpoint import ckpt
    from repro.core import verify
    from repro.core.engine import CimEngine
    from repro.core.incremental import DigestCache

    chunk_words = 1 << 10 if smoke else 1 << 14
    n_chunks = 8 if smoke else 64
    n_leaves = 2 if smoke else 8
    tree = _build(n_chunks, chunk_words, n_leaves)
    nbytes = sum(int(v.size) * 4 for v in tree.values())

    rows = []
    eng = CimEngine()   # impl="auto": REPRO_KERNEL_IMPL steers the CI matrix
    cache = DigestCache(engine=eng, chunk_words=chunk_words)
    t0 = time.perf_counter()
    cache.digests(tree)                    # prime: full digest pass
    us_full = (time.perf_counter() - t0) * 1e6
    full_cycles = eng.stats.by_op["digest"][0]
    rows.append(("prime_full_digest", us_full,
                 f"{nbytes/1e6:.0f}MB {full_cycles} digest-cycles "
                 f"{n_leaves*n_chunks} chunks"))

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 0, tree, verify_write=False)
        base_bytes = os.path.getsize(os.path.join(d, "ckpt_00000000.npz"))
        for step, frac in enumerate(FRACTIONS, start=1):
            dirty_tree, k = _dirty(tree, frac, chunk_words, seed=step)

            snap = eng.stats.snapshot()
            t0 = time.perf_counter()
            cache.digests(dirty_tree)      # incremental re-verify
            us = (time.perf_counter() - t0) * 1e6
            cyc = eng.stats.by_op["digest"][0] - snap.by_op["digest"][0]
            rows.append((
                f"reverify_dirty_{int(frac*100):d}pct", us,
                f"{k}/{n_leaves*n_chunks} chunks {cyc} digest-cycles "
                f"({full_cycles/max(cyc,1):.1f}x fewer than full)"))

            t0 = time.perf_counter()
            # cache= keeps the dirty scan O(dirty) too (the cache is already
            # synced with dirty_tree, so it identity-hits every leaf)
            ckpt.save_delta(d, step, dirty_tree, base_step=step - 1,
                            verify_write=False, cache=cache)
            us = (time.perf_counter() - t0) * 1e6
            sz = os.path.getsize(os.path.join(d, f"ckpt_{step:08d}.npz"))
            rows.append((
                f"save_delta_dirty_{int(frac*100):d}pct", us,
                f"{sz/1e6:.2f}MB on disk vs {base_bytes/1e6:.2f}MB full"))
            # keep the cache tracking what's on disk for the next fraction
            tree = dirty_tree

    # reference: the non-incremental full scan at the same tree size
    t0 = time.perf_counter()
    verify.tree_digest(tree, engine=eng)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("reverify_full_scan", us, f"O(tree) reference, {nbytes/1e6:.0f}MB"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small tree for CI (seconds, not minutes)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(smoke=args.smoke):
        print(f"incremental/{name},{us:.1f},{derived}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
