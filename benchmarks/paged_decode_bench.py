"""Fused vs. unfused paged-decode attention, at kernel level (DESIGN.md §18).

Two implementations of the same decode-step attention over a block-paged
KV pool:

  fused    — ``kernels/paged_attn.py``: one Pallas dispatch that walks the
             block table via scalar prefetch, streams pool blocks through
             VMEM and keeps the online-softmax accumulator in registers
             (interpreted on CPU, compiled on real TPU);
  unfused  — the gather -> QK -> mask -> softmax -> PV jnp chain
             (``kernels/ref.py::paged_decode``), which is also the
             production decode path on CPU backends and the kernel's
             bit-exact-twin reference.

Swept across block_size x slots x f32/i8 KV.  Reported per cell: wall
microseconds per call for both paths and their jaxpr dispatch counts
(``roofline/analysis.dispatch_count`` — the fused path is a single
``pallas_call`` where the chain is dozens of primitives).  On CPU the
fused timing is the *interpreter's* (orders of magnitude slower — the
win this benchmark audits is dispatches and bytes, not CPU wall time);
the tok/s comparison under the production dispatch lives in
``serve_throughput.py``.

``--smoke`` additionally runs the engine-level gates CI pins in both
kernel modes: fused decode tokens (``REPRO_FUSED_DECODE=on``) bit-identical
to unfused (``off``) on a float32 smoke engine, and — outside the
interpret CI mode — the fused whole-decode-step dispatch count strictly
below the unfused one.

Run:  PYTHONPATH=src python benchmarks/paged_decode_bench.py [--smoke]
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np


def _time(fn, *args, iters: int = 5) -> float:
    """Median wall microseconds per call (post-compile)."""
    import jax

    jax.block_until_ready(fn(*args))            # compile / first interpret
    ts = []
    for _ in range(iters):
        t0 = time.monotonic()
        jax.block_until_ready(fn(*args))
        ts.append((time.monotonic() - t0) * 1e6)
    return float(np.median(ts))


def _make_case(bs: int, slots: int, kv_dtype: str, seed: int = 0):
    """One decode-step attention problem over a block-paged pool."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    kv, g, dh, w = 2, 2, 16, 6
    n_blocks = 1 + slots * w
    q = jnp.asarray(rng.standard_normal((slots, kv, g, dh)), jnp.float32)
    ck = rng.standard_normal((n_blocks, kv, bs, dh))
    cv = rng.standard_normal((n_blocks, kv, bs, dh))
    scale, out_scale = dh ** -0.5, 1.0
    if kv_dtype == "i8":
        i8s = 32.0
        ck = np.clip(np.round(ck * i8s), -127, 127).astype(np.int8)
        cv = np.clip(np.round(cv * i8s), -127, 127).astype(np.int8)
        scale, out_scale = scale / i8s, 1.0 / i8s
    else:
        ck, cv = ck.astype(np.float32), cv.astype(np.float32)
    table = jnp.asarray(
        rng.permutation(slots * w).reshape(slots, w) + 1, jnp.int32)
    pos = jnp.asarray(rng.integers(1, w * bs, size=(slots,)), jnp.int32)
    return (q, jnp.asarray(ck), jnp.asarray(cv), table, pos,
            float(scale), float(out_scale))


def _bench_cell(bs: int, slots: int, kv_dtype: str, quiet: bool):
    import functools

    import jax
    import jax.numpy as jnp
    from repro.kernels import paged_attn, ref
    from repro.roofline import analysis

    q, ck, cv, table, pos, scale, out_scale = _make_case(bs, slots, kv_dtype)
    interpret = jax.default_backend() != "tpu"
    fused = functools.partial(paged_attn.paged_decode_attention,
                              window=0, scale=scale, out_scale=out_scale,
                              interpret=interpret)
    unfused = jax.jit(functools.partial(ref.paged_decode, window=0,
                                        scale=scale, out_scale=out_scale))
    out_f = np.asarray(fused(q, ck, cv, table, pos))
    out_u = np.asarray(unfused(q, ck, cv, table, pos))
    np.testing.assert_allclose(out_f, out_u, rtol=2e-5, atol=2e-5)

    disp_f = analysis.dispatch_count(
        jax.make_jaxpr(fused)(q, ck, cv, table, pos))
    disp_u = analysis.dispatch_count(
        jax.make_jaxpr(unfused)(q, ck, cv, table, pos))
    us_f = _time(fused, q, ck, cv, table, pos)
    us_u = _time(unfused, q, ck, cv, table, pos)
    name = f"bs{bs}_s{slots}_{kv_dtype}"
    derived = (f"fused_us={us_f:.1f} unfused_us={us_u:.1f} "
               f"disp_fused={disp_f} disp_unfused={disp_u}")
    if not quiet:
        print(f"{name:<16s} {us_f:>10.1f} {us_u:>11.1f} "
              f"{disp_f:>6d} {disp_u:>8d}")
    assert disp_f < disp_u, (
        f"{name}: fused kernel traces to {disp_f} dispatches, "
        f"not below the unfused chain's {disp_u}")
    return name, us_f, derived


def _bench(smoke: bool, quiet: bool = False):
    cells = ([(8, 2, "f32"), (8, 2, "i8")] if smoke else
             [(bs, s, d) for bs in (8, 16) for s in (2, 8)
              for d in ("f32", "i8")])
    if not quiet:
        print(f"{'cell':<16s} {'fused us':>10s} {'unfused us':>11s} "
              f"{'disp_f':>6s} {'disp_u':>8s}")
    return [_bench_cell(bs, s, d, quiet) for bs, s, d in cells]


def _run_smoke_engine(fused: str):
    """Tokens + decode-step audit of a small paged engine under one
    ``REPRO_FUSED_DECODE`` setting (restored afterwards)."""
    import jax
    import jax.numpy as jnp
    import repro.configs as configs
    from repro.models import lm
    from repro.serve import ServeEngine, synthetic_trace

    prev = os.environ.get("REPRO_FUSED_DECODE")
    os.environ["REPRO_FUSED_DECODE"] = fused
    try:
        cfg = configs.get("qwen3-4b").smoke(dtype=jnp.float32)
        params = lm.init_params(cfg, jax.random.PRNGKey(7))
        eng = ServeEngine(cfg, params, slots=2, s_max=24, paged=True)
        for r in synthetic_trace(4, cfg.vocab, seed=7):
            eng.submit(r)
        rep = eng.run()
        toks = {rid: rep.tokens(rid).tolist() for rid in rep.sessions}
        return toks, eng.decode_roofline()
    finally:
        if prev is None:
            os.environ.pop("REPRO_FUSED_DECODE", None)
        else:
            os.environ["REPRO_FUSED_DECODE"] = prev


def _smoke_gates() -> None:
    from repro.roofline import report

    toks_on, audit_on = _run_smoke_engine("on")
    toks_off, audit_off = _run_smoke_engine("off")
    assert toks_on == toks_off, (
        "fused decode tokens diverge from unfused on the smoke engine")
    print(report.serve_decode_header())
    print(report.serve_decode_row("decode/fused", audit_on))
    print(report.serve_decode_row("decode/unfused", audit_off))
    if os.environ.get("REPRO_KERNEL_IMPL", "") != "interpret":
        assert audit_on["dispatches"] < audit_off["dispatches"], (
            f"fused decode step dispatches ({audit_on['dispatches']}) not "
            f"below unfused ({audit_off['dispatches']})")
    print("smoke OK: fused tokens == unfused; decode-step dispatches "
          f"{audit_on['dispatches']} (fused) vs {audit_off['dispatches']} "
          "(unfused)")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    _bench(args.smoke)
    if args.smoke:
        _smoke_gates()
    return 0


def run():
    """benchmarks/run.py entry: (name, us_per_call, derived) CSV rows —
    us_per_call is the fused path's wall microseconds per call (the
    interpreter's on CPU; see module docstring)."""
    for name, us, derived in _bench(True, quiet=True):
        yield name, us, derived


if __name__ == "__main__":
    raise SystemExit(main())
