"""Paper Table I: cycle-latency comparison against prior CiM XOR designs,
extended to bulk-operation throughput (the paper's §II system argument) and
to this framework's TPU bit-engine kernels.

For the TPU columns we *measure* the wall-time of the single-pass fused
kernels (ref path on CPU; the Pallas path lowers the same single-pass
structure for TPU) and report bytes/s alongside the cycle model.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import speedup
from repro.kernels import ops


def _time(f, *a, n=5):
    f(*a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[tuple]:
    rows = []
    n_bits = 512 * 512 * 64  # a 512-row bank copy-verify workload
    for design in speedup.TABLE_I:
        tech, extra_t, lat = speedup.TABLE_I[design]
        cyc = speedup.design_cycles(design, n_bits)
        cv = speedup.copy_verify_cycles(512, design)
        rows.append((f"table1_{design}", 0.0,
                     f"tech={tech} extra_transistors={extra_t} "
                     f"latency={lat}cyc bulk_16Mbit={cyc}cyc "
                     f"copy_verify_512rows={cv}cyc"))

    # TPU bit-engine measured throughput (single memory pass per operand)
    rng = np.random.default_rng(0)
    buf = jnp.asarray(rng.integers(0, 2**32, 1 << 22, dtype=np.uint32))  # 16MB
    us = _time(lambda b: ops.digest(b, impl="ref"), buf)
    rows.append(("tpu_parity_digest_16MiB", us,
                 f"{buf.nbytes / (us * 1e-6) / 1e9:.2f} GB/s single-pass"))
    key = jnp.array([1, 2], dtype=jnp.uint32)
    us = _time(lambda b: ops.stream_cipher(b, key), buf)
    rows.append(("tpu_xor_cipher_16MiB", us,
                 f"{buf.nbytes / (us * 1e-6) / 1e9:.2f} GB/s single-pass"))
    a = jnp.asarray(rng.integers(0, 2**32, (512, 64), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (512, 64), dtype=np.uint32))
    us = _time(lambda x, y: ops.xnor_matmul(x, y, 2048, impl="ref"), a, b)
    bitops = 2 * 512 * 512 * 2048
    rows.append(("tpu_xnor_gemm_512x512x2048", us,
                 f"{bitops / (us * 1e-6) / 1e12:.2f} Tbitops/s packed"))
    return rows
