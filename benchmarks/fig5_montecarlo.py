"""Paper Fig. 5: 5000-point Monte-Carlo variation analysis + array
scalability vs HRS/LRS ratio.

Reports: SL-current distributions per input state (Fig. 5(c)), n_CELL/n_REF
node-voltage spreads (Fig. 5(d)), XOR error rates under variation, worst-case
sense margins, and max-rows vs on/off ratio (Fig. 5(b)).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import montecarlo


def run() -> list[tuple]:
    rows = []
    t0 = time.perf_counter()
    res = montecarlo.run(jax.random.PRNGKey(0), samples=5000, rows=3)
    jax.block_until_ready(res.i_sl)
    dt = (time.perf_counter() - t0) * 1e6

    i = np.asarray(res.i_sl)
    for si, name in enumerate(["00", "01", "11"]):
        rows.append((f"fig5c_I_{name}", dt / 3,
                     f"mean={i[:, si].mean()*1e6:.4f}uA "
                     f"std={i[:, si].std()*1e6:.4f}uA "
                     f"err={float(res.error_rate[si]):.5f}"))
    v = np.asarray(res.v_cell)
    rows.append(("fig5d_vcell", 0.0,
                 f"V(01)={v[:,1].mean()*1e3:.1f}±{v[:,1].std()*1e3:.2f}mV "
                 f"V(11)={v[:,2].mean()*1e3:.1f}±{v[:,2].std()*1e3:.2f}mV"))
    m = np.asarray(res.margins)
    rows.append(("fig5_margins", 0.0,
                 f"min_lo={m[:,0].min()*1e6:.2f}uA min_hi={m[:,1].min()*1e6:.2f}uA"))

    # beyond-paper: the same MC vmapped over a bank stack (DESIGN.md §10) —
    # every bank is an independent device/Vt world; errors aggregate over all.
    t0 = time.perf_counter()
    bres = montecarlo.run(jax.random.PRNGKey(1), samples=1250, rows=3, banks=4)
    jax.block_until_ready(bres.i_sl)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("fig5_banked_mc", dt,
                 f"worlds={bres.i_sl.shape[0]}x{bres.i_sl.shape[1]}banks "
                 f"max_err={float(bres.error_rate.max()):.5f} "
                 f"min_margin={float(bres.margins.min())*1e6:.2f}uA"))

    t0 = time.perf_counter()
    ratios = jnp.array([1e4, 3e4, 1e5, 3e5, 3e9 / 1e4])
    mr_lrs = np.asarray(montecarlo.max_rows_sweep(ratios, vary="lrs"))
    mr_hrs = np.asarray(montecarlo.max_rows_sweep(ratios, vary="hrs"))
    dt = (time.perf_counter() - t0) * 1e6
    for r, a, b in zip(np.asarray(ratios), mr_lrs, mr_hrs):
        rows.append((f"fig5b_ratio_{r:.0e}", dt / len(mr_lrs),
                     f"max_rows(vary_lrs)={int(a)} max_rows(vary_hrs)={int(b)}"))
    return rows
