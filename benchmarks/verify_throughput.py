"""Paper §II system argument: bulk copy + verification + encryption at the
framework level — checkpoint-shard digest/encrypt throughput and the
end-to-end save(+verify)/restore(+verify) path.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.checkpoint import ckpt
from repro.core import encrypt, verify
from repro.core.engine import CimEngine


def run() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)
    tree = {"layer0": rng.standard_normal((1024, 2048)).astype(np.float32),
            "layer1": rng.standard_normal((2048, 1024)).astype(np.float32),
            "embed": rng.standard_normal((4096, 512)).astype(np.float32)}
    nbytes = sum(a.nbytes for a in tree.values())

    t0 = time.perf_counter()
    for k, v in tree.items():
        verify.np_digest(v)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("host_digest_tree", us,
                 f"{nbytes/1e6:.0f}MB {nbytes/(us*1e-6)/1e9:.2f} GB/s"))

    t0 = time.perf_counter()
    for k, v in tree.items():
        encrypt.encrypt_np(v, "root", k)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("host_encrypt_tree", us,
                 f"{nbytes/(us*1e-6)/1e9:.2f} GB/s counter-mode XOR"))

    # device path through the banked engine (DESIGN.md §10): same digests,
    # plus modeled bank-cycle accounting.
    import jax
    import jax.numpy as jnp
    jtree = {k: jnp.asarray(v) for k, v in tree.items()}
    jax.block_until_ready(verify.tree_digest(jtree))       # jit warmup
    eng = CimEngine()
    t0 = time.perf_counter()
    digs = verify.tree_digest(jtree, engine=eng)
    jax.block_until_ready(digs)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("engine_digest_tree", us,
                 f"{eng.stats.cycles} bank-cycles "
                 f"({eng.stats.ops_per_cycle:.0f} ops/cycle, "
                 f"{eng.geometry.banks} banks)"))

    words = {k: jax.lax.bitcast_convert_type(v, jnp.uint32)
             for k, v in jtree.items()}
    for k, v in words.items():                             # jit warmup
        jax.block_until_ready(encrypt.encrypt_device(v, "root", k))
    t0 = time.perf_counter()
    for k, v in words.items():
        jax.block_until_ready(encrypt.encrypt_device(v, "root", k,
                                                     engine=eng))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("engine_encrypt_tree", us,
                 f"{nbytes/(us*1e-6)/1e9:.2f} GB/s via CimEngine"))

    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        ckpt.save(d, 1, tree, root_key="root")        # includes write-verify
        us_save = (time.perf_counter() - t0) * 1e6
        import jax
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        t0 = time.perf_counter()
        ckpt.restore(d, 1, like, root_key="root")     # includes read-verify
        us_rest = (time.perf_counter() - t0) * 1e6
        sz = os.path.getsize(os.path.join(d, "ckpt_00000001.npz"))
    rows.append(("ckpt_save_encrypt_verify", us_save,
                 f"{sz/1e6:.0f}MB on disk, write+parity-verify"))
    rows.append(("ckpt_restore_decrypt_verify", us_rest,
                 "restore+parity-verify"))
    return rows
