"""Replicated serving tier with a kill-a-replica fault drill
(DESIGN.md §17).

The same seeded mixed-length trace runs twice:

  single      — one ServeEngine (the PR-5/6 serving path), the token
                baseline;
  replicated  — a Router over N replicas (least-loaded admission, each
                replica on its own launch.mesh sub-mesh slice), with the
                kill drill: at ``--kill-at`` router steps the most-loaded
                replica dies mid-flight, its queued sessions are
                resubmitted and its admitted sessions drain onto the
                survivors as encrypted migration checkpoints
                (ckpt.save / save_delta + restore against a derived spec).

Because the engine's sampling contract folds (rid, token index) — never
slot or batch composition — into every draw, and migration moves the
session's exact device state (paged KV blocks by table row, recurrent
carries, position, chunked-prefill progress), the replicated run must
produce bit-identical tokens per request, kill or no kill.  The
background integrity scrubber (incremental DigestCache over resident
packed weights + idle cached KV blocks) runs every ``--epoch-steps``
router steps throughout.

``--smoke`` asserts: every request finishes, zero token divergence vs
the single-engine baseline, at least one session actually migrated,
at least one scrubber pass covered the resident packed weights, and no
corruption was reported — wired into CI in both kernel modes.

Run:  PYTHONPATH=src python benchmarks/serve_replicated.py [--smoke]
"""

from __future__ import annotations

import argparse
import os
import tempfile

import numpy as np


def _setup(arch: str, smoke: bool, seed: int):
    import jax
    import repro.configs as configs
    from repro.models import lm

    cfg = configs.get(arch)
    if smoke:
        cfg = cfg.smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _trace(cfg, n_req: int, smoke: bool, seed: int):
    from repro.serve import synthetic_trace

    plens, ntoks = ((4, 7, 11), (4, 6, 9)) if smoke else ((16, 32), (16, 32))
    return synthetic_trace(n_req, cfg.vocab, seed=seed, prompt_lens=plens,
                           new_tokens=ntoks, n_ctx_tokens=cfg.n_ctx_tokens,
                           d_model=cfg.d_model), plens, ntoks


def run_single(cfg, params, trace, slots: int, s_max: int, seed: int,
               pack: bool = True):
    from repro.serve import ServeEngine

    eng = ServeEngine(cfg, params, slots=slots, s_max=s_max, seed=seed,
                      pack=pack, paged=True)
    for r in trace:
        eng.submit(r)
    return eng.run()


def run_replicated(cfg, params, trace, *, replicas: int, slots: int,
                   s_max: int, seed: int, kill_at: int | None,
                   epoch_steps: int, ckpt_dir: str, pack: bool = True):
    from repro.serve import Router

    router = Router(cfg, params, replicas, slots=slots, s_max=s_max,
                    seed=seed, pack=pack, ckpt_dir=ckpt_dir,
                    epoch_steps=epoch_steps)
    for r in trace:
        router.submit(r)
    return router.run(kill_at=kill_at)


def _ckpt_bytes(ckpt_dir: str) -> int:
    total = 0
    for root, _, files in os.walk(ckpt_dir):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total


def _bench(arch: str, smoke: bool, replicas: int, slots: int, requests: int,
           kill_at: int, epoch_steps: int, seed: int, quiet: bool = False):
    def say(*a):
        if not quiet:
            print(*a)

    cfg, params = _setup(arch, smoke, seed)
    n_req = requests or (10 if smoke else 24)
    trace, plens, ntoks = _trace(cfg, n_req, smoke, seed)
    s_max = max(plens) + max(ntoks) + 4

    base = run_single(cfg, params, trace, slots, s_max, seed)
    with tempfile.TemporaryDirectory(prefix="serve_mig_") as d:
        rep = run_replicated(cfg, params, trace, replicas=replicas,
                             slots=slots, s_max=s_max, seed=seed,
                             kill_at=kill_at, epoch_steps=epoch_steps,
                             ckpt_dir=d)
        wire_bytes = _ckpt_bytes(d)

    say(f"# serve_replicated arch={cfg.name} replicas={replicas} "
        f"slots={slots}/replica requests={n_req} kill_at={kill_at} "
        f"epoch={epoch_steps}")
    say(f"{'path':<12s} {'tok/s':>9s} {'wall s':>8s} {'migrations':>11s} "
        f"{'scrubs':>7s} {'corrupt':>8s}")
    say(f"{'single':<12s} {base.tok_per_s:>9.1f} {base.wall:>8.2f} "
        f"{'—':>11s} {'—':>7s} {'—':>8s}")
    say(f"{'replicated':<12s} {rep.tok_per_s:>9.1f} {rep.wall:>8.2f} "
        f"{len(rep.migrations):>11d} {rep.scrub_passes:>7d} "
        f"{rep.scrub_corruptions:>8d}")
    say(f"  drill: killed replica {rep.killed}, "
        f"{len(rep.migrations)} migration checkpoint(s) "
        f"({wire_bytes / 2**10:.0f} KiB encrypted wire), "
        f"{len(rep.straggler_events)} straggler observations")
    divergent = [rid for rid in base.sessions
                 if rep.sessions[rid].tokens != base.sessions[rid].tokens]
    say(f"  identity: {len(base.sessions) - len(divergent)}/"
        f"{len(base.sessions)} requests bit-identical to the single-engine "
        f"baseline")
    return cfg, base, rep, divergent, wire_bytes


def _check_smoke(cfg, base, rep, divergent) -> None:
    assert set(rep.sessions) == set(base.sessions)
    unfinished = [rid for rid, s in rep.sessions.items() if not s.done]
    assert not unfinished, (
        f"kill drill left requests unfinished: {unfinished}")
    assert not divergent, (
        f"tokens diverged from the single-engine baseline after the kill "
        f"drill: rids {divergent}")
    assert rep.killed, "drill did not kill a replica"
    assert rep.migrations, (
        "drill killed a replica but migrated no admitted session — the "
        "trace must keep the victim busy at kill time")
    assert rep.scrub_passes >= 1, "no scrubber pass ran"
    weight_leaves = sum(r.scrub_weight_leaves for r in rep.replicas)
    assert weight_leaves > 0, (
        "scrubber pass covered no resident weight leaves")
    assert rep.scrub_corruptions == 0, (
        f"scrubber reported {rep.scrub_corruptions} corruptions on an "
        f"uncorrupted run")
    assert cfg.quant == "xnor", (
        "smoke gate expects an xnor arch so the scrubbed residency is the "
        "packed form")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b+xnor")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2,
                    help="slots per replica")
    ap.add_argument("--requests", type=int, default=0,
                    help="trace length (0: 24, or 10 under --smoke)")
    ap.add_argument("--kill-at", type=int, default=6,
                    help="router step of the kill drill (0: no kill)")
    ap.add_argument("--epoch-steps", type=int, default=4,
                    help="scrubber cadence in router steps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, base, rep, divergent, _ = _bench(
        args.arch, args.smoke, args.replicas, args.slots, args.requests,
        args.kill_at or None, args.epoch_steps, args.seed)
    if args.smoke:
        _check_smoke(cfg, base, rep, divergent)
        print("smoke OK: kill drill finished every in-flight request with "
              "zero token divergence vs the single engine, and the "
              "integrity scrubber passed over the resident packed weights")
    return 0


def run():
    """benchmarks/run.py entry: (name, us_per_call, derived) CSV rows —
    us_per_call is wall microseconds per generated token."""
    cfg, base, rep, divergent, wire_bytes = _bench(
        "qwen2-7b+xnor", True, 2, 2, 8, 5, 4, 0, quiet=True)
    yield ("single", 1e6 / max(base.tok_per_s, 1e-9),
           f"tok/s={base.tok_per_s:.1f}")
    yield ("replicated_kill", 1e6 / max(rep.tok_per_s, 1e-9),
           f"tok/s={rep.tok_per_s:.1f} migrations={len(rep.migrations)} "
           f"divergent={len(divergent)} scrubs={rep.scrub_passes} "
           f"corrupt={rep.scrub_corruptions} "
           f"wire_kib={wire_bytes / 2**10:.0f}")


if __name__ == "__main__":
    raise SystemExit(main())
