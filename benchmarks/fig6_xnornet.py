"""Paper Fig. 6: XNOR-Net application-level speedup vs N_O (XNOR ops per
cycle), comparing the paper's 1-cycle design against 2- and 3-cycle prior
work and against this framework's TPU packed-lane bit-engine, plus the
XOR-Net variant ([36]).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import speedup


def run() -> list[tuple]:
    rows = []
    n_os = [64, 256, 1024, 4096, 16384, 65536]
    for n_o in n_os:
        s1 = float(speedup.xnornet_speedup(n_o))          # 1-cycle (ours)
        s2 = float(speedup.xnornet_speedup(n_o / 2))      # 2-cycle designs
        s3 = float(speedup.xnornet_speedup(n_o / 3))      # 3-cycle designs
        sx = float(speedup.xornet_speedup(n_o))
        rows.append((f"fig6_NO_{n_o}", 0.0,
                     f"S_1cyc={s1:.1f} S_2cyc={s2:.1f} S_3cyc={s3:.1f} "
                     f"S_xornet={sx:.1f} vs_cpu64={s1/63.92:.2f}x"))
    tpu = speedup.tpu_n_o()
    rows.append(("fig6_tpu_bit_engine", 0.0,
                 f"N_O={tpu} S={float(speedup.xnornet_speedup(tpu)):.0f} "
                 f"(paper eq. with packed VPU lanes)"))
    # alternate parameter reading (N_W=3x3 filters, N_I=14x14 maps)
    s_alt = float(speedup.xnornet_speedup(tpu, c=256, n_w=9, n_i=196))
    rows.append(("fig6_tpu_alt_params", 0.0,
                 f"S={s_alt:.0f} with (N_W, N_I) swapped reading"))
    return rows
