"""Two serving workloads through the unchanged engine core (DESIGN.md §16).

The block-contract registry's payoff, measured: scenarios the engine was
never specialized for, at serve-level numbers.

  transcribe/slots=N — streaming transcription on whisper-tiny: synthetic
                audio streams whose windows decode *incrementally* (each
                window's prompt carries the transcript tail of its
                predecessors, so a stream is a chain of dependent
                sessions).  Rows sweep the slot count; the engine's
                (rid, step) seed-folding makes every row emit bit-identical
                transcripts — slots only buy wall time.

  classify/*  — the paper's XNOR-CNN classification (Fig. 6) as a batched
                service on the xnor-cnn arch: one-shot sessions (a single
                QUERY_TOKEN prompt, image patches as ctx,
                ``max_new_tokens=1``), greedy argmax token = class id.
                packed vs float rows A/B the resident representation: with
                ``pack=True`` every classification runs the paper's
                popcount GEMM from uint32 sign-planes.

``--smoke`` asserts (a) transcripts are bit-identical across slot counts,
(b) packed and float classification predict identically, (c) serve-path
accuracy >= 0.9 on held-out images, and (d) one-shot sessions drain with
zero decode steps (pure slot turnover) — wired into CI in both kernel
modes.

Run:  PYTHONPATH=src python benchmarks/serve_workloads.py [--smoke]
"""

from __future__ import annotations

import argparse
import time


def _bench_transcribe(smoke: bool, seed: int, quiet: bool = False):
    """Streaming transcription rows over one seeded set of audio streams."""
    def say(*a):
        if not quiet:
            print(*a)
    import jax
    import jax.numpy as jnp
    import repro.configs as configs
    from repro.models import lm
    from repro.serve import TranscriptionService, synthetic_audio_trace

    cfg = configs.get("whisper-tiny")
    n_streams, n_windows, budget = (3, 2, 4) if smoke else (6, 4, 8)
    if smoke:
        cfg = cfg.smoke(dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    streams = synthetic_audio_trace(n_streams, n_windows,
                                    n_ctx_tokens=cfg.n_ctx_tokens,
                                    d_model=cfg.d_model, seed=seed)
    say(f"# transcription arch={cfg.name} streams={n_streams} "
        f"windows={n_windows} budget={budget} tok/window")
    rows = []
    for slots in (1, 4):
        svc = TranscriptionService(cfg, params, slots=slots,
                                   tokens_per_window=budget, seed=seed)
        t0 = time.monotonic()
        out = svc.transcribe(streams)
        wall = time.monotonic() - t0
        total = sum(len(t) for t in out.values())
        rows.append((f"slots={slots}",
                     {"wall": wall, "tok_per_s": total / max(wall, 1e-9),
                      "out": out, "stats": svc.stats}))
    say(f"{'path':<10s} {'tok/s':>8s} {'wall s':>8s} {'sessions':>9s} "
        f"{'decode steps':>13s}")
    for name, r in rows:
        say(f"{name:<10s} {r['tok_per_s']:>8.1f} {r['wall']:>8.2f} "
            f"{r['stats'].prefills:>9d} {r['stats'].decode_steps:>13d}")
    return rows


def _bench_classify(smoke: bool, seed: int, quiet: bool = False):
    """Classification rows: packed bit-planes vs float sign weights."""
    def say(*a):
        if not quiet:
            print(*a)
    import jax
    import numpy as np
    from repro.models import bcnn
    from repro.serve import ClassifierService

    n_images = 16 if smoke else 64
    svc = ClassifierService(slots=4, seed=seed)
    imgs, y = bcnn.synthetic_images(jax.random.PRNGKey(seed + 99), n_images)
    imgs, y = np.asarray(imgs), np.asarray(y)
    say(f"# classification arch={svc.cfg.name} images={n_images} slots=4 "
        f"(train acc {svc.train_acc:.2f})")
    rows = []
    for name, service in (
            ("packed", svc),
            ("float", ClassifierService(cfg=svc.cfg, params=svc.params,
                                        slots=4, pack=False))):
        t0 = time.monotonic()
        pred = service.classify(imgs)
        wall = time.monotonic() - t0
        rows.append((name, {
            "wall": wall, "img_per_s": n_images / max(wall, 1e-9),
            "acc": float(np.mean(pred == y)), "pred": pred,
            "stats": service.stats}))
    say(f"{'path':<8s} {'img/s':>8s} {'wall s':>8s} {'acc':>6s} "
        f"{'sessions':>9s} {'decode steps':>13s}")
    for name, r in rows:
        say(f"{name:<8s} {r['img_per_s']:>8.1f} {r['wall']:>8.2f} "
            f"{r['acc']:>6.2f} {r['stats'].prefills:>9d} "
            f"{r['stats'].decode_steps:>13d}")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    t_rows = _bench_transcribe(args.smoke, args.seed)
    c_rows = _bench_classify(args.smoke, args.seed)

    if args.smoke:
        import numpy as np
        serial, wide = t_rows[0][1], t_rows[1][1]
        assert serial["out"] == wide["out"], (
            "transcripts diverge across slot counts — scheduling leaked "
            "into sampling")
        packed, flt = c_rows[0][1], c_rows[1][1]
        np.testing.assert_array_equal(packed["pred"], flt["pred"],
                                      "packed-XNOR predictions diverge "
                                      "from float-sign")
        assert packed["acc"] >= 0.9, (
            f"serve-path accuracy {packed['acc']:.2f} below 0.9 on "
            f"held-out images")
        for name, r in c_rows:
            assert r["stats"].decode_steps == 0, (
                f"{name}: one-shot sessions took "
                f"{r['stats'].decode_steps} decode steps (expected pure "
                f"prefill slot turnover)")
        print("smoke OK: transcripts schedule-independent, packed == "
              "float classification, accuracy >= 0.9, one-shot batches "
              "drain with zero decode steps")
    return 0


def run():
    """benchmarks/run.py entry: (name, us_per_call, derived) CSV rows —
    us per transcript token (transcription) / per image (classification)."""
    for name, r in _bench_transcribe(True, 0, quiet=True):
        st = r["stats"]
        yield (f"transcribe_{name.replace('=', '')}",
               1e6 / max(r["tok_per_s"], 1e-9),
               f"tok/s={r['tok_per_s']:.1f} sessions={st.prefills} "
               f"decode_steps={st.decode_steps}")
    for name, r in _bench_classify(True, 0, quiet=True):
        yield (f"classify_{name}", 1e6 / max(r["img_per_s"], 1e-9),
               f"img/s={r['img_per_s']:.1f} acc={r['acc']:.2f}")


if __name__ == "__main__":
    raise SystemExit(main())
