"""Continuous batching vs the static-batch baseline, packed vs float
weights, block-paged vs slot-dense KV residency (DESIGN.md §13–§14).

Serve paths over the same seeded mixed-length request trace:

  static       — the pre-engine loop (``serve_step.generate_static``, kept
                 verbatim as the baseline): fixed batches of ``slots``
                 requests, prompts right-padded to the batch max, every
                 request decoded to the batch max budget, eager per-token
                 dispatch;
  cont/dense   — the continuous-batching engine, slot-dense KV cache
                 (every slot reserves s_max positions);
  cont/paged   — the engine on the block-paged layout: shared block pool +
                 per-slot block tables + chunked prefill.  Run at *equal
                 device cache memory* with the dense path (same total
                 token capacity), which lets it run ~2-3x the concurrent
                 slots because requests only reserve the blocks they can
                 actually use;
  */packed     — the same engines with packed-weight residency (xnor
                 archs: binary filters as uint32 sign-planes, float
                 weights absent).

Reported per path: useful tok/s (requested tokens / wall), p50/p95
per-request latency, p50/p95 TTFT, resident param bytes, and block-pool
utilization (mean/peak blocks in use) for paged rows.  ``--smoke`` shrinks
the trace and asserts (a) every continuous path >= the static baseline and
(b) paged-continuous >= dense-continuous at equal cache memory — wired
into CI in both kernel modes.

Run:  PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke]
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def run_static(cfg, params, trace, slots: int):
    """Batches of ``slots`` requests; prompts right-padded to the batch max,
    budgets stretched to the batch max.  Per-request latency = its batch's
    completion time (every request in a static batch waits for the
    slowest).  Useful tokens = the trace's requested budgets.  The loop is
    ``serve_step.generate_static`` — the pre-engine path preserved as the
    baseline (``generate`` itself now routes through the engine)."""
    import jax.numpy as jnp
    from repro.train.serve_step import generate_static

    t0 = time.monotonic()
    latencies = []
    for i in range(0, len(trace), slots):
        batch = trace[i:i + slots]
        pmax = max(r.prompt.shape[0] for r in batch)
        nmax = max(r.max_new_tokens for r in batch)
        prompt = np.zeros((len(batch), pmax), np.int32)
        for j, r in enumerate(batch):
            prompt[j, :r.prompt.shape[0]] = r.prompt
        ctx = None
        if cfg.n_ctx_tokens:
            ctx = jnp.asarray(np.stack([np.asarray(r.ctx) for r in batch]))
        out = generate_static(cfg, params, jnp.asarray(prompt), nmax, ctx)
        np.asarray(out)                      # sync
        done = time.monotonic() - t0
        latencies.extend([done] * len(batch))
    wall = time.monotonic() - t0
    useful = sum(r.max_new_tokens for r in trace)
    return {"wall": wall, "tok_per_s": useful / max(wall, 1e-9),
            "p50": float(np.quantile(latencies, 0.5)),
            "p95": float(np.quantile(latencies, 0.95)),
            "ttft50": float("nan"), "ttft95": float("nan")}


def run_engine(cfg, params, trace, slots: int, s_max: int, pack: bool,
               seed: int, paged: bool = False, n_blocks: int = 0):
    from repro.serve import ServeEngine

    eng = ServeEngine(cfg, params, slots=slots, s_max=s_max, seed=seed,
                      pack=pack, paged=paged, n_blocks=n_blocks)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    lat = report.latency_quantiles((0.5, 0.95))
    ttft = report.ttft_quantiles((0.5, 0.95))
    return {"wall": report.wall, "tok_per_s": report.tok_per_s,
            "p50": lat[0.5], "p95": lat[0.95],
            "ttft50": ttft[0.5], "ttft95": ttft[0.95],
            "param_bytes": _tree_bytes(eng.params),
            "stats": report.stats}, report


def _tree_bytes(tree) -> int:
    import jax

    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree))


def _bench(arch: str, smoke: bool, slots: int, requests: int, seed: int,
           quiet: bool = False):
    """All serve paths over one trace; returns the table rows.

    ``quiet`` suppresses the human-readable table — benchmarks/run.py
    consumes stdout as CSV, so the suite entry must not print into it.
    """
    def say(*a):
        if not quiet:
            print(*a)
    import jax
    import repro.configs as configs
    from repro.models import lm
    from repro.serve import synthetic_trace

    cfg = configs.get(arch)
    # dense s_max is the max context the engine *supports*; the trace's
    # requests sit well below it — exactly the over-provisioning regime
    # block paging exists for
    plens, ntoks, s_max = (4, 8, 12), (4, 6, 10), 48
    if smoke:
        cfg = cfg.smoke()
    else:
        plens, ntoks, s_max = (16, 32, 64), (16, 32), 256
    n_req = requests or (10 if smoke else 16)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    trace = synthetic_trace(n_req, cfg.vocab, seed=seed,
                            prompt_lens=plens, new_tokens=ntoks,
                            n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model)

    # equal cache memory: the paged pool holds exactly the dense layout's
    # token capacity (slots * s_max tokens per layer); the slot count then
    # scales by how much a worst-case request actually needs
    cap_tokens = slots * s_max
    max_need = max(r.prompt.shape[0] + r.max_new_tokens - 1 for r in trace)
    paged_slots = max(slots, cap_tokens // max_need)
    n_blocks = 1 + cap_tokens // cfg.block_size

    say(f"# serve_throughput arch={cfg.name} slots={slots} "
          f"requests={n_req} (prompts {plens}, budgets {ntoks}, "
          f"s_max={s_max}); paged: slots={paged_slots} "
          f"n_blocks={n_blocks - 1}x{cfg.block_size}tok (equal cache memory)")
    float_bytes = _tree_bytes(params)

    rows = []
    stat = run_static(cfg, params, trace, slots)
    rows.append(("static", stat, float_bytes))
    eng_d, _ = run_engine(cfg, params, trace, slots, s_max,
                          pack=False, seed=seed)
    rows.append(("cont/dense", eng_d, eng_d["param_bytes"]))
    eng_p, _ = run_engine(cfg, params, trace, paged_slots, s_max,
                          pack=False, seed=seed, paged=True,
                          n_blocks=n_blocks)
    rows.append(("cont/paged", eng_p, eng_p["param_bytes"]))
    if cfg.quant == "xnor":
        eng_pp, _ = run_engine(cfg, params, trace, paged_slots, s_max,
                               pack=True, seed=seed, paged=True,
                               n_blocks=n_blocks)
        rows.append(("paged/packed", eng_pp, eng_pp["param_bytes"]))

    say(f"{'path':<13s} {'tok/s':>9s} {'wall s':>8s} {'p50 ms':>8s} "
          f"{'p95 ms':>8s} {'ttft50':>8s} {'ttft95':>8s} "
          f"{'resident MB':>12s} {'blk util':>9s}")
    for name, r, nbytes in rows:
        st = r.get("stats")
        util = (f"{st.block_utilization:>8.0%}"
                if st is not None and st.blocks_total else f"{'—':>8s}")
        say(f"{name:<13s} {r['tok_per_s']:>9.1f} {r['wall']:>8.2f} "
              f"{r['p50']*1e3:>8.0f} {r['p95']*1e3:>8.0f} "
              f"{r['ttft50']*1e3:>8.0f} {r['ttft95']*1e3:>8.0f} "
              f"{nbytes/2**20:>12.2f} {util}")
    if cfg.quant == "xnor":
        say(f"packed residency: {float_bytes/rows[-1][2]:.1f}x smaller "
              f"resident params than float")
    return cfg, rows, stat


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b+xnor")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="dense-path slot count (paged scales up at equal "
                         "cache memory)")
    ap.add_argument("--requests", type=int, default=0,
                    help="trace length (0: 16, or 10 under --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, rows, stat = _bench(args.arch, args.smoke, args.slots,
                             args.requests, args.seed)

    if args.smoke:
        # every continuous path must clear the bar — a max() would let one
        # path regress below static while another keeps CI green
        for name, r, _ in rows:
            if name == "static":
                continue
            assert r["tok_per_s"] >= stat["tok_per_s"], (
                f"{name} ({r['tok_per_s']:.1f} tok/s) slower than static "
                f"baseline ({stat['tok_per_s']:.1f} tok/s)")
        by_name = {name: r for name, r, _ in rows}
        dense, paged = by_name["cont/dense"], by_name["cont/paged"]
        assert paged["tok_per_s"] >= dense["tok_per_s"], (
            f"paged ({paged['tok_per_s']:.1f} tok/s) slower than dense "
            f"({dense['tok_per_s']:.1f} tok/s) at equal cache memory")
        print("smoke OK: continuous >= static (all paths) and "
              "paged >= dense at equal cache memory")
    return 0


def run():
    """benchmarks/run.py entry: (name, us_per_call, derived) CSV rows —
    us_per_call is wall microseconds per useful token on the smoke trace."""
    _, rows, _ = _bench("qwen2-7b+xnor", True, 2, 8, 0, quiet=True)
    for name, r, nbytes in rows:
        us = 1e6 / max(r["tok_per_s"], 1e-9)
        st = r.get("stats")
        util = (f" blk_util={st.block_utilization:.2f}"
                if st is not None and st.blocks_total else "")
        yield (name.replace("/", "_"), us,
               f"tok/s={r['tok_per_s']:.1f} resident_mb="
               f"{nbytes/2**20:.2f}{util}")


if __name__ == "__main__":
    raise SystemExit(main())
