"""Continuous batching vs the static-batch baseline, packed vs float
weights, block-paged vs slot-dense KV residency (DESIGN.md §13–§14).

Serve paths over the same seeded mixed-length request trace:

  static       — the pre-engine loop (``serve_step.generate_static``, kept
                 verbatim as the baseline): fixed batches of ``slots``
                 requests, prompts right-padded to the batch max, every
                 request decoded to the batch max budget, eager per-token
                 dispatch;
  cont/dense   — the continuous-batching engine, slot-dense KV cache
                 (every slot reserves s_max positions);
  cont/paged   — the engine on the block-paged layout: shared block pool +
                 per-slot block tables + chunked prefill.  Run at *equal
                 device cache memory* with the dense path (same total
                 token capacity), which lets it run ~2-3x the concurrent
                 slots because requests only reserve the blocks they can
                 actually use;
  */packed     — the same engines with packed-weight residency (xnor
                 archs: binary filters as uint32 sign-planes, float
                 weights absent).

A second table runs a 90%-shared-prefix trace (the system-prompt regime)
through the paged engine with the content-addressed prefix cache on vs
off (DESIGN.md §15): hit rate, fresh blocks per request, copy-on-write
copies, and TTFT side by side.

Reported per path: useful tok/s (requested tokens / wall), p50/p95
per-request latency, p50/p95 TTFT, resident param bytes, and block-pool
utilization (mean/peak blocks in use) for paged rows.  ``--smoke`` shrinks
the trace and asserts (a) every continuous path >= the static baseline,
(b) paged-continuous >= dense-continuous at equal cache memory, and
(c) on the shared trace, prefix caching yields bit-identical tokens with
lower TTFT p50 (gated in engine steps — schedule depth — since wall time
on the smoke model is dispatch overhead, not prefill compute) and fewer
fresh blocks per request — wired into CI in both kernel modes.

Run:  PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke]
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def run_static(cfg, params, trace, slots: int):
    """Batches of ``slots`` requests; prompts right-padded to the batch max,
    budgets stretched to the batch max.  Per-request latency = its batch's
    completion time (every request in a static batch waits for the
    slowest).  Useful tokens = the trace's requested budgets.  The loop is
    ``serve_step.generate_static`` — the pre-engine path preserved as the
    baseline (``generate`` itself now routes through the engine)."""
    import jax.numpy as jnp
    from repro.train.serve_step import generate_static

    t0 = time.monotonic()
    latencies = []
    for i in range(0, len(trace), slots):
        batch = trace[i:i + slots]
        pmax = max(r.prompt.shape[0] for r in batch)
        nmax = max(r.max_new_tokens for r in batch)
        prompt = np.zeros((len(batch), pmax), np.int32)
        for j, r in enumerate(batch):
            prompt[j, :r.prompt.shape[0]] = r.prompt
        ctx = None
        if cfg.n_ctx_tokens:
            ctx = jnp.asarray(np.stack([np.asarray(r.ctx) for r in batch]))
        out = generate_static(cfg, params, jnp.asarray(prompt), nmax, ctx)
        np.asarray(out)                      # sync
        done = time.monotonic() - t0
        latencies.extend([done] * len(batch))
    wall = time.monotonic() - t0
    useful = sum(r.max_new_tokens for r in trace)
    return {"wall": wall, "tok_per_s": useful / max(wall, 1e-9),
            "p50": float(np.quantile(latencies, 0.5)),
            "p95": float(np.quantile(latencies, 0.95)),
            "ttft50": float("nan"), "ttft95": float("nan")}


def run_engine(cfg, params, trace, slots: int, s_max: int, pack: bool,
               seed: int, paged: bool = False, n_blocks: int = 0,
               prefix_cache: bool = True, audit: bool = False):
    from repro.serve import ServeEngine

    eng = ServeEngine(cfg, params, slots=slots, s_max=s_max, seed=seed,
                      pack=pack, paged=paged, n_blocks=n_blocks,
                      prefix_cache=prefix_cache)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    lat = report.latency_quantiles((0.5, 0.95))
    ttft = report.ttft_quantiles((0.5, 0.95))
    out = {"wall": report.wall, "tok_per_s": report.tok_per_s,
           "p50": lat[0.5], "p95": lat[0.95],
           "ttft50": ttft[0.5], "ttft95": ttft[0.95],
           "param_bytes": _tree_bytes(eng.params),
           "stats": report.stats}
    if audit:
        # AOT roofline audit of the decode step (nothing re-runs): achieved
        # vs analytic-minimum bytes + jaxpr dispatch count (DESIGN.md §18)
        out["roofline"] = eng.decode_roofline()
    return out, report


def _tree_bytes(tree) -> int:
    import jax

    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree))


def _bench(arch: str, smoke: bool, slots: int, requests: int, seed: int,
           quiet: bool = False):
    """All serve paths over one trace; returns the table rows.

    ``quiet`` suppresses the human-readable table — benchmarks/run.py
    consumes stdout as CSV, so the suite entry must not print into it.
    """
    def say(*a):
        if not quiet:
            print(*a)
    import jax
    import repro.configs as configs
    from repro.models import lm
    from repro.serve import synthetic_trace

    cfg = configs.get(arch)
    # dense s_max is the max context the engine *supports*; the trace's
    # requests sit well below it — exactly the over-provisioning regime
    # block paging exists for
    plens, ntoks, s_max = (4, 8, 12), (4, 6, 10), 48
    if smoke:
        cfg = cfg.smoke()
    else:
        plens, ntoks, s_max = (16, 32, 64), (16, 32), 256
    n_req = requests or (10 if smoke else 16)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    trace = synthetic_trace(n_req, cfg.vocab, seed=seed,
                            prompt_lens=plens, new_tokens=ntoks,
                            n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model)

    # equal cache memory: the paged pool holds exactly the dense layout's
    # token capacity (slots * s_max tokens per layer); the slot count then
    # scales by how much a worst-case request actually needs
    cap_tokens = slots * s_max
    max_need = max(r.prompt.shape[0] + r.max_new_tokens - 1 for r in trace)
    paged_slots = max(slots, cap_tokens // max_need)
    n_blocks = 1 + cap_tokens // cfg.block_size

    say(f"# serve_throughput arch={cfg.name} slots={slots} "
          f"requests={n_req} (prompts {plens}, budgets {ntoks}, "
          f"s_max={s_max}); paged: slots={paged_slots} "
          f"n_blocks={n_blocks - 1}x{cfg.block_size}tok (equal cache memory)")
    float_bytes = _tree_bytes(params)

    rows = []
    stat = run_static(cfg, params, trace, slots)
    rows.append(("static", stat, float_bytes))
    eng_d, _ = run_engine(cfg, params, trace, slots, s_max,
                          pack=False, seed=seed)
    rows.append(("cont/dense", eng_d, eng_d["param_bytes"]))
    eng_p, _ = run_engine(cfg, params, trace, paged_slots, s_max,
                          pack=False, seed=seed, paged=True,
                          n_blocks=n_blocks)
    rows.append(("cont/paged", eng_p, eng_p["param_bytes"]))
    if cfg.quant == "xnor":
        eng_pp, _ = run_engine(cfg, params, trace, paged_slots, s_max,
                               pack=True, seed=seed, paged=True,
                               n_blocks=n_blocks)
        rows.append(("paged/packed", eng_pp, eng_pp["param_bytes"]))

    say(f"{'path':<13s} {'tok/s':>9s} {'wall s':>8s} {'p50 ms':>8s} "
          f"{'p95 ms':>8s} {'ttft50':>8s} {'ttft95':>8s} "
          f"{'resident MB':>12s} {'blk util':>9s}")
    for name, r, nbytes in rows:
        st = r.get("stats")
        util = (f"{st.block_utilization:>8.0%}"
                if st is not None and st.blocks_total else f"{'—':>8s}")
        say(f"{name:<13s} {r['tok_per_s']:>9.1f} {r['wall']:>8.2f} "
              f"{r['p50']*1e3:>8.0f} {r['p95']*1e3:>8.0f} "
              f"{r['ttft50']*1e3:>8.0f} {r['ttft95']*1e3:>8.0f} "
              f"{nbytes/2**20:>12.2f} {util}")
    if cfg.quant == "xnor":
        say(f"packed residency: {float_bytes/rows[-1][2]:.1f}x smaller "
              f"resident params than float")
    fused_rows = _bench_fused(cfg, params, trace, paged_slots, s_max,
                              n_blocks, seed, quiet=quiet)
    return cfg, rows, stat, fused_rows


def _bench_fused(cfg, params, trace, slots: int, s_max: int, n_blocks: int,
                 seed: int, quiet: bool = False):
    """Fused vs. unfused decode dispatch on the paged engine (§18).

    ``paged/fused`` runs the production dispatch (``fused_decode="auto"``:
    the single-dispatch Pallas kernels on real TPU, their bit-exact unfused
    twin on CPU backends — so on CPU CI the two rows execute the same
    program and differ only in noise); ``paged/unfused`` forces the chain.
    Each row carries the AOT decode-step roofline audit: achieved bytes vs
    the analytic floor, and the jaxpr dispatch count.  Kernel-level fused
    timings (where CPU interprets the kernel) live in
    ``paged_decode_bench.py``.
    """
    import dataclasses

    from repro.roofline import report as rreport

    def say(*a):
        if not quiet:
            print(*a)

    rows = []
    for name, mode in (("paged/fused", "auto"), ("paged/unfused", "off")):
        c = dataclasses.replace(cfg, fused_decode=mode)
        r, _ = run_engine(c, params, trace, slots, s_max, pack=False,
                          seed=seed, paged=True, n_blocks=n_blocks,
                          audit=True)
        rows.append((name, r))
    say(rreport.serve_decode_header())
    for name, r in rows:
        say(rreport.serve_decode_row(f"{name} ({r['tok_per_s']:.1f} tok/s)",
                                     r["roofline"]))
    return rows


def _check_fused_smoke(rows) -> None:
    """--smoke gates for the fused/unfused decode rows."""
    fused, unfused = rows[0][1], rows[1][1]
    # on CPU CI both rows run the same program (the kernel engages on real
    # TPU only under "auto"), so >= holds up to scheduler noise; the slack
    # keeps the gate meaningful without flaking on equal-program jitter
    assert fused["tok_per_s"] >= 0.9 * unfused["tok_per_s"], (
        f"fused decode ({fused['tok_per_s']:.1f} tok/s) slower than "
        f"unfused ({unfused['tok_per_s']:.1f} tok/s)")
    for _, r in rows:
        rf = r["roofline"]
        assert rf["achieved_bytes"] >= rf["roofline_bytes"] > 0, (
            "roofline floor above achieved bytes — the analytic model or "
            "the cost analysis is wrong")


def _bench_prefix(arch: str, smoke: bool, slots: int, requests: int,
                  seed: int, quiet: bool = False):
    """Prefix caching on a shared-prompt trace (DESIGN.md §15).

    90% of requests open with one long common prefix — the system-prompt
    regime prefix caching exists for.  The same trace runs through two
    otherwise identical paged engines, prefix cache on vs off; the cache
    skips the shared blocks' prefill chunks and maps them copy-on-write,
    so TTFT and fresh blocks per request both drop while tokens stay
    bit-identical (sharing reuses the exact KV the donor wrote).
    """
    def say(*a):
        if not quiet:
            print(*a)
    import jax
    import repro.configs as configs
    from repro.models import lm
    from repro.serve import synthetic_trace

    cfg = configs.get(arch)
    if smoke:
        cfg = cfg.smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    bs = cfg.block_size
    plens, ntoks = ((4, 8), (4, 6)) if smoke else ((16, 32), (16, 32))
    prefix_len = 16 * bs
    n_req = requests or (10 if smoke else 16)
    s_max = prefix_len + max(plens) + max(ntoks)
    s_max += (-s_max) % bs
    # two slots: a short first admission wave, so the donor's blocks are
    # registered before most sharers arrive — the steady-state regime a
    # production prefix cache lives in
    p_slots = 2
    n_blocks = 1 + (p_slots + 2) * (s_max // bs)
    trace = synthetic_trace(n_req, cfg.vocab, seed=seed,
                            prompt_lens=plens, new_tokens=ntoks,
                            n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model,
                            prefix_frac=0.9, prefix_len=prefix_len)
    say(f"# prefix caching arch={cfg.name} slots={p_slots} "
          f"requests={n_req} (90% share a {prefix_len}-token prefix, "
          f"suffixes {plens}, budgets {ntoks}, "
          f"n_blocks={n_blocks - 1}x{bs}tok)")

    rows = []
    for name, on in (("paged/prefix", True), ("paged/no-prefix", False)):
        r, rep = run_engine(cfg, params, trace, p_slots, s_max, pack=False,
                            seed=seed, paged=True, n_blocks=n_blocks,
                            prefix_cache=on)
        r["report"] = rep
        stp = rep.ttft_step_quantiles((0.5, 0.95))
        r["ttft_steps50"], r["ttft_steps95"] = stp[0.5], stp[0.95]
        rows.append((name, r))

    say(f"{'path':<15s} {'tok/s':>9s} {'ttft50':>8s} {'stp50':>6s} "
          f"{'stp95':>6s} {'hit rate':>9s} {'blk/req':>8s} {'cow':>4s} "
          f"{'evict':>6s}")
    for name, r in rows:
        st = r["stats"]
        say(f"{name:<15s} {r['tok_per_s']:>9.1f} {r['ttft50']*1e3:>8.0f} "
              f"{r['ttft_steps50']:>6.0f} {r['ttft_steps95']:>6.0f} "
              f"{st.prefix_hit_rate:>8.0%} {st.blocks_per_request:>8.2f} "
              f"{st.cow_copies:>4d} {st.prefix_evictions:>6d}")
    return rows


def _check_prefix_smoke(rows) -> None:
    """--smoke gates for the shared-trace column."""
    on, off = rows[0][1], rows[1][1]
    for rid in on["report"].sessions:
        assert np.array_equal(on["report"].tokens(rid),
                              off["report"].tokens(rid)), (
            f"rid {rid}: prefix-cached tokens diverge from uncached")
    st_on, st_off = on["stats"], off["stats"]
    assert st_on.prefix_hit_rate > 0.5, (
        f"90%-shared trace only hit {st_on.prefix_hit_rate:.0%} of "
        f"prompt tokens in the prefix cache")
    assert st_off.prefix_hits == 0
    assert st_on.blocks_per_request < st_off.blocks_per_request, (
        f"prefix caching did not reduce fresh blocks per request "
        f"({st_on.blocks_per_request:.2f} vs {st_off.blocks_per_request:.2f})")
    # TTFT gated in engine steps (schedule depth): the smoke model is so
    # small that wall TTFT is per-step dispatch/sync overhead, pure machine
    # noise; the step count is deterministic and is what wall time tracks
    # once prefill compute dominates (skipping 16 shared blocks drops p50
    # from ~42 to ~24 steps on this trace)
    assert on["ttft_steps50"] < off["ttft_steps50"], (
        f"prefix-cached TTFT p50 ({on['ttft_steps50']:.0f} engine steps) "
        f"not below uncached ({off['ttft_steps50']:.0f}) on a 90%-shared "
        f"trace")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b+xnor")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="dense-path slot count (paged scales up at equal "
                         "cache memory)")
    ap.add_argument("--requests", type=int, default=0,
                    help="trace length (0: 16, or 10 under --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, rows, stat, fused_rows = _bench(args.arch, args.smoke, args.slots,
                                         args.requests, args.seed)
    prefix_rows = _bench_prefix(args.arch, args.smoke, args.slots,
                                args.requests, args.seed)

    if args.smoke:
        # every continuous path must clear the bar — a max() would let one
        # path regress below static while another keeps CI green
        for name, r, _ in rows:
            if name == "static":
                continue
            assert r["tok_per_s"] >= stat["tok_per_s"], (
                f"{name} ({r['tok_per_s']:.1f} tok/s) slower than static "
                f"baseline ({stat['tok_per_s']:.1f} tok/s)")
        by_name = {name: r for name, r, _ in rows}
        dense, paged = by_name["cont/dense"], by_name["cont/paged"]
        assert paged["tok_per_s"] >= dense["tok_per_s"], (
            f"paged ({paged['tok_per_s']:.1f} tok/s) slower than dense "
            f"({dense['tok_per_s']:.1f} tok/s) at equal cache memory")
        _check_fused_smoke(fused_rows)
        _check_prefix_smoke(prefix_rows)
        print("smoke OK: continuous >= static (all paths), paged >= dense "
              "at equal cache memory, fused decode >= unfused with sane "
              "roofline columns, and prefix caching cuts TTFT and "
              "blocks/request on a 90%-shared trace at identical tokens")
    return 0


def run():
    """benchmarks/run.py entry: (name, us_per_call, derived) CSV rows —
    us_per_call is wall microseconds per useful token on the smoke trace."""
    _, rows, _, fused_rows = _bench("qwen2-7b+xnor", True, 2, 8, 0,
                                    quiet=True)
    for name, r, nbytes in rows:
        us = 1e6 / max(r["tok_per_s"], 1e-9)
        st = r.get("stats")
        util = (f" blk_util={st.block_utilization:.2f}"
                if st is not None and st.blocks_total else "")
        yield (name.replace("/", "_"), us,
               f"tok/s={r['tok_per_s']:.1f} resident_mb="
               f"{nbytes/2**20:.2f}{util}")
    for name, r in fused_rows:
        rf = r["roofline"]
        pct = 100.0 * rf["roofline_bytes"] / max(rf["achieved_bytes"], 1)
        yield (name.replace("/", "_"), 1e6 / max(r["tok_per_s"], 1e-9),
               f"tok/s={r['tok_per_s']:.1f} "
               f"achieved_bytes={rf['achieved_bytes']:.0f} "
               f"roofline_bytes={rf['roofline_bytes']:.0f} "
               f"roofline_pct={pct:.1f} dispatches={rf['dispatches']}")
    for name, r in _bench_prefix("qwen2-7b+xnor", True, 2, 8, 0, quiet=True):
        st = r["stats"]
        yield (name.replace("/", "_").replace("-", "_"),
               r["ttft50"] * 1e6,
               f"ttft50_steps={r['ttft_steps50']:.0f} "
               f"hit_rate={st.prefix_hit_rate:.2f} "
               f"blk_per_req={st.blocks_per_request:.2f} "
               f"cow={st.cow_copies}")


if __name__ == "__main__":
    raise SystemExit(main())
