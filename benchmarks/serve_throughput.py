"""Continuous batching vs the static-batch baseline, packed vs float
weights (DESIGN.md §13).

Three serve paths over the same seeded mixed-length request trace:

  static      — the pre-engine loop (``serve_step.generate_static``, kept
                verbatim as the baseline): fixed batches of ``slots``
                requests, prompts right-padded to the batch max, every
                request decoded to the batch max budget, eager per-token
                dispatch;
  cont/float  — the continuous-batching engine serving float weights;
  cont/packed — the engine with packed-weight residency (xnor archs:
                binary filters live as uint32 sign-planes, float weights
                absent from the resident params).

Reported per path: useful tok/s (requested tokens / wall), p50/p95
per-request latency, resident param bytes.  ``--smoke`` shrinks the trace
and asserts continuous batching >= the static baseline in tok/s — wired
into CI in both kernel modes.

Run:  PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke]
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def run_static(cfg, params, trace, slots: int):
    """Batches of ``slots`` requests; prompts right-padded to the batch max,
    budgets stretched to the batch max.  Per-request latency = its batch's
    completion time (every request in a static batch waits for the
    slowest).  Useful tokens = the trace's requested budgets.  The loop is
    ``serve_step.generate_static`` — the pre-engine path preserved as the
    baseline (``generate`` itself now routes through the engine)."""
    import jax.numpy as jnp
    from repro.train.serve_step import generate_static

    t0 = time.monotonic()
    latencies = []
    for i in range(0, len(trace), slots):
        batch = trace[i:i + slots]
        pmax = max(r.prompt.shape[0] for r in batch)
        nmax = max(r.max_new_tokens for r in batch)
        prompt = np.zeros((len(batch), pmax), np.int32)
        for j, r in enumerate(batch):
            prompt[j, :r.prompt.shape[0]] = r.prompt
        ctx = None
        if cfg.n_ctx_tokens:
            ctx = jnp.asarray(np.stack([np.asarray(r.ctx) for r in batch]))
        out = generate_static(cfg, params, jnp.asarray(prompt), nmax, ctx)
        np.asarray(out)                      # sync
        done = time.monotonic() - t0
        latencies.extend([done] * len(batch))
    wall = time.monotonic() - t0
    useful = sum(r.max_new_tokens for r in trace)
    return {"wall": wall, "tok_per_s": useful / max(wall, 1e-9),
            "p50": float(np.quantile(latencies, 0.5)),
            "p95": float(np.quantile(latencies, 0.95))}


def run_engine(cfg, params, trace, slots: int, s_max: int, pack: bool,
               seed: int):
    from repro.serve import ServeEngine

    eng = ServeEngine(cfg, params, slots=slots, s_max=s_max, seed=seed,
                      pack=pack)
    for r in trace:
        eng.submit(r)
    report = eng.run()
    lat = report.latency_quantiles((0.5, 0.95))
    return {"wall": report.wall, "tok_per_s": report.tok_per_s,
            "p50": lat[0.5], "p95": lat[0.95],
            "param_bytes": _tree_bytes(eng.params)}, report


def _tree_bytes(tree) -> int:
    import jax

    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b+xnor")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=0,
                    help="trace length (0: 16, or 10 under --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import repro.configs as configs
    from repro.models import lm
    from repro.serve import synthetic_trace

    cfg = configs.get(args.arch)
    plens, ntoks, s_max = (4, 8, 12), (4, 6, 10), 24
    if args.smoke:
        cfg = cfg.smoke()
    else:
        plens, ntoks, s_max = (16, 32, 64), (16, 32), 128
    n_req = args.requests or (10 if args.smoke else 16)
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    trace = synthetic_trace(n_req, cfg.vocab, seed=args.seed,
                            prompt_lens=plens, new_tokens=ntoks,
                            n_ctx_tokens=cfg.n_ctx_tokens,
                            d_model=cfg.d_model)

    print(f"# serve_throughput arch={cfg.name} slots={args.slots} "
          f"requests={n_req} (prompts {plens}, budgets {ntoks})")
    float_bytes = _tree_bytes(params)

    rows = []
    stat = run_static(cfg, params, trace, args.slots)
    rows.append(("static", stat, float_bytes))
    eng_f, _ = run_engine(cfg, params, trace, args.slots, s_max,
                          pack=False, seed=args.seed)
    rows.append(("cont/float", eng_f, eng_f["param_bytes"]))
    if cfg.quant == "xnor":
        eng_p, _ = run_engine(cfg, params, trace, args.slots, s_max,
                              pack=True, seed=args.seed)
        rows.append(("cont/packed", eng_p, eng_p["param_bytes"]))

    print(f"{'path':<12s} {'tok/s':>9s} {'wall s':>8s} {'p50 ms':>8s} "
          f"{'p95 ms':>8s} {'resident MB':>12s}")
    for name, r, nbytes in rows:
        print(f"{name:<12s} {r['tok_per_s']:>9.1f} {r['wall']:>8.2f} "
              f"{r['p50']*1e3:>8.0f} {r['p95']*1e3:>8.0f} "
              f"{nbytes/2**20:>12.2f}")
    if cfg.quant == "xnor":
        print(f"packed residency: {float_bytes/rows[-1][2]:.1f}x smaller "
              f"resident params than float")

    if args.smoke:
        # every continuous path must clear the bar — a max() would let the
        # packed path regress below static while float keeps CI green
        for name, r, _ in rows:
            if name == "static":
                continue
            assert r["tok_per_s"] >= stat["tok_per_s"], (
                f"{name} ({r['tok_per_s']:.1f} tok/s) slower than static "
                f"baseline ({stat['tok_per_s']:.1f} tok/s)")
        print("smoke OK: continuous batching >= static baseline "
              "(float and packed)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
