"""Paper Fig. 4: functional verification of single-cycle in-memory XOR/XNOR.

Reproduces the 3x3 array of Fig. 4(a): programs the assumed memory states,
asserts both word lines, reports per-column SL currents (Fig. 4(d)) and the
XOR/XNOR outputs for every input combination, plus memory-mode write/read
(Fig. 3).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import cim, logic


def run() -> list[tuple]:
    rows = []
    # Fig. 4(a) states: row0/row1 give columns (1,0), (0,0), (1,1)
    bits = jnp.array([[1, 0, 1], [0, 0, 1], [1, 1, 0]])
    st = cim.make_array(bits)

    t0 = time.perf_counter()
    i_sl = np.asarray(cim.sl_currents(st, jnp.array([True, True, False])))
    xor_out = np.asarray(cim.compute(st, 0, 1, "xor"))
    xnor_out = np.asarray(cim.compute(st, 0, 1, "xnor"))
    dt = (time.perf_counter() - t0) * 1e6

    for col, (i, xo, xn) in enumerate(zip(i_sl, xor_out, xnor_out)):
        a, b = int(bits[0, col]), int(bits[1, col])
        rows.append((f"fig4_col{col}_{a}{b}", dt / 3,
                     f"I_SL={i*1e6:.3f}uA XOR={int(xo)} XNOR={int(xn)}"))
        assert int(xo) == a ^ b and int(xn) == 1 - (a ^ b)

    # reference current placement (Fig. 4(b))
    rows.append(("fig4_refs", 0.0,
                 f"REF1={logic.REF_LO*1e6:.0f}uA REF2={logic.REF_HI*1e6:.0f}uA"
                 f" I00={i_sl[1]*1e9:.2f}nA I01={i_sl[0]*1e6:.2f}uA"
                 f" I11={i_sl[2]*1e6:.1f}uA"))

    # Fig. 3: memory-mode write 0->1 and 1->0, then read back via the same SA
    st = cim.write(st, 1, 0, 1)
    st = cim.write(st, 0, 2, 0)
    rd = np.asarray(cim.read(st, 1))
    rows.append(("fig3_write_read", 0.0,
                 f"row1_after_write={rd.astype(int).tolist()}"))
    assert rd[0] == True  # noqa: E712
    return rows
