"""Paper Fig. 1(a)+(b) at system scale: bulk copy with single-pass parity
verification, corruption detection, and XOR-stream encryption — the
checkpoint I/O path of the framework, exercised standalone.

Run:  PYTHONPATH=src python examples/copy_verify_encrypt.py
"""

import tempfile

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core import cim, verify
from repro.core.engine import BankGeometry, CimEngine, ShardedCimEngine
from repro.launch.mesh import make_engine_mesh
import jax.numpy as jnp

# --- the circuit-level story: row copy + in-memory XOR verification ----------
src_row = np.array([1, 0, 1, 1, 0, 0, 1, 0])
arr = cim.make_array(jnp.zeros((2, 8)))
for c, bit in enumerate(src_row):                      # program source row
    arr = cim.write(arr, 0, c, int(bit))
for c, bit in enumerate(src_row):                      # copy to row 1
    arr = cim.write(arr, 1, c, int(bit))
diff = np.asarray(cim.compute(arr, 0, 1, "xor"))
print("circuit copy-verify (XOR of rows, all-zero = ok):",
      diff.astype(int), "->", "OK" if not diff.any() else "CORRUPT")
arr = cim.write(arr, 1, 3, int(1 - src_row[3]))        # corrupt one bit
diff = np.asarray(cim.compute(arr, 0, 1, "xor"))
print("after 1-bit corruption:", diff.astype(int), "-> flagged:",
      bool(diff.any()))

# --- the banked story: many copies verified per sense cycle (DESIGN.md §10) --
rng0 = np.random.default_rng(7)
engine = CimEngine(BankGeometry(banks=4, rows=8, cols=32))
src = rng0.integers(0, 2, (12, 32))                    # 12 copied rows
dst = src.copy()
dst[5, 20] ^= 1                                        # corrupt copy #5
diff = np.asarray(engine.simulate(jnp.asarray(src), jnp.asarray(dst), "xor"))
bad = np.flatnonzero(diff.any(axis=1))
print(f"banked copy-verify: {len(src)} pairs over {engine.geometry.banks} "
      f"banks in {engine.stats.cycles} sense cycles -> corrupt rows {bad}")

# --- the framework-level story: checkpoint shards -----------------------------
rng = np.random.default_rng(0)
tree = {"w1": rng.standard_normal((512, 256)).astype(np.float32),
        "w2": rng.standard_normal((256, 512)).astype(np.float32)}

with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 1, tree, root_key="secret")           # encrypt + verify
    ok, bad = ckpt.check(d, 1, root_key="secret")
    print("checkpoint parity check after save:", "OK" if ok else bad)

    # tamper one bit inside the (valid) container
    path = f"{d}/ckpt_00000001.npz"
    data = dict(np.load(path))
    data["w1"].view(np.uint32)[7] ^= 1 << 3
    with open(path, "wb") as f:
        np.savez(f, **data)
    ok, bad = ckpt.check(d, 1, root_key="secret")
    print("after tampering one bit:", "OK" if ok else f"corrupt leaves={bad}")
    assert not ok

    # single-bit sensitivity of the digest itself (XOR linearity)
    d0 = verify.np_digest(tree["w1"])
    t2 = tree["w1"].copy()
    t2.view(np.uint32).reshape(-1)[123] ^= 1 << 30   # one bit, one word
    d1 = verify.np_digest(t2)
    nbits = sum(int(x).bit_count() for x in np.bitwise_xor(d0, d1))
    print(f"digest bits flipped by a 1-bit corruption: {nbits} (exactly 1)")
    assert nbits == 1

# --- the sharded story: the mesh as the outer bank dimension (§11) -----------
# Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to see a real
# 8-way split; on one device the path is identical, just D=1.
mesh = make_engine_mesh()                              # 1-D "bank" mesh
sharded = ShardedCimEngine(mesh)
geo = sharded.geometry
print(f"\nsharded engine: {geo.devices} device(s) x {geo.banks} banks x "
      f"{geo.cols} cols = {geo.bits_per_cycle} bit-ops/cycle")

dig = verify.tree_digest(tree, engine=sharded)         # sharded per-leaf fold
for name in tree:                                      # == host digests, bit-exact
    assert np.array_equal(np.asarray(dig[name]), verify.np_digest(tree[name]))
nbits_total = sum(a.size * a.dtype.itemsize * 8 for a in tree.values())
print(f"tree digested in {sharded.stats.cycles} modeled cycles "
      f"({nbits_total} bits; only 512 B digests crossed devices)")

with tempfile.TemporaryDirectory() as d:               # device-side ckpt I/O
    ckpt.save(d, 2, tree, root_key="secret", engine=sharded)
    like = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in tree.items()}
    out, _ = ckpt.restore(d, 2, like, root_key="secret")   # host path reads it
    assert all(np.array_equal(out[k], tree[k]) for k in tree)
    print("device-encrypted checkpoint restored via host path: OK")

# --- the incremental story: re-verify only what moved (DESIGN.md §12) --------
# The paper's backup-scrub workload: after a step touches a fraction of the
# pool, a DigestCache re-digests only the dirty chunks — O(changed), not
# O(tree) — and save_delta writes only the leaves whose digest moved.
from repro.core.incremental import DigestCache

jtree = {k: jnp.asarray(v) for k, v in tree.items()}
cache = DigestCache(engine=sharded, chunk_words=4096)
cache.digests(jtree)                                   # prime: full pass
before = sharded.stats.snapshot()
w1 = jtree["w1"].at[0, 0].set(0.0)                     # touch ONE element
cache.digests({"w1": w1, "w2": jtree["w2"]})
print(f"\nincremental re-verify after a 1-element update: "
      f"{cache.last.dirty_chunks}/{cache.last.chunks} chunks re-digested, "
      f"{sharded.stats.cycles - before.cycles} engine cycles "
      f"(clean leaves: {cache.last.clean_leaves})")

with tempfile.TemporaryDirectory() as d:               # delta checkpoint chain
    ckpt.save(d, 1, tree, root_key="secret")
    tree2 = dict(tree, w1=np.asarray(w1))
    m = ckpt.save_delta(d, 2, tree2, root_key="secret")
    stored = [k for k, v in m["leaves"].items() if v["stored_in"] == 2]
    out, _ = ckpt.restore(d, 2, like, root_key="secret")  # resolves the chain
    assert all(np.array_equal(out[k], tree2[k]) for k in tree2)
    print(f"delta checkpoint stored only {stored}; base+delta restore: OK")
