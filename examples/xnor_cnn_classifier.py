"""Paper Fig. 1(c) / §VI: an XNOR-Net binary classifier trained end-to-end.

A small binary-dense network (XNOR-Net semantics: sign activations/weights
with alpha/beta scaling, STE gradients, full-precision first/last layers)
on a synthetic 16x16 two-class image task.  At inference the hidden layers
run through the *packed* XNOR-popcount path — the compute the paper's CiM
array executes in memory — and we assert it matches the float-sign path.

``--serve`` additionally runs the same stripe task as a *served* workload
(DESIGN.md §16): the ``xnor-cnn`` arch — the ``bindense`` registered block
kind — trained in-process and classified through the continuous-batching
engine via ``repro.serve.ClassifierService`` (one-shot sessions, greedy
argmax token = class id, packed popcount residency).

Run:  PYTHONPATH=src python examples/xnor_cnn_classifier.py [--serve]
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import xnor_layers
from repro.core.bitpack import binarize_ste

D_IN, D_H, N_CLS = 256, 512, 2


def make_data(key, n):
    """Two classes: vertical vs horizontal stripes + noise."""
    k1, k2, k3 = jax.random.split(key, 3)
    y = jax.random.bernoulli(k1, 0.5, (n,)).astype(jnp.int32)
    xs = jnp.linspace(-1, 1, 16)
    vert = jnp.sign(jnp.sin(8 * xs))[None, :].repeat(16, 0)
    horz = vert.T
    base = jnp.where(y[:, None, None] == 1, vert[None], horz[None])
    x = base + 0.8 * jax.random.normal(k2, (n, 16, 16))
    return x.reshape(n, D_IN), y


def init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    s = lambda k, shp: jax.random.normal(k, shp) / jnp.sqrt(shp[-1])
    return {"w_in": s(k1, (D_H, D_IN)),      # full precision (XNOR-Net rule)
            "w_mid": s(k2, (D_H, D_H)),      # binary
            "w_out": s(k3, (N_CLS, D_H))}    # full precision


def forward(params, x, packed=False):
    h = jnp.tanh(x @ params["w_in"].T)                     # fp first layer
    h = xnor_layers.xnor_linear(h, params["w_mid"], packed=packed)
    h = jax.nn.relu(h)
    return h @ params["w_out"].T                           # fp last layer


def loss_fn(params, x, y):
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))


def main():
    key = jax.random.PRNGKey(0)
    params = init(key)
    xtr, ytr = make_data(jax.random.PRNGKey(1), 512)
    xte, yte = make_data(jax.random.PRNGKey(2), 256)

    @jax.jit
    def step(params, x, y, lr):
        l, g = jax.value_and_grad(loss_fn)(params, x, y)
        return jax.tree.map(lambda p, gg: p - lr * gg, params, g), l

    for epoch in range(60):
        params, l = step(params, xtr, ytr, 0.3)
        if epoch % 15 == 0:
            acc = jnp.mean(jnp.argmax(forward(params, xte), -1) == yte)
            print(f"epoch {epoch:3d} loss {float(l):.4f} "
                  f"test_acc {float(acc):.3f}")

    acc_f = jnp.mean(jnp.argmax(forward(params, xte), -1) == yte)
    acc_p = jnp.mean(jnp.argmax(forward(params, xte, packed=True), -1) == yte)
    same = jnp.allclose(forward(params, xte), forward(params, xte, packed=True),
                        rtol=1e-3, atol=1e-3)
    print(f"final: float-sign acc {float(acc_f):.3f} | packed XNOR-popcount "
          f"acc {float(acc_p):.3f} | paths agree: {bool(same)}")
    assert acc_f > 0.9 and bool(same)

    if "--serve" in sys.argv[1:]:
        serve_demo()


def serve_demo():
    """The same task as a served workload: classification requests through
    the continuous-batching engine (DESIGN.md §16)."""
    from repro.models import bcnn
    from repro.serve import ClassifierService

    svc = ClassifierService(slots=4)          # trains the xnor-cnn arch
    imgs, y = bcnn.synthetic_images(jax.random.PRNGKey(2), 64)
    pred = svc.classify(np.asarray(imgs))
    acc = float(np.mean(pred == np.asarray(y)))
    print(f"served: {len(pred)} images through the engine "
          f"({svc.stats.prefills} one-shot sessions, "
          f"{svc.stats.decode_steps} decode steps) | acc {acc:.3f}")
    assert acc > 0.9


if __name__ == "__main__":
    main()
