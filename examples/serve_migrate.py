"""Live session migration, step by step (DESIGN.md §17).

A session decoding on engine A is frozen mid-flight, serialized to an
encrypted checkpoint (`export_session` -> `ckpt.save`), restored on a
brand-new engine B against a spec B derives from nothing but the request
(`export_spec` -> `ckpt.restore` -> `import_session`), and finished
there.  Because sampling folds only ``(rid, token index)`` and the wire
carries the session's exact device state — paged KV blocks by table
row, position, chunked-prefill progress — the stitched token stream is
bit-identical to a run that never moved.  The same mechanics power the
replica router's kill drill (``benchmarks/serve_replicated.py``).

Run:  PYTHONPATH=src python examples/serve_migrate.py
"""

import tempfile

import jax

import repro.configs as configs
from repro.checkpoint import ckpt
from repro.models import lm
from repro.serve import Request, Router, ServeEngine, synthetic_trace

cfg = configs.get("qwen3-4b").smoke()
params = lm.init_params(cfg, jax.random.PRNGKey(0))
trace = synthetic_trace(3, cfg.vocab, seed=5, prompt_lens=(6, 10),
                        new_tokens=(8, 12))
KW = dict(slots=2, s_max=32, seed=0, paged=True)

# --- 1. the baseline: one engine, never migrated ----------------------------

base = ServeEngine(cfg, params, **KW)
for r in trace:
    base.submit(r)
base_rep = base.run()
want = {r.rid: list(base_rep.tokens(r.rid)) for r in trace}

# --- 2. freeze mid-decode, ship the encrypted wire, resume elsewhere --------

a = ServeEngine(cfg, params, **KW)
for r in trace:
    a.submit(r)
for _ in range(4):                      # a few decode steps: rid 0 is
    a.step()                            # mid-flight, tokens half-generated
rid = 0
done_before = len(a.sessions[rid].tokens)

with tempfile.TemporaryDirectory(prefix="mig_") as d:
    wire = a.export_session(rid)        # pure read of A's device state
    ckpt.save(d, 1, wire, root_key="demo-key")

    b = ServeEngine(cfg, params, **KW)  # fresh engine, empty pools
    req = next(r for r in trace if r.rid == rid)
    like = b.export_spec(req)           # shapes from (cfg, geometry, req)
    restored, _ = ckpt.restore(d, 1, like, root_key="demo-key")
    b.import_session(req, restored)
    a.release_migrated(rid)             # A frees the slot + blocks

rep_a, rep_b = a.run(), b.run()         # both engines drain independently
got = {r.rid: list((rep_b if r.rid == rid else rep_a).tokens(r.rid))
       for r in trace}
print(f"migrated rid {rid} after {done_before}/{len(want[rid])} tokens; "
      f"resumed on engine B with {len(got[rid]) - done_before} more")
assert got == want, "migration changed tokens"
print(f"all {len(trace)} token streams bit-identical to the "
      f"never-migrated baseline")

# --- 3. the same wire through the replica router's fault drill --------------

trace2 = synthetic_trace(6, cfg.vocab, seed=9, prompt_lens=(5, 8),
                         new_tokens=(6, 9))
single = ServeEngine(cfg, params, **KW)
for r in trace2:
    single.submit(r)
want2 = single.run()

with tempfile.TemporaryDirectory(prefix="mig_") as d:
    router = Router(cfg, params, 2, slots=2, s_max=32, seed=0,
                    ckpt_dir=d, epoch_steps=4)
    for r in trace2:
        router.submit(r)
    rep = router.run(kill_at=5)         # kill the most-loaded replica
div = [r.rid for r in trace2
       if rep.sessions[r.rid].tokens != want2.sessions[r.rid].tokens]
print(f"router drill: killed replica {rep.killed}, "
      f"{len(rep.migrations)} migration(s), "
      f"{rep.scrub_passes} scrubber pass(es), "
      f"{len(div)} divergent streams")
assert not div and rep.scrub_corruptions == 0
print("kill drill token-identical to the single engine; scrubber clean")
