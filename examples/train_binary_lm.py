"""End-to-end driver: train a reduced LM for a few hundred steps, both in
full precision and with the paper's XNOR (binary) projections, with
fault-tolerant checkpointing (XOR-parity verified + encrypted) enabled.

This is the (b)-deliverable end-to-end training example; at container scale
it uses the reduced config (same code path as the production mesh).

Run:  PYTHONPATH=src python examples/train_binary_lm.py [--steps 200]
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data.synthetic import Pipeline
from repro.distributed import fault
from repro.models import lm
from repro.train import train_step as train_mod


def run(cfg, steps, ckpt_dir, label):
    pipe = Pipeline(cfg, batch_size=8, seq_len=64, seed=0)
    runner = fault.Runner(ckpt_dir, save_every=max(steps // 4, 1),
                          root_key="example-key")
    state, start = runner.resume_or_init(
        train_mod.abstract_state(cfg),
        lambda: train_mod.init_state(cfg, jax.random.PRNGKey(0)))

    @jax.jit
    def step_fn(state, batch, step):
        return train_mod.train_step(cfg, state, batch, step, peak_lr=3e-3,
                                    warmup=20, total=steps)

    losses = []
    for step in range(start, steps):
        batch = jax.tree.map(jnp.asarray, pipe.get(step))
        state, m = step_fn(state, batch, jnp.asarray(step, jnp.int32))
        losses.append(float(m["loss"]))
        runner.maybe_save(step + 1, state)
        if step % 50 == 0:
            print(f"  [{label}] step {step:4d} loss {losses[-1]:.4f}")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"  [{label}] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return first, last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2-7b")
    args = ap.parse_args()

    base = configs.get(args.arch).smoke()
    print(f"== full precision ({base.name}) ==")
    with tempfile.TemporaryDirectory() as d:
        f_fp, l_fp = run(base, args.steps, d, "fp")

    import dataclasses
    bcfg = dataclasses.replace(base, quant="xnor")
    print(f"== binary XNOR projections ({bcfg.name}+xnor) ==")
    with tempfile.TemporaryDirectory() as d:
        f_bn, l_bn = run(bcfg, args.steps, d, "xnor")

    print(f"summary: fp {f_fp:.3f}->{l_fp:.3f} | xnor {f_bn:.3f}->{l_bn:.3f}")
    assert l_fp < f_fp and l_bn < f_bn, "both variants must learn"


if __name__ == "__main__":
    main()
