"""Quickstart: the paper's single-cycle in-memory XOR/XNOR, bottom to top.

  1. circuit level — program a CiM array, compute XOR/XNOR in one sense cycle
  2. bit-engine level — packed XNOR-GEMM kernel vs the float oracle
  3. application level — copy-verify + encrypt a parameter tree
  4. model level — one forward through a binary-quantized (XNOR) LM

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import bitpack, cim, encrypt, verify
from repro.kernels import ops, ref
from repro.models import lm

# 1. circuit level -----------------------------------------------------------
bits = jnp.array([[1, 0, 1, 0], [0, 0, 1, 1], [1, 1, 0, 0]])
arr = cim.make_array(bits)
print("rows:", np.asarray(bits[0]), np.asarray(bits[1]))
print("in-memory XOR :", np.asarray(cim.compute(arr, 0, 1, "xor")).astype(int))
print("in-memory XNOR:", np.asarray(cim.compute(arr, 0, 1, "xnor")).astype(int))

# 2. bit-engine level ---------------------------------------------------------
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
b = jnp.asarray(rng.standard_normal((32, 256)), jnp.float32)
pa, _ = ops.binarize(a)
pb, _ = ops.binarize(b)
got = ops.xnor_matmul(pa, pb, 256)
want = ref.xnor_dot_float(a, b)
print("packed XNOR-GEMM == sign-matmul oracle:",
      bool(jnp.all(got == want)), "| packed operand is",
      a.nbytes // pa.nbytes, "x smaller")

# 3. application level --------------------------------------------------------
tree = {"w": np.asarray(a), "b": np.asarray(b)}
d0 = verify.np_digest(tree["w"])
enc = encrypt.encrypt_np(tree["w"], "root-key", "w")
dec = encrypt.decrypt_np(enc, "root-key", "w", np.float32, tree["w"].shape)
print("copy-verify digest stable:", bool((verify.np_digest(dec) == d0).all()),
      "| encrypted bytes differ:", not np.array_equal(
          enc[:16], np.asarray(tree["w"]).view(np.uint8)[:16]))

# 4. model level --------------------------------------------------------------
cfg = dataclasses.replace(configs.get("qwen2-7b").smoke(), quant="xnor")
params = lm.init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
logits, _ = lm.forward(cfg, params, tokens)
print(f"binary-quantized {cfg.name}: logits {logits.shape}, "
      f"finite={bool(jnp.isfinite(logits.astype(jnp.float32)).all())}")
