"""Batched serving example: prefill + resident-state decode across three
architecture families (dense GQA, recurrent hybrid, enc-dec audio),
demonstrating the same serve path the decode_* dry-run cells lower.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import lm
from repro.train import serve_step

for arch in ["qwen3-4b", "recurrentgemma-2b", "whisper-tiny"]:
    cfg = configs.get(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, P, N = 4, 24, 12
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)
    ctx = None
    if cfg.n_ctx_tokens:
        ctx = jax.random.normal(key, (B, cfg.n_ctx_tokens, cfg.d_model),
                                jnp.float32) * 0.1
    t0 = time.time()
    out = serve_step.generate(cfg, params, prompt, N, ctx=ctx,
                              temperature=0.8, key=key)
    dt = time.time() - t0
    print(f"{arch:20s} batch={B} prompt={P} +{N} tokens "
          f"in {dt:5.1f}s -> sample row: {out[0][:8].tolist()}...")
    assert out.shape == (B, N)
    assert int(out.max()) < cfg.vocab
print("serve path OK for dense / hybrid / enc-dec families")
