"""Batched serving example: prefill + resident-state decode across three
architecture families (dense GQA, recurrent hybrid, enc-dec audio) via the
compatibility ``generate`` API, then the multi-request continuous-batching
engine directly — heterogeneous prompts/budgets sharing one resident batch,
with packed-weight residency on a binary (+xnor) arch, and finally
content-addressed prefix caching over the block-paged KV cache on a
shared-system-prompt trace.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models import lm
from repro.serve import Request, ServeEngine, synthetic_trace
from repro.train import serve_step

# --- 1. static-batch compatibility API (wraps the engine) -------------------

for arch in ["qwen3-4b", "recurrentgemma-2b", "whisper-tiny"]:
    cfg = configs.get(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, P, N = 4, 24, 12
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)
    ctx = None
    if cfg.n_ctx_tokens:
        ctx = jax.random.normal(key, (B, cfg.n_ctx_tokens, cfg.d_model),
                                jnp.float32) * 0.1
    t0 = time.time()
    out = serve_step.generate(cfg, params, prompt, N, ctx=ctx,
                              temperature=0.8, key=key)
    dt = time.time() - t0
    print(f"{arch:20s} batch={B} prompt={P} +{N} tokens "
          f"in {dt:5.1f}s -> sample row: {out[0][:8].tolist()}...")
    assert out.shape == (B, N)
    assert int(out.max()) < cfg.vocab
print("serve path OK for dense / hybrid / enc-dec families")

# --- 2. the multi-request engine API ----------------------------------------
# Mixed prompt lengths and budgets share one resident batch: slots free at
# different times and queued requests are admitted (prefilled) into them
# while the others keep decoding.  On a +xnor arch the engine serves from
# packed weights — the binary filters exist only as uint32 sign-planes.

cfg = configs.get("qwen2-7b+xnor").smoke(dtype=jnp.float32)
params = lm.init_params(cfg, jax.random.PRNGKey(1))
eng = ServeEngine(cfg, params, slots=2, s_max=32, seed=0)
trace = synthetic_trace(6, cfg.vocab, seed=7, prompt_lens=(5, 9, 14),
                        new_tokens=(3, 6, 9))
for r in trace:
    eng.submit(r)
report = eng.run()
lat = report.latency_quantiles((0.5, 0.95))
print(f"engine: {len(trace)} requests over 2 slots -> "
      f"{report.generated} tokens, {report.tok_per_s:.1f} tok/s, "
      f"p50={lat[0.5]*1e3:.0f}ms p95={lat[0.95]*1e3:.0f}ms")
for r in trace:
    toks = report.tokens(r.rid)
    assert toks.shape[0] == r.max_new_tokens
    assert int(toks.max()) < cfg.vocab

# a fresh engine over the same trace reproduces the same tokens: sampling
# keys depend on (request, step), never on slot assignment
eng2 = ServeEngine(cfg, params, slots=3, s_max=32, seed=0)
for r in synthetic_trace(6, cfg.vocab, seed=7, prompt_lens=(5, 9, 14),
                         new_tokens=(3, 6, 9)):
    eng2.submit(r)
report2 = eng2.run()
assert all(np.array_equal(report.tokens(r.rid), report2.tokens(r.rid))
           for r in trace)
print("engine OK: deterministic across slot counts, packed-resident weights")

# --- 3. prefix caching on the block-paged engine -----------------------------
# 90% of requests open with the same 48-token "system prompt".  The paged
# engine content-hashes each full prompt block; later requests map the
# cached blocks read-only, skip their prefill chunks, and copy-on-write
# the divergence block before their first scatter.  Tokens stay
# bit-identical to an uncached engine — sharing reuses the exact KV the
# first request wrote.

cfg = configs.get("qwen3-4b").smoke()
params = lm.init_params(cfg, jax.random.PRNGKey(2))
# prefix ends mid-block, so every sharer's first write lands in a cached
# block and must copy-on-write it first
shared = synthetic_trace(6, cfg.vocab, seed=11, prompt_lens=(4, 7),
                         new_tokens=(3, 5), prefix_frac=0.9,
                         prefix_len=6 * cfg.block_size + 3)
reports = {}
for on in (True, False):
    eng3 = ServeEngine(cfg, params, slots=2, s_max=64, seed=0, paged=True,
                       n_blocks=40, prefix_cache=on)
    for r in shared:
        eng3.submit(r)
    reports[on] = eng3.run()
assert all(np.array_equal(reports[True].tokens(r.rid),
                          reports[False].tokens(r.rid)) for r in shared)
st = reports[True].stats
print(f"prefix cache: hit rate {st.prefix_hit_rate:.0%} of prompt tokens, "
      f"{st.blocks_per_request:.1f} fresh blocks/request "
      f"(vs {reports[False].stats.blocks_per_request:.1f} uncached), "
      f"{st.cow_copies} copy-on-write copies — tokens identical to the "
      f"uncached engine")
